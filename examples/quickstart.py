"""Quickstart: the paper's technique in 30 lines.

Builds a pre-defined sparse junction (clash-free pattern), shows its
storage/compute savings, and trains the paper's (800, 100, 10) MLP at
rho=21% on the synthetic MNIST stand-in for a couple of epochs.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (make_pattern, schedule_is_clash_free, storage_cost,
                        to_mask)
from repro.configs.paper_mlp import MNIST_2J, rho_from_dout
from repro.data import synthetic_mnist
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp


def main():
    # 1. a clash-free pre-defined sparse pattern (paper §III-C, type 1)
    pat = make_pattern(n_left=800, n_right=100, rho=0.2,
                       method="clashfree", seed=0)
    print(f"junction 800x100 @ rho={pat.density:.0%}: "
          f"{pat.n_edges} edges, d_in={pat.d_in}")
    sched = pat.meta["sched"]
    print("clash-free schedule verified:",
          schedule_is_clash_free(sched, 800 // pat.meta["z"]))

    # 2. the hardware storage saving (paper Table I)
    fc = storage_cost(MNIST_2J)
    sp = storage_cost(MNIST_2J, d_in=[160, 100])
    print(f"storage words: FC={fc.total}  sparse={sp.total} "
          f"({fc.total / sp.total:.1f}x smaller)")

    # 3. train the paper's MLP with that sparsity
    data = synthetic_mnist(n_train=3000, n_test=800)
    cfg = MLPConfig(n_net=MNIST_2J,
                    rho=rho_from_dout(MNIST_2J, (20, 10)),
                    method="clashfree")
    model = SparseMLP(cfg)
    print(f"training sparse MLP: |W|={model.n_weights()} "
          f"(density {model.density():.0%}) ...")
    _, acc = train_mlp(model, data, epochs=4)
    print(f"test accuracy: {acc:.3f}")


if __name__ == "__main__":
    main()
