"""End-to-end driver #1: the paper's own experiment, faithfully.

Trains the (800, 100, 100, 100, 10) MLP of paper Table II at several
densities with all three pattern methods and prints the comparison —
a few hundred optimizer steps per configuration.

    PYTHONPATH=src python examples/train_sparse_mlp.py [--epochs 8]
"""
import argparse

import numpy as np

from repro.configs.paper_mlp import MNIST_4J, rho_from_dout
from repro.data import synthetic_mnist
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--rows", type=int, default=3)
    args = ap.parse_args()

    data = synthetic_mnist(n_train=6000, n_test=1500)
    ladder = [(40, 40, 40, 10), (10, 10, 10, 10), (1, 2, 2, 10)]
    print(f"{'d_out':>18s} {'rho%':>6s} {'clashfree':>10s} "
          f"{'structured':>10s} {'random':>10s}")
    for d_out in ladder[:args.rows]:
        rho = rho_from_dout(MNIST_4J, d_out)
        accs = {}
        for method in ("clashfree", "structured", "random"):
            cfg = MLPConfig(n_net=MNIST_4J, rho=rho, method=method)
            model = SparseMLP(cfg)
            _, acc = train_mlp(model, data, epochs=args.epochs)
            accs[method] = acc
        rho_net = SparseMLP(MLPConfig(n_net=MNIST_4J, rho=rho)).density()
        print(f"{str(d_out):>18s} {100 * rho_net:6.1f} "
              f"{accs['clashfree']:10.3f} {accs['structured']:10.3f} "
              f"{accs['random']:10.3f}")


if __name__ == "__main__":
    main()
