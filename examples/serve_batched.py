"""End-to-end driver #3: batched serving (prefill + decode loop).

Loads a smoke-scale assigned architecture, prefills a batch of prompts and
decodes continuations with greedy/sampled decoding through the production
decode path (KV caches, single-token steps).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-4b \
        [--batch 4 --prompt-len 32 --gen 24 --sample]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.nn import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    extra = None
    if cfg.input_mode == "embeddings" or cfg.enc_dec is not None:
        extra = {"embeds": jnp.asarray(
            rng.normal(size=(args.batch, args.prompt_len,
                             cfg.frontend_dim)), jnp.float32)}

    toks, tps = generate(model, params, prompt,
                         s_max=args.prompt_len + args.gen,
                         steps=args.gen, greedy=not args.sample,
                         key=jax.random.key(1), extra_batch=extra)
    print(f"{args.arch}: generated {toks.shape[1]} tokens x "
          f"{toks.shape[0]} sequences at {tps:.1f} tok/s")
    for i in range(min(2, args.batch)):
        print(f"  seq{i}: {np.asarray(toks[i])[:16]} ...")


if __name__ == "__main__":
    main()
