"""End-to-end driver #3: continuous-batching serving.

Loads a smoke-scale assigned architecture and serves a batch of
*mixed-length* prompts through ``repro.serving.ServingEngine``: chunked
prefill interleaves with decode under a per-step token budget, KV lives in
a paged cache, and short requests finish (and free their pages) while long
ones are still decoding — no head-of-line blocking on the longest prompt.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-4b \
        [--batch 4 --prompt-len 32 --gen 24 --sample]

``--no-engine`` runs the legacy monolithic prefill + dense-cache decode
loop instead (same-length prompts only) for an A/B comparison.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate_cached
from repro.nn import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="longest prompt; engine mode mixes lengths "
                         "down to prompt-len/4")
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--no-engine", action="store_true",
                    help="legacy dense-cache loop (A/B baseline)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--token-budget", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    legacy_only = (cfg.input_mode == "embeddings" or cfg.enc_dec is not None
                   or (cfg.moe is not None and cfg.moe.capacity_factor
                       * cfg.moe.top_k < cfg.moe.n_routed))
    if args.no_engine or legacy_only:
        if legacy_only and not args.no_engine:
            print(f"{args.arch}: stub-frontend/enc-dec/capacity-"
                  f"constrained MoE — legacy path")
        prompt = jnp.asarray(rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
        extra = None
        if legacy_only:
            extra = {"embeds": jnp.asarray(
                rng.normal(size=(args.batch, args.prompt_len,
                                 cfg.frontend_dim)), jnp.float32)}
        toks, tps = generate_cached(
            model, params, prompt, s_max=args.prompt_len + args.gen,
            steps=args.gen, greedy=not args.sample,
            key=jax.random.key(1), extra_batch=extra)
        print(f"{args.arch} [legacy]: {toks.shape[1]} tokens x "
              f"{toks.shape[0]} sequences at {tps:.1f} tok/s")
        for i in range(min(2, args.batch)):
            print(f"  seq{i}: {np.asarray(toks[i])[:16]} ...")
        return

    from repro.serving import EngineConfig, ServingEngine

    # mixed prompt lengths: the whole point of continuous batching
    lens = [max(4, args.prompt_len * (i % 4 + 1) // 4)
            for i in range(args.batch)]
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n in lens]
    pages_per_seq = -(-(max(lens) + args.gen) // args.page_size)
    eng = ServingEngine(
        model, params,
        EngineConfig(max_slots=min(args.batch, 8),
                     page_size=args.page_size,
                     total_pages=args.batch * pages_per_seq,
                     max_pages_per_seq=pages_per_seq,
                     token_budget=args.token_budget,
                     prefill_chunk=32, greedy=not args.sample),
        key=jax.random.key(1))
    t0 = time.time()
    outs = eng.run(prompts, args.gen)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"{args.arch} [engine]: {n_tok} tokens over {args.batch} "
          f"requests (prompt lens {lens}) at {n_tok / dt:.1f} tok/s; "
          f"stats={eng.sched.stats}")
    cnt, tot = eng.obs.histogram("serving_ttft_seconds").stats()
    if cnt:
        print(f"  mean time-to-first-token: {1e3 * tot / cnt:.1f} ms")
    for i in range(min(2, args.batch)):
        print(f"  req{i} (len {lens[i]}): {outs[i][:16]} ...")


if __name__ == "__main__":
    main()
