"""End-to-end driver #2: pretrain a ~100M-param LM with pre-defined sparse
FFN junctions for a few hundred steps on synthetic bigram data.

The paper's technique applied at LM scale: every FFN junction is a
block-circulant clash-free sparse matrix (rho_up=0.5, rho_down=0.75); the
trainer is the full production path (AdamW, grad clip, cosine schedule,
checkpointing, grad accumulation).

    PYTHONPATH=src python examples/sparse_llm_pretrain.py \
        [--steps 300] [--dense] [--size full100m|small]
"""
import argparse
import time

from repro.data import BigramLM
from repro.nn import ModelConfig, SparsityConfig, build_model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def make_config(size: str, dense: bool) -> ModelConfig:
    sp = SparsityConfig(enabled=not dense, rho_ffn=(0.5, 0.75),
                        block_in=64, block_out=64)
    if size == "full100m":
        # ~100M params: 12L x d512 x ffn2048, 32k vocab
        return ModelConfig(
            name="sparse-llm-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, d_ff=2048, vocab_size=32768, sparsity=sp,
            attn_chunk=128, loss_chunk=256, dtype="float32", remat=False)
    return ModelConfig(
        name="sparse-llm-small", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab_size=2048, sparsity=sp,
        attn_chunk=64, loss_chunk=128, dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--size", default="small",
                    choices=["small", "full100m"])
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = make_config(args.size, args.dense)
    model = build_model(cfg)
    n = sum(x.size for x in __import__("jax").tree.leaves(
        model.init(__import__("jax").random.key(0))))
    ffn_w = sum(l.n_params for blk_kind in [] for l in [])  # shown below
    print(f"model: {cfg.name}  params={n / 1e6:.1f}M  "
          f"sparsity={'off' if args.dense else cfg.sparsity.rho_ffn}")

    tc = TrainerConfig(
        opt=AdamWConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps, weight_decay=0.05),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=max(args.steps // 4, 1),
        log_every=max(args.steps // 20, 1))
    trainer = Trainer(model, tc)
    data = BigramLM(vocab_size=cfg.vocab_size, branching=8, noise=0.05,
                    seed=0)
    t0 = time.time()
    _, _, hist = trainer.fit(
        data.iterate(args.batch, args.seq), steps=args.steps,
        on_step=lambda s, m: print(
            f"step {s:5d}  loss {m['loss']:.4f}  "
            f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}", flush=True))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({toks / dt:.0f} tok/s on this host)")


if __name__ == "__main__":
    main()
