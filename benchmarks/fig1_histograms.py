"""Paper Fig. 1 — trained FC weight histograms motivate sparsity, and
accuracy vs overall density (sparsifying junction 1 first).

Reported: the fraction of near-zero weights per junction after FC training
(the paper's visual claim: junction 1 has far more near-zero weights than
junction 2 — that is why early junctions tolerate sparsity), and the
accuracy-vs-density curve of Fig. 1(c).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.paper_mlp import MNIST_2J, rho_from_dout
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp

from .common import emit, mnist_like


def run(epochs: int = 12, full: bool = False):
    data = mnist_like()
    model = SparseMLP(MLPConfig(n_net=MNIST_2J))
    params, acc = train_mlp(model, data, epochs=epochs, seed=0)
    emit("fig1/fc_test_acc", 0.0, round(acc, 4))

    fracs = []
    for i in range(2):
        w = np.asarray(params[f"j{i}"]["w"]).reshape(-1)
        thresh = 0.02 * np.abs(w).max()
        fracs.append(float((np.abs(w) < thresh).mean()))
        emit(f"fig1/junction{i + 1}_near_zero_frac", 0.0,
             round(fracs[-1], 4))
    # the motivating observation: junction 1 is much more sparsifiable
    emit("fig1/j1_over_j2_near_zero_ratio", 0.0,
         round(fracs[0] / max(fracs[1], 1e-6), 2))

    # Fig 1(c): accuracy vs density, thinning junction 1 first
    douts = [(50, 10), (20, 10), (10, 10), (5, 10)] if not full else \
        [(80, 10), (50, 10), (20, 10), (10, 10), (5, 10), (2, 10)]
    for d_out in douts:
        rho = rho_from_dout(MNIST_2J, d_out)
        cfg = MLPConfig(n_net=MNIST_2J, rho=rho, method="clashfree")
        m = SparseMLP(cfg)
        _, a = train_mlp(m, data, epochs=epochs, seed=0)
        emit(f"fig1c/rho{m.density() * 100:.1f}_acc", 0.0, round(a, 4))
