"""Paper Fig. 12 / §V — clash-free pre-defined sparsity vs less-constrained
sparsification: LSS (learned structured sparsity: L1-penalty training +
magnitude threshold) and attention-based preprocessed sparsity (input-
variance-driven out-degrees).

Paper's claim: LSS (which trains at FC cost) is best, attention-based is
close, and clash-free pre-defined sparsity — the only one that is cheap at
TRAINING time — lands within ~2% at moderate density.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparsity as S
from repro.configs.paper_mlp import MNIST_2J
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp

from .common import emit, mnist_like


def _mask_from_attention(x_train, n_net, rho, seed=0):
    """Variance-quantized out-degree allocation (paper §V-A), junction 1;
    uniform degrees elsewhere. Returns per-junction masks."""
    rng = np.random.default_rng(seed)
    n0, n1 = n_net[0], n_net[1]
    var = x_train[:2000].var(axis=0)
    # quantize variances into 3 attention levels with weights 3:2:1
    q = np.quantile(var, [1 / 3, 2 / 3])
    level = np.digitize(var, q)  # 0,1,2
    w = np.array([1.0, 2.0, 3.0])[level]
    target_edges = int(rho * n0 * n1)
    deg = np.maximum(1, np.round(w / w.sum() * target_edges)).astype(int)
    deg = np.minimum(deg, n1)
    mask = np.zeros((n0, n1), np.float32)
    for i in range(n0):
        cols = rng.choice(n1, size=deg[i], replace=False)
        mask[i, cols] = 1.0
    return mask


def _train_masked(data, n_net, mask1, epochs, l2=1e-4, seed=0,
                  l1=0.0, lr=1e-3):
    """Train a 2-junction MLP with a fixed mask on junction 1 (mask=None ->
    FC) and optional L1 penalty (for LSS). Returns (params, test_acc)."""
    x_tr, y_tr, x_te, y_te = data
    rng = np.random.default_rng(seed)
    k = jax.random.split(jax.random.key(seed), 4)
    w1 = jax.random.normal(k[0], n_net[:2]) * np.sqrt(2.0 / n_net[0])
    w2 = jax.random.normal(k[1], n_net[1:]) * np.sqrt(2.0 / n_net[1])
    params = {"w1": w1, "b1": jnp.full(n_net[1], 0.1),
              "w2": w2, "b2": jnp.full(n_net[2], 0.1)}
    m1 = jnp.asarray(mask1) if mask1 is not None else None

    def logits(p, x):
        w1 = p["w1"] * m1 if m1 is not None else p["w1"]
        h = jax.nn.relu(x @ w1 + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss(p, x, y):
        lp = jax.nn.log_softmax(logits(p, x))
        nll = -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        reg = l2 * (jnp.sum(p["w1"] ** 2) + jnp.sum(p["w2"] ** 2))
        if l1:
            reg = reg + l1 * (jnp.sum(jnp.abs(p["w1"]))
                              + jnp.sum(jnp.abs(p["w2"])))
        return nll + reg

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(p, m, v, x, y, t):
        g = jax.grad(loss)(p, x, y)
        b1c, b2c = 0.9, 0.999
        m = jax.tree.map(lambda a, b: b1c * a + (1 - b1c) * b, m, g)
        v = jax.tree.map(lambda a, b: b2c * a + (1 - b2c) * b * b, v, g)
        t1 = t + 1

        def upd(pp, mm, vv):
            mh = mm / (1 - b1c ** t1)
            vh = vv / (1 - b2c ** t1)
            return pp - lr * mh / (jnp.sqrt(vh) + 1e-8)
        return jax.tree.map(upd, p, m, v), m, v

    n = x_tr.shape[0]
    t = 0.0
    for _ in range(epochs):
        order = rng.permutation(n)
        for s0 in range(0, n - 255, 256):
            idx = order[s0:s0 + 256]
            params, m, v = step(params, m, v, jnp.asarray(x_tr[idx]),
                                jnp.asarray(y_tr[idx]), t)
            t += 1

    def acc(p):
        pred = jnp.argmax(logits(p, jnp.asarray(x_te)), -1)
        return float((pred == jnp.asarray(y_te)).mean())

    return params, acc(params)


def run(epochs: int = 10, rho: float = 0.2):
    data = mnist_like()
    n_net = MNIST_2J

    # (a) clash-free pre-defined (junction 1 sparse at rho, j2 dense)
    cfg = MLPConfig(n_net=n_net, rho=(rho, 1.0), method="clashfree")
    _, acc_cf = train_mlp(SparseMLP(cfg), data, epochs=epochs, seed=0)
    emit("fig12/clashfree", 0.0, round(acc_cf, 4))

    # (b) attention-based preprocessed sparsity
    mask1 = _mask_from_attention(data[0], n_net, rho)
    _, acc_attn = _train_masked(data, n_net, mask1, epochs)
    emit("fig12/attention_based", 0.0, round(acc_attn, 4))

    # (c) LSS: train FC with L1, threshold junction 1 to rho, brief finetune
    p_lss, _ = _train_masked(data, n_net, None, epochs, l1=1e-5)
    w1 = np.asarray(p_lss["w1"])
    k = int((1 - rho) * w1.size)
    thresh = np.partition(np.abs(w1).reshape(-1), k)[k]
    mask_lss = (np.abs(w1) >= thresh).astype(np.float32)
    _, acc_lss = _train_masked(data, n_net, mask_lss, max(2, epochs // 3))
    emit("fig12/lss", 0.0, round(acc_lss, 4))

    emit("fig12/clashfree_minus_lss", 0.0, round(acc_cf - acc_lss, 4))
    emit("fig12/clashfree_minus_attn", 0.0, round(acc_cf - acc_attn, 4))
