"""Paper Figs. 9-11 — 'large and sparse' beats 'small and dense'.

Trend 4: at an equal trainable-parameter budget, a wider hidden layer with
pre-defined sparsity outperforms a narrower dense one — until individual
junction densities cross the critical density. Reproduced with matched
budgets on the synthetic MNIST stand-in, (800, x, 10) nets.
"""
from __future__ import annotations

import numpy as np

from repro.core import degrees_for_density
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp

from .common import emit, mnist_like


def run(epochs: int = 10, seeds: int = 2):
    data = mnist_like()
    # budget chosen = params of (800, 25, 10) FC ~ 20.25k weights
    configs = [
        ("dense_x25", (800, 25, 10), None),
        # x=100: junction1 rho=24% -> ~19.2k+1k weights (same budget)
        ("sparse_x100", (800, 100, 10), (0.24, 1.0)),
        # x=200: junction1 rho=11.5% -> ~18.4k+2k
        ("sparse_x200", (800, 200, 10), (0.115, 1.0)),
        # x=400: rho=4.6% -> at/below critical density territory
        ("sparse_x400", (800, 400, 10), (0.046, 1.0)),
    ]
    results = {}
    for name, n_net, rho in configs:
        accs = []
        m = SparseMLP(MLPConfig(n_net=n_net, rho=rho, method="clashfree"))
        for s in range(seeds):
            cfg = MLPConfig(n_net=n_net, rho=rho, method="clashfree",
                            seed=s)
            _, acc = train_mlp(SparseMLP(cfg), data, epochs=epochs, seed=s)
            accs.append(acc)
        results[name] = float(np.mean(accs))
        emit(f"fig9/{name}/weights{m.n_weights()}", 0.0,
             round(results[name], 4))
    emit("fig9/large_sparse_minus_small_dense", 0.0,
         round(results["sparse_x100"] - results["dense_x25"], 4))
