"""Paper Table II — clash-free vs structured vs random pre-defined sparsity.

The paper's claim (trend 1): hardware-friendly clash-free patterns match
structured and random patterns at every density, and random degrades at very
low density (disconnected neurons). Reproduced on the synthetic MNIST
stand-in with the paper's 4-junction net, across the Table II density ladder.
"""
from __future__ import annotations

import numpy as np

from repro.configs.paper_mlp import MNIST_4J, rho_from_dout
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp

from .common import emit, mnist_like

# a representative subset of the Table II rows (full ladder with --full)
ROWS_FAST = [(40, 40, 40, 10), (10, 10, 10, 10), (1, 2, 2, 10)]
ROWS_FULL = [(80, 80, 80, 10), (60, 60, 60, 10), (40, 40, 40, 10),
             (20, 20, 20, 10), (10, 10, 10, 10), (5, 10, 10, 10),
             (2, 5, 5, 10), (1, 2, 2, 10)]


def run(full: bool = False, epochs: int = 10, seeds: int = 2):
    data = mnist_like()
    rows = ROWS_FULL if full else ROWS_FAST
    for d_out in rows:
        rho = rho_from_dout(MNIST_4J, d_out)
        rho_net = sum(d * MNIST_4J[i] for i, d in enumerate(d_out)) / \
            sum(MNIST_4J[i] * MNIST_4J[i + 1]
                for i in range(len(MNIST_4J) - 1))
        for method in ("clashfree", "structured", "random"):
            accs = []
            for seed in range(seeds):
                cfg = MLPConfig(n_net=MNIST_4J, rho=rho, method=method,
                                seed=seed)
                _, acc = train_mlp(SparseMLP(cfg), data, epochs=epochs,
                                   seed=seed)
                accs.append(acc)
            emit(f"table2/rho{rho_net * 100:.1f}/{method}", 0.0,
                 round(float(np.mean(accs)), 4))
