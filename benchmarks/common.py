"""Shared benchmark utilities: timing + CSV emission + datasets.

Every benchmark emits ``name,us_per_call,derived`` rows (the harness
contract): ``us_per_call`` is wall-time per jitted call where timing makes
sense (0 for pure-accuracy rows), ``derived`` is the paper-relevant quantity
(accuracy, storage words, ratio, ...).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable

from repro.obs import trace as obs_trace

ROWS = []


def emit(name: str, us_per_call: float, derived):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_call(fn: Callable, *args, iters: int = 10, warmup: int = 2,
              name: str = "call") -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays).

    Delegates to ``repro.obs.trace.timed_call``: each iteration is a
    ``bench/<name>`` span in the shared obs registry, so benchmark rows
    and live metrics read the same clock."""
    return obs_trace.timed_call(fn, *args, iters=iters, warmup=warmup,
                                name=name)


@lru_cache(maxsize=4)
def mnist_like(n_train=6000, n_test=1500, seed=0, n_features=None):
    from repro.data import synthetic_mnist
    return synthetic_mnist(n_train=n_train, n_test=n_test, seed=seed,
                           n_features=n_features)


@lru_cache(maxsize=2)
def reuters_like(n_train=6000, n_test=1500, seed=0, redundancy=8):
    from repro.data import synthetic_features
    return synthetic_features(n_train=n_train, n_test=n_test, seed=seed,
                              n_classes=50, n_features=2000,
                              redundancy=redundancy)
