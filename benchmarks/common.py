"""Shared benchmark utilities: timing + CSV emission + datasets.

Every benchmark emits ``name,us_per_call,derived`` rows (the harness
contract): ``us_per_call`` is wall-time per jitted call where timing makes
sense (0 for pure-accuracy rows), ``derived`` is the paper-relevant quantity
(accuracy, storage words, ratio, ...).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable

from repro.obs import trace as obs_trace

ROWS = []


def emit(name: str, us_per_call: float, derived):
    """Record one benchmark row.

    ``us_per_call`` is kept as a NUMBER and ``derived`` as a structured
    object (a dict of numeric/string fields; a bare scalar is wrapped as
    ``{"value": v}``, ``""``/``None`` as ``{}``) so BENCH_*.json artifacts
    diff numerically across PRs — the PR-9 files emitted both as strings.
    The printed CSV contract (``name,us_per_call,derived``) is unchanged.
    """
    if derived is None or (isinstance(derived, str) and not derived):
        derived = {}
    elif not isinstance(derived, dict):
        derived = {"value": derived}
    row = {"name": name, "us_per_call": round(float(us_per_call), 2),
           "derived": derived}
    ROWS.append(row)
    if list(derived) == ["value"]:
        dstr = str(derived["value"])
    else:
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{row['us_per_call']:.2f},{dstr}", flush=True)


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2,
              repeats: int = 3, name: str = "call") -> float:
    """Best-of-``repeats`` median wall-time per call in microseconds
    (blocks on jax arrays).

    Delegates to ``repro.obs.trace.timed_call`` — the same measurement
    core the autotuner uses, so tuning decisions and benchmark rows read
    one clock. Defaults changed in PR 10: 3 rounds x 5-iteration medians
    (best-of-k absorbs background-load noise the old single 10-iteration
    median leaked into BENCH rows)."""
    return obs_trace.timed_call(fn, *args, iters=iters, warmup=warmup,
                                repeats=repeats, name=name)


@lru_cache(maxsize=4)
def mnist_like(n_train=6000, n_test=1500, seed=0, n_features=None):
    from repro.data import synthetic_mnist
    return synthetic_mnist(n_train=n_train, n_test=n_test, seed=seed,
                           n_features=n_features)


@lru_cache(maxsize=2)
def reuters_like(n_train=6000, n_test=1500, seed=0, redundancy=8):
    from repro.data import synthetic_features
    return synthetic_features(n_train=n_train, n_test=n_test, seed=seed,
                              n_classes=50, n_features=2000,
                              redundancy=redundancy)
