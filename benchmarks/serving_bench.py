"""Serving benchmark: the continuous-batching engine vs recompute/cached.

Three generation strategies over the same smoke-scale model and prompts:

* **recompute** — the naive baseline: every emitted token re-runs the full
  forward pass over a fixed-size padded buffer (O(S) work per token, one
  compile). This is also the parity oracle for the engine's paged decode.
* **cached**    — the legacy monolithic prefill + dense-cache decode loop
  (the ``launch.serve.generate_cached`` algorithm, jitted functions
  hoisted here so the timed call runs warm).
* **engine**    — ``ServingEngine``: paged KV cache, chunked prefill
  interleaved with batched decode, one token per running request per step.

A fourth section benchmarks **speculative decode** (prompt-lookup drafts,
``spec_k=4``) against the plain engine on a repetitive-prompt workload,
reporting draft acceptance rate and the tokens/sec multiplier — the
acceptance bar there is a throughput win (> 1x) plus the engine's >= 2x
over recompute.

Reported per density (the paper's junction-density sweep applied to the
serving stack): tokens/sec, time-to-first-token, and the engine's speedup
over recompute — the acceptance bar is >= 2x at batch >= 4 on CPU/XLA.

``--quick`` runs one density at tiny shapes and writes a JSON artifact for
CI trend tracking (``--json path``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn import build_model
from repro.obs import trace as obs_trace
from repro.serving import EngineConfig, ServingEngine

from .common import emit


def make_recompute(model, params):
    """Build a full-recompute greedy generator with its jitted functions
    hoisted, so a warmup call actually warms the timed call (a fresh
    ``jax.jit`` wrapper per call would re-trace every time and the
    baseline would be measured compile-dominated)."""
    fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
    logits_at = jax.jit(
        lambda p, h, n: model.logits_fn(
            p, jax.lax.dynamic_slice_in_dim(h, n - 1, 1, axis=1)))

    def run(prompts: np.ndarray, steps: int):
        """Returns (tokens (B, steps), tokens/sec, ttft seconds)."""
        b, prompt_len = prompts.shape
        buf = np.zeros((b, prompt_len + steps), np.int32)
        buf[:, :prompt_len] = prompts
        out = np.zeros((b, steps), np.int32)
        t0 = time.perf_counter()
        ttft = None
        n = prompt_len
        for i in range(steps):
            h = fwd(params, jnp.asarray(buf))
            tok = np.asarray(jnp.argmax(logits_at(params, h, n), -1))[:, 0]
            if ttft is None:
                ttft = time.perf_counter() - t0
            out[:, i] = tok
            if n < buf.shape[1]:
                buf[:, n] = tok
            n += 1
        dt = time.perf_counter() - t0
        return out, b * steps / max(dt, 1e-9), ttft

    return run


def make_cached(model, params, s_max: int):
    """Dense-cache greedy generator (the legacy loop) with hoisted jits."""
    prefill = jax.jit(lambda p, b: model.prefill(p, b, s_max))
    step = jax.jit(model.decode_step, donate_argnums=(2,))

    def run(prompts: np.ndarray, steps: int):
        """Returns (tokens (B, steps), tokens/sec)."""
        t0 = time.perf_counter()
        logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [tok]
        for _ in range(steps - 1):
            logits, cache = step(params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        toks = jnp.concatenate(out, axis=1)
        toks.block_until_ready()
        dt = time.perf_counter() - t0
        return np.asarray(toks), prompts.shape[0] * steps / max(dt, 1e-9)

    return run


def make_engine(model, params, batch: int, max_len: int, page_size: int,
                token_budget: int, spec_k: int = 0,
                quant=None) -> ServingEngine:
    pages_per_seq = -(-max_len // page_size)
    return ServingEngine(
        model, params,
        EngineConfig(max_slots=min(batch, 8), page_size=page_size,
                     total_pages=batch * pages_per_seq,
                     max_pages_per_seq=pages_per_seq,
                     token_budget=token_budget, prefill_chunk=32,
                     spec_k=spec_k, quant=quant))


def spec_workload(rng, vocab: int, batch: int, prompt_len: int):
    """Maximally repetitive prompts — each a single token repeated — the
    drafter's target regime (code, templated docs and long generations
    stuck in an attractor all repeat their own n-grams; a constant prompt
    is the distilled version that also drives the smoke model into a
    repeating continuation, so draft acceptance is exercised rather than
    left to the luck of a random-weight trajectory)."""
    return [np.full(prompt_len, t, np.int32)
            for t in rng.integers(0, vocab, batch)]


def engine_generate(eng: ServingEngine, prompts, steps: int):
    """One engine run (the engine — and its compiled step — is reused
    across calls; warm up with a short run first).

    Timing and latency come from the engine's own obs registry — the
    benchmark reads the same counters/histograms the live ``/metrics``
    endpoint serves, instead of keeping a second set of clocks: the run
    is bracketed by a ``bench/engine_run`` span and TTFT is the delta of
    the ``serving_ttft_seconds`` histogram over the run.

    Returns (outputs, tokens/sec, mean ttft seconds, stats)."""
    reg = eng.obs
    emit_c = reg.counter("serving_emitted_tokens_total")
    ttft_h = reg.histogram("serving_ttft_seconds")
    n0 = emit_c.value()
    c0, s0 = ttft_h.stats()
    with obs_trace.span("bench/engine_run", registry=reg, reqs=len(prompts)):
        outs = eng.run(prompts, steps)
    durs = reg.span_durations("bench/engine_run")
    dt = durs[-1] if durs else 1e-9
    n_tok = emit_c.value() - n0
    c1, s1 = ttft_h.stats()
    ttft = (s1 - s0) / (c1 - c0) if c1 > c0 else 0.0
    return outs, n_tok / max(dt, 1e-9), ttft, dict(eng.sched.stats)


def _best_of(fn, reps: int):
    """Best-of-``reps`` whole-run measurement (result tuple with tokens/sec
    at index 1). The workloads are deterministic — identical tokens and
    step counts every rep — so the spread is pure host noise and max is
    the honest estimator (the same reasoning as ``timed_call``'s
    best-of-medians for per-call benches; whole-run throughput can't use
    per-iteration medians, so best-of-k is the run-level analogue)."""
    best = None
    for _ in range(max(1, reps)):
        r = fn()
        if best is None or r[1] > best[1]:
            best = r
    return best


NEAR_TIE_MARGIN = 0.05  # f32 top-2 logit gap below which a flip is a tie


def int8_top1_agreement(model, params, params_q, seqs, prompt_len: int,
                        page_size: int):
    """Teacher-forced top-1 agreement of the quantized paged path (int8
    weights + int8 KV) against the f32 paged path, position by position.

    Each sequence is prompt + the tokens the f32 engine emitted. Both
    models are fed the *f32* token history at every generated position —
    so a single flip costs one position, not the whole tail (free-running
    greedy decode compounds: one near-tie flip diverges the trajectory
    permanently, which on a random-weight smoke model measures tie
    density, not int8 fidelity).

    Returns ``(raw, gated, n_near_tie, n_tok)``:

    * ``raw``   — plain argmax-match fraction.
    * ``gated`` — the CI metric: flips at positions where the f32 top-2
      logit margin is below ``NEAR_TIE_MARGIN`` are excused (int8 noise
      perturbs logits by ~the per-block scale; flipping a coin-flip
      decision is expected and harmless). A flip at a *confident*
      position means quantization moved a logit by more than the scale
      bound — a real defect (e.g. mis-indexed block scales) — and fails
      the >= 99% gate.
    """
    from repro.nn.common import dtype_of
    from repro.serving import kv_cache

    dt = dtype_of(model.cfg)
    n_same = n_tie = n_tok = 0
    for seq in seqs:
        toks = np.asarray(seq, np.int32)
        total = -(-len(toks) // page_size)
        st_ = kv_cache.init_page_state(1, total, total)
        st_ = kv_cache.alloc_pages(st_, 0, total)
        caches = [model.stack.init_paged_cache(1, total, page_size, dt),
                  model.stack.init_paged_cache(1, total, page_size, dt,
                                               quant_kv=True)]

        def step(p, chunk, pos, cache):
            return model.paged_step(
                p, jnp.asarray(chunk[None]),
                jnp.asarray([pos], jnp.int32),
                jnp.asarray([len(chunk)], jnp.int32),
                cache, st_.page_table, jnp.asarray([0], jnp.int32),
                backend="auto")

        l32, caches[0] = step(params, toks[:prompt_len], 0, caches[0])
        l8, caches[1] = step(params_q, toks[:prompt_len], 0, caches[1])
        for i in range(prompt_len, len(toks)):
            lo32 = np.asarray(l32[0, -1])
            a32, a8 = int(lo32.argmax()), int(jnp.argmax(l8[0, -1]))
            if a32 == a8:
                n_same += 1
            else:
                top2 = np.sort(lo32)[-2:]
                n_tie += int(top2[1] - top2[0] < NEAR_TIE_MARGIN)
            n_tok += 1
            l32, caches[0] = step(params, toks[i:i + 1], i, caches[0])
            l8, caches[1] = step(params_q, toks[i:i + 1], i, caches[1])
    raw = n_same / max(n_tok, 1)
    gated = (n_same + n_tie) / max(n_tok, 1)
    return raw, gated, n_tie, n_tok


def run(arch: str = "qwen2-7b", batch: int = 4, prompt_len: int = 32,
        steps: int = 32, page_size: int = 16, quick: bool = False,
        densities=None) -> dict:
    if quick:
        batch, prompt_len, steps = 4, 16, 8
    base = get_config(arch, smoke=True)
    if densities is None:
        # default = the config's own junction setup (sparse for most
        # archs); "dense" isolates what pre-defined sparsity costs in the
        # skinny-M decode regime; the tuple sweeps a lower density
        densities = [None] if quick else [None, "dense", (0.25, 0.5)]

    rng = np.random.default_rng(0)
    results = {"arch": arch, "batch": batch, "prompt_len": prompt_len,
               "steps": steps, "page_size": page_size, "rows": []}
    for rho in densities:
        if rho is None:
            cfg = base            # the config's own (usually sparse) FFN
            tag = "default"
        elif rho == "dense":
            cfg = base.with_(sparsity=dataclasses.replace(
                base.sparsity, enabled=False))
            tag = "dense"
        else:
            cfg = base.with_(sparsity=dataclasses.replace(
                base.sparsity, enabled=True, rho_ffn=rho))
            tag = f"rho{rho[0]}"
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        prompts_same = rng.integers(
            0, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)
        mixed = [rng.integers(0, cfg.vocab_size,
                              (max(4, prompt_len * (i % 4 + 1) // 4),)
                              ).astype(np.int32) for i in range(batch)]

        # warmup all three paths (compile time excluded from rates)
        recompute = make_recompute(model, params)
        cached = make_cached(model, params, prompt_len + steps)
        recompute(prompts_same, 2)
        _, r_tps, r_ttft = _best_of(
            lambda: recompute(prompts_same, steps), 2)
        cached(prompts_same, 2)
        _, c_tps = _best_of(lambda: cached(prompts_same, steps), 2)
        eng = make_engine(model, params, batch, prompt_len + steps,
                          page_size, token_budget=batch + prompt_len)
        engine_generate(eng, list(prompts_same), 2)
        outs_f32, e_tps, e_ttft, stats = _best_of(
            lambda: engine_generate(eng, list(prompts_same), steps), 3)
        _, m_tps, m_ttft, _ = engine_generate(eng, mixed, steps)

        speedup = e_tps / max(r_tps, 1e-9)
        row = {"density": tag, "dtype": "float32",
               "recompute_tps": round(r_tps, 1),
               "recompute_ttft_ms": round(1e3 * r_ttft, 1),
               "cached_tps": round(c_tps, 1),
               "engine_tps": round(e_tps, 1),
               "engine_ttft_ms": round(1e3 * e_ttft, 1),
               "engine_mixed_tps": round(m_tps, 1),
               "engine_mixed_ttft_ms": round(1e3 * m_ttft, 1),
               "speedup_vs_recompute": round(speedup, 2),
               "stats": stats}
        results["rows"].append(row)

        # int8 engine (PR 9): same model + prompts through a weight- and
        # KV-quantized engine — decode is bandwidth-bound, so the 4x
        # smaller slabs/pages are the win. Agreement numbers:
        # * top1_agreement_vs_f32 (the >= 99% CI gate): teacher-forced
        #   with near-tie flips excused — see int8_top1_agreement.
        # * top1_agreement_raw: the same without the excusal.
        # * free_running_agreement (informational): token match of two
        #   independent greedy runs. On a RANDOM-weight smoke model a
        #   single near-tie argmax flip cascades into divergence of the
        #   whole tail, so this number reflects the model's tie density
        #   more than int8 quality — do not gate on it.
        if cfg.sparsity.enabled:
            from repro.core.quant import QuantConfig
            engq = make_engine(model, params, batch, prompt_len + steps,
                               page_size, token_budget=batch + prompt_len,
                               quant=QuantConfig())
            engine_generate(engq, list(prompts_same), 2)
            outs_q, q_tps, q_ttft, _ = _best_of(
                lambda: engine_generate(engq, list(prompts_same), steps),
                3)
            n_tok = sum(len(a) for a in outs_f32)
            n_same = sum(int((np.asarray(a) == np.asarray(b)).sum())
                         for a, b in zip(outs_f32, outs_q))
            free = n_same / max(n_tok, 1)
            seqs = [np.concatenate([p, np.asarray(o, np.int32)])
                    for p, o in zip(prompts_same, outs_f32)]
            raw, top1, n_tie, n_tok_tf = int8_top1_agreement(
                engq.model, params, engq.params, seqs, prompt_len,
                page_size)
            results["rows"].append({
                "density": tag, "dtype": "int8",
                "engine_tps": round(q_tps, 1),
                "engine_ttft_ms": round(1e3 * q_ttft, 1),
                "top1_agreement_vs_f32": round(top1, 4),
                "top1_agreement_raw": round(raw, 4),
                "near_tie_flips": n_tie,
                "free_running_agreement": round(free, 4),
                "speedup_vs_f32_engine": round(
                    q_tps / max(e_tps, 1e-9), 2)})
            emit(f"serving/{arch}_{tag}_engine_tps_int8", 0.0,
                 round(q_tps, 1))
            emit(f"serving/{arch}_{tag}_engine_ttft_ms_int8", 0.0,
                 round(1e3 * q_ttft, 1))
            emit(f"serving/{arch}_{tag}_int8_top1_agreement", 0.0,
                 round(top1, 4))
            emit(f"serving/{arch}_{tag}_int8_free_running_agreement",
                 0.0, round(free, 4))

        if tag == "default":
            # speculative decode: repetitive-prompt workload in a
            # decode-dominated regime (generation length >= 48 even under
            # --quick: with short generations prefill amortisation hides
            # what speculation changes), spec_k=4 drafter vs a plain
            # engine with identical shapes and budget
            sp_gen, sp_prompt = max(steps, 48), 16
            sp = spec_workload(rng, cfg.vocab_size, batch, sp_prompt)
            ebase = make_engine(model, params, batch, sp_prompt + sp_gen,
                                page_size,
                                token_budget=batch + sp_prompt)
            engk = make_engine(model, params, batch, sp_prompt + sp_gen,
                               page_size, token_budget=batch + sp_prompt,
                               spec_k=4)
            # full-length warmups: a short warmup misses the rollback
            # (truncate) code path and its compiles land in the timed run.
            # Timed runs are best-of-3 — the workload is deterministic
            # (identical tokens and step counts every rep), so the spread
            # is pure host noise and max is the honest estimator.
            engine_generate(ebase, sp, sp_gen)
            s0 = dict(ebase.sched.stats)       # stats are cumulative
            base_tps = 0.0
            for _ in range(3):
                _, tps_i, _, bst = engine_generate(ebase, sp, sp_gen)
                base_tps = max(base_tps, tps_i)
            if engk.spec_k > 0:
                engine_generate(engk, sp, sp_gen)
                k0 = dict(engk.sched.stats)
                spec_tps = 0.0
                for _ in range(3):
                    _, tps_i, _, st = engine_generate(engk, sp, sp_gen)
                    spec_tps = max(spec_tps, tps_i)
                reps = 3
                drafted = (st["spec_drafted"] - k0["spec_drafted"]) // reps
                accepted = (st["spec_accepted"]
                            - k0["spec_accepted"]) // reps
                acc = accepted / max(drafted, 1)
                results["spec"] = {
                    "spec_k": engk.spec_k,
                    "acceptance_rate": round(acc, 3),
                    "drafted": drafted,
                    "accepted": accepted,
                    "base_tps": round(base_tps, 1),
                    "spec_tps": round(spec_tps, 1),
                    "speedup_vs_base": round(
                        spec_tps / max(base_tps, 1e-9), 2),
                    "steps_base": (bst["steps"] - s0["steps"]) // reps,
                    "steps_spec": (st["steps"] - k0["steps"]) // reps}
                emit(f"serving/{arch}_spec_acceptance", 0.0,
                     round(acc, 3))
                emit(f"serving/{arch}_spec_tps", 0.0, round(spec_tps, 1))
                emit(f"serving/{arch}_spec_speedup", 0.0,
                     round(spec_tps / max(base_tps, 1e-9), 2))
            else:
                # recurrent stack: the engine clamps spec_k to 0
                results["spec"] = {"spec_k": 0, "clamped": True}

        emit(f"serving/{arch}_{tag}_recompute_tps", 0.0, round(r_tps, 1))
        emit(f"serving/{arch}_{tag}_cached_tps", 0.0, round(c_tps, 1))
        emit(f"serving/{arch}_{tag}_engine_tps", 0.0, round(e_tps, 1))
        emit(f"serving/{arch}_{tag}_engine_ttft_ms", 0.0,
             round(1e3 * e_ttft, 1))
        emit(f"serving/{arch}_{tag}_speedup_vs_recompute", 0.0,
             round(speedup, 2))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write results to this JSON file (CI artifact)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the bench here")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append obs registry events to this JSONL file")
    args = ap.parse_args()
    if args.metrics_jsonl:
        from repro.obs import get_registry
        get_registry().set_jsonl(args.metrics_jsonl)
    with obs_trace.profile_trace(args.profile_dir):
        res = run(arch=args.arch, batch=args.batch,
                  prompt_len=args.prompt_len, steps=args.gen,
                  page_size=args.page_size, quick=args.quick)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"wrote {args.json}")
    # acceptance gate on the default row (the config's own junction
    # setup — what CI tracks); other rows are informational sweeps
    ok = res["rows"][0]["speedup_vs_recompute"] >= 2.0
    print(f"engine >= 2x recompute at batch={res['batch']} "
          f"(default density): {'PASS' if ok else 'FAIL'}")
    for r in res["rows"]:
        if r.get("dtype") != "int8":
            continue
        q_ok = r["top1_agreement_vs_f32"] >= 0.99
        print(f"int8 engine ({r['density']}): "
              f"{r['top1_agreement_vs_f32']:.1%} teacher-forced top-1 "
              f"agreement vs f32 ({r['near_tie_flips']} near-tie flips "
              f"excused, raw {r['top1_agreement_raw']:.1%}, "
              f"free-running {r['free_running_agreement']:.1%}), "
              f"{r['engine_tps']} tok/s: "
              f"{'PASS' if q_ok else 'FAIL'}")
        ok = ok and q_ok
    sp = res.get("spec", {})
    if sp.get("spec_k"):
        spec_ok = sp["speedup_vs_base"] > 1.0
        print(f"spec decode (k={sp['spec_k']}): acceptance "
              f"{sp['acceptance_rate']:.1%}, {sp['spec_tps']} tok/s vs "
              f"{sp['base_tps']} base "
              f"({sp['speedup_vs_base']:.2f}x, steps "
              f"{sp['steps_spec']} vs {sp['steps_base']}): "
              f"{'PASS' if spec_ok else 'FAIL'}")
        ok = ok and spec_ok
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
