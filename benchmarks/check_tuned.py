"""CI gate over the ``*_tuned`` rows in a BENCH_PR10.json artifact.

    PYTHONPATH=src python -m benchmarks.check_tuned BENCH_PR10.json

Checks (exit 1 on any failure):

* every row whose ``derived`` carries a ``tuned_speedup`` must satisfy
  ``tuned_speedup >= 1 - TOL`` — the measured-auto dispatch is never
  allowed to lose to the static heuristic it replaces (ties land at
  exactly 1.0 by construction: when the winner is the default
  configuration the default timing is reused);
* ``kernel/csd_spmm_rho0.5_tuned``: ``speedup_vs_dense >= 0.9`` — the
  rho=0.5 regime, where both sparse dataflows lose to one GEMM, must
  recover to ~dense parity via the dense-ref escape hatch;
* ``kernel/csd_decode_m2_rho0.25_tuned``: ``speedup_vs_dense >= 1.0`` —
  the M=2 decode cliff (gather pathology) must no longer lose to dense.

``TOL`` absorbs residual best-of-k measurement noise on genuinely
re-measured (non-tie) rows; the named gates are the ISSUE's acceptance
bars and carry their own thresholds.
"""
from __future__ import annotations

import json
import sys

TOL = 0.05

# name -> (derived field, minimum) — the ISSUE acceptance bars
NAMED_GATES = {
    "kernel/csd_spmm_rho0.5_tuned": ("speedup_vs_dense", 0.9),
    "kernel/csd_decode_m2_rho0.25_tuned": ("speedup_vs_dense", 1.0),
}


def check(rows: list) -> list:
    failures = []
    tuned = {r["name"]: r for r in rows
             if isinstance(r.get("derived"), dict)
             and "tuned_speedup" in r["derived"]}
    if not tuned:
        return ["no *_tuned rows found (tuning did not run?)"]
    for name, row in sorted(tuned.items()):
        sp = float(row["derived"]["tuned_speedup"])
        if sp < 1.0 - TOL:
            failures.append(
                f"{name}: tuned_speedup {sp:.2f} < {1.0 - TOL:.2f} "
                f"(backend={row['derived'].get('backend')})")
    for name, (field, lo) in NAMED_GATES.items():
        row = tuned.get(name)
        if row is None:
            failures.append(f"{name}: row missing from artifact")
            continue
        v = row["derived"].get(field)
        if v is None or float(v) < lo:
            failures.append(f"{name}: {field} {v} < {lo}")
    return failures


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else "BENCH_PR10.json"
    with open(path) as fh:
        rows = json.load(fh)
    failures = check(rows)
    n_tuned = sum(1 for r in rows if isinstance(r.get("derived"), dict)
                  and "tuned_speedup" in r["derived"])
    if failures:
        print(f"check_tuned: {len(failures)} failure(s) over {n_tuned} "
              f"tuned rows in {path}:")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"check_tuned: {n_tuned} tuned rows in {path} all >= "
          f"{1.0 - TOL:.2f}x vs heuristic; named gates pass")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
