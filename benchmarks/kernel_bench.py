"""Timed micro-benchmarks: the CSD-SpMM sparse junction vs dense matmul.

Standalone CLI (the CI sharded job uses it)::

    PYTHONPATH=src python -m benchmarks.kernel_bench --quick --sharded \
        --devices 8 --json kernel-sharded-bench.json

``--devices N`` forces N host devices (must run before any jax init, so
only valid through this CLI, not ``benchmarks.run``'s in-process calls);
``--sharded`` times the model-parallel junction path per density next to
the single-device path; ``--json`` dumps the emitted rows.

Wall-clock on this host CPU (XLA path; the Pallas path targets TPU), at
several densities. ``derived`` reports the speedup over dense and the
effective GFLOP/s. The paper's complexity claim (compute scales with |W|)
is checked directly: flops_ratio ~= rho.

Every kernel row has a ``*_tuned`` sibling (PR 10): the regime is warmed
through ``repro.tune`` (cache hit, or benched in-process on a miss) and
``backend="auto"`` — which now dispatches the measured winner — is timed
against the static-heuristic default row. When the winner IS the default
configuration the default timing is reused (same executable, ratio
exactly 1.0). ``kernel/csd_decode_m2_scatter`` is the regression row for
the skinny-M cliff: gather's activation-gather lowering collapses at
M = 2 while scatter's weight-gather form is M-independent.

Also times the fused bias+activation epilogue against the unfused
(matmul, then separate bias/relu) form, forward and full train-step
(value_and_grad on w and b). Caveat for reading the numbers: on this XLA
CPU path both forms jit to essentially the same HLO (XLA fuses the
elementwise epilogue either way, and the fused VJP's cotangent masking
matches what autodiff derives), so the ``fused_*`` rows are an
API-parity + plumbing check hovering near 1.0x — the HBM-residency win
of the in-kernel epilogue only exists on the Pallas/TPU path, where the
pre-activation never leaves VMEM.
"""
from __future__ import annotations

import os
import sys

def _sniff_devices(argv):
    """Pre-argparse --devices extraction (both `--devices 8` and
    `--devices=8`) — must run before the first jax import, which locks
    the XLA device count."""
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--devices="):
            return a.split("=", 1)[1]
    return None


if __name__ == "__main__":
    _n = _sniff_devices(sys.argv)
    if _n:
        # append: an exported XLA_FLAGS must not silently veto the forcing
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={_n}").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_block_pattern
from repro.core.quant import quantize_slab
from repro.kernels import ops

from .common import emit, time_call


def _warm_junction(spec: dict) -> dict:
    """Measured cache entry for one junction regime: a hit returns the
    stored decision, a miss benches the regime in-process (the CLI
    pre-warms config-derived regimes; the bench covers its own shapes)."""
    from repro import tune
    from repro.tune import tuner
    c = tune.get_cache()
    key = tune.junction_key(
        m=spec["m"], n_in=spec["n_in"], n_out=spec["n_out"],
        rho=spec["rho"], E=spec.get("E", 0),
        dtype=spec.get("dtype", "float32"),
        quant=spec.get("quant", False), form=spec.get("form", "plain"))
    return c.get(key) or tuner.bench_junction(spec, cache=c, iters=2,
                                              repeats=2)


def _emit_tuned(name: str, spec: dict, default_us: float, auto_fn, *args,
                extra=None) -> float:
    """Emit a ``*_tuned`` row next to a default row: warm the tune cache
    for the regime, time ``backend="auto"`` (which now hits it), report
    the speedup over the static default row. When the measured winner IS
    the default configuration (xla/gather off-TPU) the default timing is
    reused — same executable, so the ratio is exactly 1.0 rather than
    re-measurement noise. Under ``REPRO_TUNE_DISABLE=1`` no tuned row is
    emitted at all (``backend="auto"`` is the heuristic then, so the row
    would gate nothing)."""
    from repro import tune
    if tune.disabled():
        return default_us
    ent = _warm_junction(spec)
    is_default = (ent.get("backend") == "xla"
                  and ent.get("dataflow", "gather") == "gather")
    t = default_us if is_default else time_call(auto_fn, *args, name=name)
    d = {"backend": ent.get("backend"),
         "dataflow": ent.get("dataflow", "-"),
         "default_us": round(default_us, 2),
         "tuned_speedup": round(default_us / t, 2)}
    if extra:
        d.update(extra(t))
    emit(name, t, d)
    return t


def run(n_in: int = 1024, n_out: int = 4096, m: int = 512):
    x = jax.random.normal(jax.random.key(0), (m, n_in))
    wd = jax.random.normal(jax.random.key(1), (n_in, n_out)) * 0.02

    dense = jax.jit(lambda x, w: x @ w)
    t_dense = time_call(dense, x, wd, name="dense_matmul")
    emit("kernel/dense_matmul", t_dense,
         {"gflops": round(2 * m * n_in * n_out / (t_dense * 1e-6) / 1e9,
                          1)})

    for rho in (0.5, 0.25, 0.125):
        bp = make_block_pattern(n_in, n_out, rho, block_in=128,
                                block_out=128, seed=0)
        w = jax.random.normal(
            jax.random.key(2), (bp.n_rb, bp.d_in_b, 128, 128)) * 0.02
        b = jax.random.normal(jax.random.key(3), (n_out,)) * 0.02
        f = jax.jit(lambda x, w: ops.csd_matmul(x, w, bp, backend="xla"))
        t = time_call(f, x, w, name=f"csd_spmm_rho{rho}")
        emit(f"kernel/csd_spmm_rho{rho}", t,
             {"speedup_vs_dense": round(t_dense / t, 2)})

        f_auto = jax.jit(lambda x, w, bp=bp: ops.csd_matmul(
            x, w, bp, backend="auto"))
        _emit_tuned(
            f"kernel/csd_spmm_rho{rho}_tuned",
            dict(m=m, n_in=n_in, n_out=n_out, rho=bp.density, E=0,
                 dtype="float32", quant=False, form="plain"),
            t, f_auto, x, w,
            extra=lambda tt, td=t_dense: {
                "speedup_vs_dense": round(td / tt, 2)})

        # fused vs unfused epilogue: forward (XLA = parity check, see
        # module docstring; the fwd fusion win is Pallas/TPU-only)
        unfused = jax.jit(lambda x, w, b: jax.nn.relu(
            ops.csd_matmul(x, w, bp, backend="xla") + b))
        fused = jax.jit(lambda x, w, b: ops.csd_matmul(
            x, w, bp, bias=b, activation="relu", backend="xla"))
        t_unf = time_call(unfused, x, w, b, name=f"unfused_fwd_rho{rho}")
        t_fus = time_call(fused, x, w, b, name=f"fused_fwd_rho{rho}")
        emit(f"kernel/fused_fwd_rho{rho}", t_fus,
             {"unfused_us": round(t_unf, 2),
              "fused_speedup": round(t_unf / t_fus, 2)})

        # fused vs unfused epilogue: train step (fwd + dw/db backward)
        def loss_unf(w, b, x):
            return jnp.mean(jax.nn.relu(
                ops.csd_matmul(x, w, bp, backend="xla") + b) ** 2)

        def loss_fus(w, b, x):
            return jnp.mean(ops.csd_matmul(
                x, w, bp, bias=b, activation="relu", backend="xla") ** 2)

        step_unf = jax.jit(jax.value_and_grad(loss_unf, argnums=(0, 1)))
        step_fus = jax.jit(jax.value_and_grad(loss_fus, argnums=(0, 1)))
        t_sunf = time_call(step_unf, w, b, x,
                           name=f"unfused_step_rho{rho}")
        t_sfus = time_call(step_fus, w, b, x,
                           name=f"fused_step_rho{rho}")
        emit(f"kernel/fused_step_rho{rho}", t_sfus,
             {"unfused_us": round(t_sunf, 2),
              "fused_speedup": round(t_sunf / t_sfus, 2)})

    # decode-shape (skinny-M) regime: the serving engine's decode steps
    # run csd_matmul at M = batch-of-slots (1..8) — track it so the
    # gather/scatter overhead at tiny M is visible next to the training
    # shapes above
    bp_dec = make_block_pattern(n_in, n_out, 0.25, block_in=128,
                                block_out=128, seed=0)
    w_dec = jax.random.normal(
        jax.random.key(5), (bp_dec.n_rb, bp_dec.d_in_b, 128, 128)) * 0.02
    f_dec = jax.jit(lambda x, w: ops.csd_matmul(x, w, bp_dec,
                                                backend="xla"))
    f_dec_auto = jax.jit(lambda x, w: ops.csd_matmul(x, w, bp_dec,
                                                     backend="auto"))
    f_dec_scatter = jax.jit(lambda x, w: ops.csd_matmul(
        x, w, bp_dec, backend="xla", dataflow="scatter"))
    # int8 decode rows (PR 9): decode is bandwidth-bound, so the 4x
    # smaller slab is where weight quantization pays — time the fused
    # dequant path right next to the f32 rows at the same skinny M
    q_dec, s_dec = quantize_slab(w_dec)
    f_q = jax.jit(lambda x, w, s: ops.csd_matmul(x, w, bp_dec,
                                                 backend="xla", w_scale=s))
    f_q_auto = jax.jit(lambda x, w, s: ops.csd_matmul(
        x, w, bp_dec, backend="auto", w_scale=s))
    for m_dec in (1, 2, 4, 8):
        xm = jax.random.normal(jax.random.key(6), (m_dec, n_in))
        t_dm = time_call(dense, xm, wd, name=f"decode_dense_m{m_dec}")
        t_sm = time_call(f_dec, xm, w_dec,
                         name=f"decode_csd_m{m_dec}")
        emit(f"kernel/csd_decode_m{m_dec}_rho0.25", t_sm,
             {"dense_us": round(t_dm, 2),
              "speedup_vs_dense": round(t_dm / t_sm, 2)})
        _emit_tuned(
            f"kernel/csd_decode_m{m_dec}_rho0.25_tuned",
            dict(m=m_dec, n_in=n_in, n_out=n_out, rho=bp_dec.density, E=0,
                 dtype="float32", quant=False, form="plain"),
            t_sm, f_dec_auto, xm, w_dec,
            extra=lambda tt, td=t_dm: {
                "speedup_vs_dense": round(td / tt, 2)})
        if m_dec == 2:
            # regression row for the M=2 cliff (PR 10): the default
            # gather dataflow gathers M-row activation slices per block
            # and falls off a cliff at M=2; scatter gathers *weights*
            # (M-independent) and must stay ahead of both gather and
            # dense here
            t_sc = time_call(f_dec_scatter, xm, w_dec,
                             name="decode_csd_m2_scatter")
            emit("kernel/csd_decode_m2_scatter", t_sc,
                 {"gather_us": round(t_sm, 2), "dense_us": round(t_dm, 2),
                  "speedup_vs_gather": round(t_sm / t_sc, 2),
                  "speedup_vs_dense": round(t_dm / t_sc, 2)})
        t_qm = time_call(f_q, xm, q_dec, s_dec,
                         name=f"decode_csd_m{m_dec}_int8")
        emit(f"kernel/csd_decode_m{m_dec}_rho0.25_int8", t_qm,
             {"f32_us": round(t_sm, 2),
              "speedup_vs_f32": round(t_sm / t_qm, 2)})
        _emit_tuned(
            f"kernel/csd_decode_m{m_dec}_rho0.25_int8_tuned",
            dict(m=m_dec, n_in=n_in, n_out=n_out, rho=bp_dec.density, E=0,
                 dtype="float32", quant=True, form="quant"),
            t_qm, f_q_auto, xm, q_dec, s_dec,
            extra=lambda tt, tf=t_sm: {
                "speedup_vs_f32": round(tf / tt, 2)})

    # training-step complexity scales with density (paper's core claim)
    def step_flops(rho):
        if rho == 1.0:
            return 2 * m * n_in * n_out
        bp = make_block_pattern(n_in, n_out, rho, block_in=128,
                                block_out=128)
        return 2 * m * bp.n_weight_elems

    emit("kernel/flops_ratio_rho0.25", 0.0,
         round(step_flops(0.25) / step_flops(1.0), 3))

    run_batched()


def run_batched(E: int = 8, d: int = 512, d_e: int = 1024, c: int = 256):
    """Batched (expert-major) junction: the MoE expert-FFN layout.

    Times the stacked dense einsum (``ecd,edf->ecf`` — the old
    ``MoE._expert_ffn`` form, now the ``kernels.ref`` oracle) against the
    batched ``csd_matmul`` path per density, forward and train-step. One
    shared pattern serves all ``E`` experts; FLOPs and weight storage scale
    with rho while the dense dispatch/combine stays untouched — the paper's
    >5X claim applied to the last dense junction family in the stack.
    """
    xe = jax.random.normal(jax.random.key(0), (E, c, d))

    wd = jax.random.normal(jax.random.key(1), (E, d, d_e)) * 0.02
    dense = jax.jit(lambda x, w: jnp.einsum("ecd,edf->ecf", x, w))
    t_dense = time_call(dense, xe, wd, name="moe_dense_einsum")
    flops = 2 * E * c * d * d_e
    emit("kernel/moe_dense_einsum", t_dense,
         {"gflops": round(flops / (t_dense * 1e-6) / 1e9, 1)})

    def step_dense(w, x):
        return jnp.mean(jnp.einsum("ecd,edf->ecf", x, w) ** 2)

    sd = jax.jit(jax.value_and_grad(step_dense))
    t_sdense = time_call(sd, wd, xe, name="moe_dense_step")
    emit("kernel/moe_dense_step", t_sdense, {})

    for rho in (0.5, 0.25, 0.125):
        bp = make_block_pattern(d, d_e, rho, block_in=128, block_out=128,
                                seed=0)
        w = jax.random.normal(
            jax.random.key(2),
            (E, bp.n_rb, bp.d_in_b, 128, 128)) * 0.02
        f = jax.jit(lambda x, w, bp=bp: ops.csd_matmul(x, w, bp,
                                                       backend="xla"))
        t = time_call(f, xe, w, name=f"moe_batched_csd_rho{rho}")
        emit(f"kernel/moe_batched_csd_rho{rho}", t,
             {"speedup_vs_dense": round(t_dense / t, 2)})

        f_auto = jax.jit(lambda x, w, bp=bp: ops.csd_matmul(
            x, w, bp, backend="auto"))
        _emit_tuned(
            f"kernel/moe_batched_csd_rho{rho}_tuned",
            dict(m=c, n_in=d, n_out=d_e, rho=bp.density, E=E,
                 dtype="float32", quant=False, form="batched"),
            t, f_auto, xe, w,
            extra=lambda tt, td=t_dense: {
                "speedup_vs_dense": round(td / tt, 2)})

        def step_sparse(w, x, bp=bp):
            return jnp.mean(ops.csd_matmul(x, w, bp, backend="xla") ** 2)

        ss = jax.jit(jax.value_and_grad(step_sparse))
        t_ss = time_call(ss, w, xe, name=f"moe_batched_step_rho{rho}")
        emit(f"kernel/moe_batched_step_rho{rho}", t_ss,
             {"speedup_vs_dense": round(t_sdense / t_ss, 2)})


def run_sharded(quick: bool = True, n_in: int = 1024, n_out: int = 4096,
                m: int = 256):
    """Model-parallel junction throughput per density vs the single-device
    path, on however many (host) devices the process sees.

    On forced host devices all "shards" share one CPU so the timings
    measure partition/collective overhead, not speedup; on a real mesh
    the same rows track the tensor-parallel scaling of the junction. The
    shard axis size plays the paper's flexible ``z``: k devices = k
    block-row ranges processed per step. The ``*_tuned`` row exercises the
    sharded ``backend="auto"`` path, which keys on the *shard-local*
    output width (tuning follows ``partition_pattern`` shapes).
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        emit("kernel/sharded_skipped", 0.0, {"devices": n_dev})
        return
    mesh = jax.make_mesh((n_dev,), ("model",))
    x = jax.random.normal(jax.random.key(0), (m, n_in))
    densities = (0.25,) if quick else (0.5, 0.25, 0.125)
    for rho in densities:
        bp = make_block_pattern(n_in, n_out, rho, block_in=128,
                                block_out=128, seed=0)
        if bp.n_rb % n_dev:
            emit(f"kernel/sharded_csd_rho{rho}", 0.0,
                 {"skipped": f"n_rb{bp.n_rb}_ndev{n_dev}"})
            continue
        w = jax.random.normal(
            jax.random.key(2), (bp.n_rb, bp.d_in_b, 128, 128)) * 0.02
        f1 = jax.jit(lambda x, w, bp=bp: ops.csd_matmul(
            x, w, bp, backend="xla"))
        fk = jax.jit(lambda x, w, bp=bp: ops.csd_matmul(
            x, w, bp, backend="xla", mesh=mesh, axis="model"))
        t1 = time_call(f1, x, w, name=f"sharded_single_rho{rho}")
        tk = time_call(fk, x, w, name=f"sharded_csd_rho{rho}")
        flops = 2 * m * bp.n_weight_elems
        emit(f"kernel/sharded_csd_rho{rho}", tk,
             {"single_us": round(t1, 2),
              "gflops": round(flops / (tk * 1e-6) / 1e9, 1),
              "devices": n_dev})

        fk_auto = jax.jit(lambda x, w, bp=bp: ops.csd_matmul(
            x, w, bp, backend="auto", mesh=mesh, axis="model"))
        _emit_tuned(
            f"kernel/sharded_csd_rho{rho}_tuned",
            dict(m=m, n_in=n_in, n_out=n_out // n_dev, rho=bp.density,
                 E=0, dtype="float32", quant=False, form="sharded"),
            tk, fk_auto, x, w)

        def step1(w, x, bp=bp):
            return jnp.mean(ops.csd_matmul(x, w, bp, backend="xla") ** 2)

        def stepk(w, x, bp=bp):
            return jnp.mean(ops.csd_matmul(
                x, w, bp, backend="xla", mesh=mesh, axis="model") ** 2)

        ts1 = time_call(jax.jit(jax.value_and_grad(step1)), w, x,
                        name=f"sharded_step1_rho{rho}")
        tsk = time_call(jax.jit(jax.value_and_grad(stepk)), w, x,
                        name=f"sharded_stepk_rho{rho}")
        emit(f"kernel/sharded_step_rho{rho}", tsk,
             {"single_us": round(ts1, 2), "devices": n_dev})


def main() -> None:
    import argparse
    import json

    from .common import ROWS
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host devices (handled pre-jax-import)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.sharded:
        run_sharded(quick=args.quick)
    else:
        run()
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(ROWS, fh, indent=1)


if __name__ == "__main__":
    main()
