"""Paper Figs. 7/8 — individual junction densities.

Trend 3: on redundant data, for a fixed overall density it is better to
keep the *later* junction denser (rho_1 < rho_2); the trend weakens or
reverses when input redundancy is removed (Fig. 8). We reproduce both arms
with the synthetic MNIST stand-in (full 800 features = redundant; cropped
200 features = reduced redundancy), 2-junction net, matched rho_net.
"""
from __future__ import annotations

import numpy as np

from repro.configs.paper_mlp import MNIST_2J
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp

from .common import emit, mnist_like


def _run_pair(n_net, data, rho_lo_hi, rho_hi_lo, tag, epochs, seeds=2):
    """Same rho_net; (sparse j1, dense j2) vs (dense j1, sparse j2)."""
    accs = {}
    for name, rho in (("early_sparse", rho_lo_hi),
                      ("late_sparse", rho_hi_lo)):
        a = []
        for s in range(seeds):
            cfg = MLPConfig(n_net=n_net, rho=rho, method="clashfree",
                            seed=s)
            _, acc = train_mlp(SparseMLP(cfg), data, epochs=epochs, seed=s)
            a.append(acc)
        accs[name] = float(np.mean(a))
        emit(f"fig7/{tag}/{name}", 0.0, round(accs[name], 4))
    # positive = sparsifying the EARLY junction (keeping the late one
    # dense) wins = the paper's trend 3 (rho_1 < rho_L)
    emit(f"fig7/{tag}/early_sparse_advantage", 0.0,
         round(accs["early_sparse"] - accs["late_sparse"], 4))


def run(epochs: int = 10):
    # redundant inputs (full 800-feature images):
    # rho_net equal in both arms: junction sizes 800x100 and 100x10.
    # early_sparse: rho=(6.25%, 100%); late_sparse: rho=(7.5%, ~0? -> use
    # (100%, 10%) vs (11%, 100%) matched edge counts.
    # |W| targets: arm A: 0.1*80000 + 1000 = 9000; arm B: 8000 + 1000*1.0
    data = mnist_like()
    _run_pair(MNIST_2J, data,
              rho_lo_hi=(0.10, 1.0),    # sparse early, dense late: 9000 w
              rho_hi_lo=(0.1125, 0.10), # 9000+100: denser early, sparse late
              tag="redundant", epochs=epochs)
    # reduced redundancy: crop to the 196 informative features (paper PCA)
    data_lo = mnist_like(n_features=196)
    n_net = (196, 100, 10)
    _run_pair(n_net, data_lo,
              rho_lo_hi=(0.10, 1.0),
              rho_hi_lo=(0.1454, 0.10),  # matched |W| ~ 2950
              tag="reduced_redundancy", epochs=epochs)
