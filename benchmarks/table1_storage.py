"""Paper Table I — hardware storage cost, FC vs pre-defined sparse.

Exact reproduction of the Table I expressions (no training needed), plus the
accuracy cost of that sparsity trained on the synthetic MNIST stand-in
(paper: 98.0% -> 97.2%; we report the same *delta* direction on our data).
"""
from __future__ import annotations

import numpy as np

from repro.core import storage_cost
from repro.configs.paper_mlp import MNIST_2J, rho_from_dout
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp

from .common import emit, mnist_like


def run(train: bool = True, epochs: int = 12):
    fc = storage_cost(MNIST_2J)
    sp = storage_cost(MNIST_2J, d_in=[160, 100])  # d_out=(20,10)
    emit("table1/fc_total_words", 0.0, fc.total)
    emit("table1/sparse_total_words", 0.0, sp.total)
    emit("table1/fc_weight_words", 0.0, fc.w)
    emit("table1/sparse_weight_words", 0.0, sp.w)
    emit("table1/memory_reduction_x", 0.0, round(fc.total / sp.total, 2))
    emit("table1/compute_reduction_x", 0.0, round(fc.w / sp.w, 2))
    assert fc.total == 85930 and sp.total == 21930  # paper's exact numbers

    if not train:
        return
    data = mnist_like()
    _, acc_fc = train_mlp(SparseMLP(MLPConfig(n_net=MNIST_2J)), data,
                          epochs=epochs, seed=0)
    cfgs = MLPConfig(n_net=MNIST_2J,
                     rho=rho_from_dout(MNIST_2J, (20, 10)),
                     method="clashfree")
    _, acc_sp = train_mlp(SparseMLP(cfgs), data, epochs=epochs, seed=0)
    emit("table1/fc_test_acc", 0.0, round(acc_fc, 4))
    emit("table1/sparse21_test_acc", 0.0, round(acc_sp, 4))
    emit("table1/acc_delta", 0.0, round(acc_fc - acc_sp, 4))
