"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``            quick set (~10 min CPU)
``PYTHONPATH=src python -m benchmarks.run --full``     full Table II ladder
``PYTHONPATH=src python -m benchmarks.run --only table2,fig12``
``PYTHONPATH=src python -m benchmarks.run --quick``    kernel + serving only,
                                                       writes BENCH_PR10.json

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
``--quick`` additionally writes the rows to ``BENCH_PR10.json`` at the repo
top level (CI uploads it): one object per row with ``us_per_call`` as a
number, ``derived`` as a structured object (PR 10 — the PR-9 artifact
carried both as strings) and a ``dtype`` column ("int8" for the
quantized-junction / quantized-engine rows, "float32" otherwise) so the
int8 decode-regime wins sit next to their full-width baselines in one
artifact. With a warm ``REPRO_TUNE_CACHE`` the ``*_tuned`` kernel rows
compare the measured-auto dispatch against the static heuristic
(``benchmarks.check_tuned`` gates them in CI).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _row_dtype(name: str) -> str:
    return "int8" if name.endswith("_int8") or "_int8_" in name \
        else "float32"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="kernel + serving benches only; write "
                         "BENCH_PR10.json at the repo top level")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host devices (for kernel_sharded; must "
                         "be set before the first jax import, which this "
                         "harness does lazily inside main)")
    args = ap.parse_args()

    if args.devices:
        # append: an exported XLA_FLAGS must not silently veto the forcing
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    from . import (fig1_histograms, fig7_junction_density, fig9_large_sparse,
                   fig12_other_methods, kernel_bench, roofline,
                   serving_bench, table1_storage, table2_methods)
    from .common import emit

    ep = args.epochs
    benches = {
        "table1": lambda: table1_storage.run(
            train=True, epochs=ep or 12),
        "table2": lambda: table2_methods.run(
            full=args.full, epochs=ep or 10),
        "fig1": lambda: fig1_histograms.run(epochs=ep or 12,
                                            full=args.full),
        "fig7": lambda: fig7_junction_density.run(epochs=ep or 10),
        "fig9": lambda: fig9_large_sparse.run(epochs=ep or 10),
        "fig12": lambda: fig12_other_methods.run(epochs=ep or 10),
        "kernel": kernel_bench.run,
        "kernel_sharded": lambda: kernel_bench.run_sharded(
            quick=not args.full),
        "roofline": roofline.run,
        "serving": lambda: serving_bench.run(quick=not args.full),
    }
    # the sharded rows only mean something on a multi-device view — run
    # them by default when --devices forces one, on request otherwise
    if args.only:
        selected = args.only.split(",")
    elif args.quick:
        selected = ["kernel", "serving"]
    else:
        selected = [b for b in benches
                    if b != "kernel_sharded" or args.devices]

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        t0 = time.time()
        try:
            benches[name]()
            emit(f"{name}/elapsed_s", 0.0, round(time.time() - t0, 1))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))

    if args.quick:
        from .common import ROWS
        rows = [dict(r, dtype=_row_dtype(r["name"])) for r in ROWS]
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_PR10.json")
        with open(path, "w") as fh:
            json.dump(rows, fh, indent=1)
        print(f"wrote {os.path.normpath(path)} ({len(rows)} rows)")

    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
