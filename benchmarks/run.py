"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``            quick set (~10 min CPU)
``PYTHONPATH=src python -m benchmarks.run --full``     full Table II ladder
``PYTHONPATH=src python -m benchmarks.run --only table2,fig12``

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host devices (for kernel_sharded; must "
                         "be set before the first jax import, which this "
                         "harness does lazily inside main)")
    args = ap.parse_args()

    if args.devices:
        import os
        # append: an exported XLA_FLAGS must not silently veto the forcing
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    from . import (fig1_histograms, fig7_junction_density, fig9_large_sparse,
                   fig12_other_methods, kernel_bench, roofline,
                   serving_bench, table1_storage, table2_methods)
    from .common import emit

    ep = args.epochs
    benches = {
        "table1": lambda: table1_storage.run(
            train=True, epochs=ep or 12),
        "table2": lambda: table2_methods.run(
            full=args.full, epochs=ep or 10),
        "fig1": lambda: fig1_histograms.run(epochs=ep or 12,
                                            full=args.full),
        "fig7": lambda: fig7_junction_density.run(epochs=ep or 10),
        "fig9": lambda: fig9_large_sparse.run(epochs=ep or 10),
        "fig12": lambda: fig12_other_methods.run(epochs=ep or 10),
        "kernel": kernel_bench.run,
        "kernel_sharded": lambda: kernel_bench.run_sharded(
            quick=not args.full),
        "roofline": roofline.run,
        "serving": lambda: serving_bench.run(quick=not args.full),
    }
    # the sharded rows only mean something on a multi-device view — run
    # them by default when --devices forces one, on request otherwise
    selected = (args.only.split(",") if args.only else
                [b for b in benches
                 if b != "kernel_sharded" or args.devices])

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        t0 = time.time()
        try:
            benches[name]()
            emit(f"{name}/elapsed_s", 0.0, round(time.time() - t0, 1))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
