"""§Roofline table generator: reads experiments/dryrun/*.json (written by
``repro.launch.dryrun``) and renders the per-(arch x shape x mesh) roofline
table as markdown (stdout + experiments/roofline.md)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

HEAD = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful/HLO flops | roofline frac |")
SEP = "|" + "---|" * 9


def load(dirname: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def render(rows) -> str:
    out = [HEAD, SEP]
    for r in rows:
        roof = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {roof['compute_s']:.4f} | {roof['memory_s']:.4f} "
            f"| {roof['collective_s']:.4f} | {roof['dominant']} "
            f"| {roof.get('useful_flop_ratio', 0):.3f} "
            f"| {roof.get('roofline_fraction', 0):.3f} |")
    return "\n".join(out)


def run(dirname: str = "experiments/dryrun",
        baseline_dir: str = "experiments/dryrun_baseline"):
    rows = load(dirname)
    if not rows:
        emit("roofline/cells", 0.0, 0)
        print("(no dry-run results found — run repro.launch.dryrun first)")
        return
    md = "## Optimized (post-hillclimb)\n\n" + render(rows)
    base = load(baseline_dir)
    if base:
        md += ("\n\n## Paper-faithful baseline (pre-hillclimb, "
               "128x128 blocks)\n\n" + render(base))
    print(md)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write(md + "\n")
    emit("roofline/cells", 0.0, len(rows))
    fracs = [r["roofline"].get("roofline_fraction", 0.0) for r in rows
             if r["kind"] == "train" and r["mesh"] == "16x16"]
    if fracs:
        emit("roofline/train_median_fraction", 0.0,
             round(sorted(fracs)[len(fracs) // 2], 3))
    if base:
        bf = [r["roofline"].get("roofline_fraction", 0.0) for r in base
              if r["kind"] == "train" and r["mesh"] == "16x16"]
        if bf:
            emit("roofline/train_median_fraction_baseline", 0.0,
                 round(sorted(bf)[len(bf) // 2], 3))
