"""Cross-version JAX compatibility shims.

The repo tracks the modern JAX API surface but must run on the pinned
jax of the container image (0.4.x line). Everything version-dependent is
funneled through here so call sites stay clean:

* ``shard_map`` — ``jax.shard_map`` (jax >= 0.6, ``check_vma=`` kwarg)
  with a fallback to ``jax.experimental.shard_map.shard_map`` (jax 0.4.x,
  ``check_rep=`` kwarg). The two flags mean the same thing (skip the
  varying-manual-axes / replication check); we translate.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: Optional[bool] = None,
) -> Callable:
    """Version-portable ``shard_map``.

    Accepts the modern keyword surface (``check_vma``); on older JAX the
    flag is forwarded as ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
