"""AdamW from scratch (no optax in this environment) + schedules + clipping.

Optimizer state is a plain pytree mirroring the parameters, so GSPMD shards
it exactly like the parameters (ZeRO-style: m/v live at 1x the sharded
parameter footprint). The update is fused into the train step — the XLA
analogue of the paper's UP running concurrently with FF/BP (Fig. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0., 1.)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def init(params) -> dict:
    zeros = lambda t: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        step_v = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices only
            step_v = step_v + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_v).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    new_params = jax.tree.unflatten(tdef, new_p)
    new_state = {"m": jax.tree.unflatten(tdef, new_m),
                 "v": jax.tree.unflatten(tdef, new_v),
                 "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
