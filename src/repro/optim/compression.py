"""Gradient/delta compression: int8 quantization with error feedback.

Used by the DiLoCo-style cross-pod sync (``train.trainer``): pods run K
local steps, then exchange *compressed* parameter deltas over DCN. Error
feedback (Seide et al. / EF-SGD) accumulates the quantization residual so
the compression is unbiased over time — the standard trick that makes 8-bit
(and lower) gradient exchange converge.

``psum_compressed`` performs the cross-pod mean in int8 inside a shard_map
over the pod axis; with no pod axis it reduces locally (identity mean).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(x: jax.Array, err: jax.Array
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (x + err); new error = input - dequantized."""
    target = x.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale)
    return q, scale, target - deq


def psum_compressed_tree(tree, err_tree, axis_name: Optional[str]):
    """Compressed mean over ``axis_name`` with error feedback, leafwise.

    Must be called inside a shard_map/psum context when axis_name is not
    None. Returns (mean_tree_f32, new_err_tree).
    """
    def leaf(x, err):
        q, scale, new_err = compress_with_feedback(x, err)
        if axis_name is None:
            return dequantize_int8(q, scale), new_err
        # exchange int8 payload; scales are f32 scalars (negligible bytes)
        s = jax.lax.psum(q.astype(jnp.int32) * 1, axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # each pod contributed q_i * scale_i; with per-tensor scales close
        # across pods we use the mean scale (exact when scales equal):
        mean = s.astype(jnp.float32) * (scale_sum / n) / n
        return mean, new_err

    leaves, tdef = jax.tree.flatten(tree)
    errs = jax.tree.leaves(err_tree)
    out, new_errs = [], []
    for x, e in zip(leaves, errs):
        m, ne = leaf(x, e)
        out.append(m)
        new_errs.append(ne)
    return jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, new_errs)


def compression_ratio(tree) -> float:
    """Bytes(int8+scale) / bytes(f32) for reporting."""
    total = sum(x.size * 4 for x in jax.tree.leaves(tree))
    comp = sum(x.size + 4 for x in jax.tree.leaves(tree))
    return comp / max(total, 1)
