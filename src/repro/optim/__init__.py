"""repro.optim — AdamW, LR schedules, gradient compression."""
from . import adam  # noqa: F401
from .adam import AdamWConfig, lr_at, global_norm  # noqa: F401
from .compression import (  # noqa: F401
    quantize_int8, dequantize_int8, compress_with_feedback,
    psum_compressed_tree, compression_ratio,
)
