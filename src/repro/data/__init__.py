"""repro.data — deterministic resumable pipelines + paper-repro datasets."""
from .synthetic import BigramLM, synthetic_mnist, synthetic_features  # noqa: F401
