"""Deterministic, resumable synthetic data pipelines.

Everything is a pure function of (seed, step) — after a restart the pipeline
regenerates batch ``k`` bit-identically with no host state to checkpoint
(fault-tolerance property: data position is implied by the step counter in
the training checkpoint). Host sharding slices each global batch by process
index, the standard multi-host pattern.

* ``BigramLM``       — tokens follow a fixed random bigram transition table
                       with noise: a learnable distribution so training
                       losses decrease meaningfully in examples/tests.
* ``synthetic_mnist``— procedural stand-in for the paper's MLP experiments
                       (the container is offline): class prototypes from a
                       seeded low-frequency random field + jitter + pixel
                       noise, 784 features padded to 800 exactly like the
                       paper's footnote 8.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class BigramLM:
    vocab_size: int = 1024
    branching: int = 8         # candidate successors per token
    noise: float = 0.05        # probability of a uniform-random token
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.table = rng.integers(
            0, self.vocab_size, size=(self.vocab_size, self.branching))

    def batch(self, step: int, batch_size: int, seq_len: int,
              process_index: int = 0, process_count: int = 1
              ) -> dict:
        """Global batch ``step``, sliced for this process."""
        assert batch_size % process_count == 0
        local = batch_size // process_count
        rng = np.random.default_rng(
            (self.seed, step, process_index))
        tokens = np.empty((local, seq_len + 1), np.int32)
        tokens[:, 0] = rng.integers(0, self.vocab_size, local)
        choice = rng.integers(0, self.branching, (local, seq_len))
        noise_mask = rng.random((local, seq_len)) < self.noise
        noise_tok = rng.integers(0, self.vocab_size, (local, seq_len))
        for t in range(seq_len):
            nxt = self.table[tokens[:, t], choice[:, t]]
            tokens[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t],
                                        nxt)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def iterate(self, batch_size: int, seq_len: int, start_step: int = 0,
                process_index: int = 0, process_count: int = 1
                ) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, batch_size, seq_len, process_index,
                             process_count)
            step += 1


def _smooth_field(rng: np.random.Generator, side: int, cutoff: int
                  ) -> np.ndarray:
    """Low-frequency random image via truncated DCT-like basis."""
    coef = rng.normal(size=(cutoff, cutoff))
    xs = np.arange(side)
    basis = np.stack([np.cos(np.pi * (xs + 0.5) * k / side)
                      for k in range(cutoff)])  # (cutoff, side)
    img = basis.T @ coef @ basis
    img = (img - img.min()) / (np.ptp(img) + 1e-9)
    return img


def synthetic_mnist(
    n_train: int = 8000,
    n_test: int = 2000,
    n_classes: int = 10,
    side: int = 28,
    pad_to: int = 800,
    noise: float = 0.35,
    max_shift: int = 2,
    seed: int = 0,
    n_features: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(x_train, y_train, x_test, y_test); features in [0,1], zero-padded to
    ``pad_to`` (paper footnote 8). ``n_features`` crops after flattening
    (used by the reduced-redundancy experiments, §IV-C)."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_field(rng, side, 6) for _ in range(n_classes)])

    def make(n, rng):
        y = rng.integers(0, n_classes, n)
        imgs = protos[y].copy()
        # small random shifts (translation invariance like handwriting)
        sx = rng.integers(-max_shift, max_shift + 1, n)
        sy = rng.integers(-max_shift, max_shift + 1, n)
        for i in range(n):
            imgs[i] = np.roll(np.roll(imgs[i], sx[i], 0), sy[i], 1)
        imgs += noise * rng.normal(size=imgs.shape)
        x = imgs.reshape(n, side * side).astype(np.float32)
        x = np.clip(x, 0.0, 1.5)
        if n_features is not None:
            x = x[:, :n_features]
        elif pad_to > x.shape[1]:
            x = np.pad(x, ((0, 0), (0, pad_to - x.shape[1])))
        return x, y.astype(np.int32)

    x_tr, y_tr = make(n_train, np.random.default_rng(seed + 1))
    x_te, y_te = make(n_test, np.random.default_rng(seed + 2))
    return x_tr, y_tr, x_te, y_te


def synthetic_features(
    n_train: int = 8000,
    n_test: int = 2000,
    n_classes: int = 50,
    n_features: int = 2000,
    informative: int = 60,
    noise: float = 1.0,
    seed: int = 0,
    redundancy: int = 8,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Reuters/TIMIT-style stand-in: class means live in an ``informative``-
    dim subspace, expanded through a random redundant mixing matrix
    (``redundancy`` controls how spread the information is — the knob for
    the §IV-C redundancy experiments)."""
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(n_classes, informative)) * 2.0
    mix = rng.normal(size=(informative, n_features)) / np.sqrt(informative)
    # concentrate information in few features when redundancy is low
    keep = rng.random((informative, n_features)) < (redundancy / informative)
    mix = mix * keep

    def make(n, rng):
        y = rng.integers(0, n_classes, n)
        z = means[y] + rng.normal(size=(n, informative)) * noise
        x = z @ mix + 0.1 * rng.normal(size=(n, n_features))
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = make(n_train, np.random.default_rng(seed + 1))
    x_te, y_te = make(n_test, np.random.default_rng(seed + 2))
    return x_tr, y_tr, x_te, y_te
