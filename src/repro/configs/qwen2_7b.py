"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; GQA with QKV bias. [arXiv:2407.10671; hf]
"""
from ..nn.common import ModelConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        max_seq_len=32768,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        ffn_gated=True,
        tie_embeddings=False,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, max_seq_len=512,
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
