"""Architecture registry: the 10 assigned archs + the paper's own MLPs.

``get_config(name)`` returns the full published configuration;
``get_config(name, smoke=True)`` returns the reduced same-family variant
used by the CPU smoke tests (same structural flags, tiny dims).
Select with ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib
from typing import List

from ..nn.common import ModelConfig, SHAPES, ShapeConfig

ARCHS = [
    "gemma3_4b",
    "granite_34b",
    "gemma2_9b",
    "qwen2_7b",
    "seamless_m4t_medium",
    "deepseek_moe_16b",
    "granite_moe_1b_a400m",
    "zamba2_1p2b",
    "mamba2_130m",
    "llava_next_34b",
]

# assignment ids (dashes) -> module names (underscores)
_ALIASES = {
    "gemma3-4b": "gemma3_4b",
    "granite-34b": "granite_34b",
    "gemma2-9b": "gemma2_9b",
    "qwen2-7b": "qwen2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "zamba2-1.2b": "zamba2_1p2b",
    "mamba2-130m": "mamba2_130m",
    "llava-next-34b": "llava_next_34b",
}

# which shape cells run per arch (DESIGN.md §4): long_500k only for
# sub-quadratic stacks (ssm / hybrid / 5:1 sliding-window).
LONG_CONTEXT_ARCHS = {"mamba2_130m", "zamba2_1p2b", "gemma3_4b"}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f".{canonical(name)}", __package__)
    return mod.smoke_config() if smoke else mod.config()


def shapes_for(name: str) -> List[ShapeConfig]:
    name = canonical(name)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if name in LONG_CONTEXT_ARCHS:
        out.append(SHAPES["long_500k"])
    return out


def all_cells() -> List[tuple]:
    """Every runnable (arch, shape) dry-run cell."""
    return [(a, s.name) for a in ARCHS for s in shapes_for(a)]
