"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba2 backbone (ssm_state=64)
plus a single *shared* attention block (32H, kv=32, d_ff=8192) applied every
6 layers over [hidden, embedding] concatenated input. [arXiv:2411.15242; hf]

38 = 6 units x 6 mamba layers (each unit followed by one application of the
shared block) + 2 epilogue mamba layers. Long-context (500k decode) runs:
the backbone state is O(1); only the 6 shared applications keep KV.
"""
from ..nn.common import (HybridConfig, ModelConfig, SSMConfig,
                         SparsityConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        n_layers=38,
        block_kind="mamba",
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,   # shared block: qk over 2*d_model concat input
        d_ff=8192,      # shared block FFN
        vocab_size=32000,
        max_seq_len=524288,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        hybrid=HybridConfig(period=6, shared_d_ff=8192,
                            concat_embedding=True),
        act="gelu",
        ffn_gated=True,
        tie_embeddings=True,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=128, vocab_size=512, max_seq_len=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=16),
        hybrid=HybridConfig(period=2, shared_d_ff=128,
                            concat_embedding=True),
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
