"""granite-34b [dense] — 88L d_model=6144 48H (GQA kv=1, i.e. MQA)
d_ff=24576 vocab=49152; llama-architecture code model. [arXiv:2405.04324; hf]
"""
from ..nn.common import ModelConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        max_seq_len=8192,
        rope_theta=10000.0,
        act="silu",
        ffn_gated=True,
        tie_embeddings=False,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=256, vocab_size=512, max_seq_len=512,
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
