"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) vocab=102400;
fine-grained MoE: 64 routed experts (d_expert=1408) top-6 + 2 shared
experts; layer 0 is a dense FFN (d_ff=10944). [arXiv:2401.06066; hf]

EP note: 64 routed experts shard 4-per-device over the 16-way model axis;
the shard_map all-to-all dispatch is the collective hot spot for this arch
(§Roofline).
"""
from ..nn.common import ModelConfig, MoEConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,   # per-expert hidden size (assignment's d_ff)
        vocab_size=102400,
        max_seq_len=16384,
        moe=MoEConfig(n_routed=64, top_k=6, n_shared=2, d_expert=1408,
                      capacity_factor=1.25, first_layer_dense=True,
                      dense_d_ff=10944),
        rope_theta=10000.0,
        act="silu",
        ffn_gated=True,
        tie_embeddings=False,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                moe_sparsity=True),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab_size=512, max_seq_len=512,
        moe=MoEConfig(n_routed=8, top_k=2, n_shared=1, d_expert=64,
                      capacity_factor=1.5, first_layer_dense=True,
                      dense_d_ff=128),
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16,
                                moe_sparsity=True),
    )
