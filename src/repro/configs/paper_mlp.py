"""The paper's own MLP configurations (§IV-A, Tables I/II).

These drive the faithful-reproduction benchmarks. Dataset note: the
evaluation container is offline, so the benchmark harness trains on
procedurally generated stand-ins (``repro.data.synthetic_mnist``) with the
same feature/class geometry; EXPERIMENTS.md reports the paper's published
numbers next to ours and compares *trends*, which is what §IV claims.
"""
from __future__ import annotations

from typing import Tuple

from ..nn.mlp import MLPConfig

# N_net configurations exactly as used in the paper
MNIST_2J = (800, 100, 10)                 # Fig. 1(a-c), Table I
MNIST_4J = (800, 100, 100, 100, 10)       # Fig. 1(d-h), Table II
REUTERS = (2000, 50, 50)                  # Table II
TIMIT = (39, 390, 39)                     # Table II
CIFAR_MLP = (4000, 500, 100)              # Table II (MLP after the CNN)

# Table II rows: (d_out per junction, z per junction)
TABLE2_MNIST = [
    ((80, 80, 80, 10), (200, 25, 25, 4)),
    ((60, 60, 60, 10), (200, 25, 25, 4)),
    ((40, 40, 40, 10), (200, 25, 25, 5)),
    ((20, 20, 20, 10), (200, 25, 25, 10)),
    ((10, 10, 10, 10), (200, 25, 25, 25)),
    ((5, 10, 10, 10), (100, 25, 25, 25)),
    ((2, 5, 5, 10), (80, 25, 25, 50)),
    ((1, 2, 2, 10), (80, 20, 20, 100)),
]


def rho_from_dout(n_net: Tuple[int, ...],
                  d_out: Tuple[int, ...]) -> Tuple[float, ...]:
    """Per-junction densities from out-degrees: rho_i = d_out_i / N_i."""
    return tuple(d / n_net[i + 1] for i, d in enumerate(d_out))


def table1_sparse() -> MLPConfig:
    """Table I sparse column: N=(800,100,10), d_out=(20,10) -> rho=21%."""
    return MLPConfig(n_net=MNIST_2J,
                     rho=rho_from_dout(MNIST_2J, (20, 10)),
                     method="clashfree")


def table1_fc() -> MLPConfig:
    return MLPConfig(n_net=MNIST_2J, rho=None)
