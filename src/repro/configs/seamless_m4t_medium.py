"""seamless-m4t-medium [audio] — enc-dec, 12L each side, d_model=1024
16H (kv=16) d_ff=4096 vocab=256206. [arXiv:2308.11596; hf]

Backbone only per the assignment: the speech frontend (w2v-BERT conformer
feature extractor) is a STUB — ``input_specs()`` delivers precomputed frame
embeddings (B, S, 1024) to the encoder adapter. Plain (ungated) GELU MLP,
classic transformer. Rope replaces the original learned positions (TPU
adaptation note: relative/learned positions add a (S, S) bias tensor that
breaks the chunked-attention memory bound; rope is the standard JAX-native
substitute and does not change junction structure).
"""
from ..nn.common import EncDecConfig, ModelConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        n_layers=24,                      # 12 enc + 12 dec
        enc_dec=EncDecConfig(n_encoder_layers=12, n_decoder_layers=12),
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        max_seq_len=32768,
        input_mode="embeddings",
        frontend_dim=1024,
        act="gelu",
        ffn_gated=False,
        tie_embeddings=True,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=4, enc_dec=EncDecConfig(2, 2),
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, frontend_dim=64, max_seq_len=512,
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
