"""mamba2-130m [ssm] — 24L d_model=768, attention-free SSD
(state-space duality), ssm_state=128, vocab=50280. [arXiv:2405.21060]

d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads, 1 B/C group.
Long-context (500k decode) runs: state is O(1) in sequence length.
§Arch-applicability: pre-defined sparsity attaches to in/out projection
junctions; the SSD recurrence has no weight junction (DESIGN.md).
"""
from ..nn.common import ModelConfig, SSMConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        n_layers=24,
        block_kind="mamba",
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        head_dim=1,
        d_ff=0,
        vocab_size=50280,
        max_seq_len=1048576,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        tie_embeddings=True,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, vocab_size=512, max_seq_len=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=16),
        loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
