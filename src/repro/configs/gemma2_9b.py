"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; alternating local/global attention, logit softcapping.
[arXiv:2408.00118; hf]
"""
from ..nn.common import ModelConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        max_seq_len=8192,
        local_global_ratio=1,       # alternating local:global
        attn_window=4096,
        logit_softcap=50.0,
        final_softcap=30.0,
        rope_theta=10000.0,
        post_norms=True,
        act="gelu_tanh",
        ffn_gated=True,
        tie_embeddings=True,
        scale_embed=True,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, max_seq_len=512, attn_window=16,
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
