"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8)
vocab=49155; 32 routed experts (d_expert=512) top-8, no shared experts.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from ..nn.common import ModelConfig, MoEConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,    # per-expert hidden size
        vocab_size=49155,
        max_seq_len=8192,
        moe=MoEConfig(n_routed=32, top_k=8, n_shared=0, d_expert=512,
                      capacity_factor=1.25),
        rope_theta=10000.0,
        act="silu",
        ffn_gated=True,
        tie_embeddings=True,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                moe_sparsity=True),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512, max_seq_len=512,
        moe=MoEConfig(n_routed=8, top_k=2, n_shared=0, d_expert=32,
                      capacity_factor=1.5),
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16,
                                moe_sparsity=True),
    )
