"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres tiling frontend.
[hf:llava-hf/llava-v1.6-mistral-7b-hf scaled per assignment; unverified]

Backbone only per the assignment: the anyres vision tower is a STUB —
``input_specs()`` delivers precomputed patch embeddings (B, S, 1024) which
the 2-layer MLP projector maps into the LM. Decode embeds generated text
tokens through the embedding table.
"""
from ..nn.common import ModelConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        max_seq_len=32768,
        input_mode="embeddings",
        frontend_dim=1024,
        rope_theta=5_000_000.0,
        act="silu",
        ffn_gated=True,
        tie_embeddings=False,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, frontend_dim=48, max_seq_len=512,
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
