"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt scaled per assignment; unverified]

Gemma-3 family flags: head_dim 256 (decoupled from d_model), GeGLU FFN,
sandwich norms + qk-norm, sliding window 1024 on local layers, embeddings
scaled by sqrt(d_model), tied head. Long-context (500k decode) runs for this
arch: only 1/6 of layers keep a full-length KV.
"""
from ..nn.common import ModelConfig, SparsityConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        max_seq_len=131072,
        local_global_ratio=5,
        attn_window=1024,
        rope_theta=1_000_000.0,
        post_norms=True,
        act="gelu_tanh",
        ffn_gated=True,
        tie_embeddings=True,
        scale_embed=True,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75)),
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, max_seq_len=512, attn_window=16,
        attn_chunk=16, loss_chunk=16, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75),
                                block_in=16, block_out=16),
    )
