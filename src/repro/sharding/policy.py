"""Sharding policy: logical axis names -> mesh axes, per execution mode.

Three rule sets (DESIGN.md §5), chosen by napkin math over the assigned
shapes (the derivations live in EXPERIMENTS.md §Perf):

* TRAIN / PREFILL — **2D FSDP + sequence parallelism.** Weights (and Adam
  state) shard over (data x model); activations shard batch over
  (pod, data) and sequence over model. Every matmul then induces a
  per-layer weight all-gather (ZeRO-3 style, overlapped by XLA inside the
  layer scan) instead of per-layer *activation* collectives — for the
  assigned shapes weight volume << activation volume (e.g. gemma3 train_4k:
  184 MB of layer weights vs 2x335 MB activation all-gathers that Megatron
  TP would move per layer). No head-count divisibility constraints: that is
  what makes one rule set work for 8-head gemma3 and 56-head llava alike.
* SERVE (decode) — TP for the FFN (column/row parallel over model),
  replicated attention projections (decode attention FLOPs are negligible),
  and **context-parallel KV**: the cache shards its *sequence* over model;
  softmax max/sum become all-reduces. No kv-head padding for MQA (granite
  kv=1) and no 16-way KV duplication.
* LONG (decode, batch=1) — as SERVE but batch unshardable: KV sequence
  shards over (data x model) = 256-way, attention reductions all-reduce
  over both axes.

Parameters/caches carry *logical* axis tuples (``model.spec()``); this
module resolves them against a mesh. Axes absent from the mesh (e.g. "pod"
on the single-pod mesh) are dropped automatically.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

TRAIN_RULES = {
    "embed_table": None,
    "embed": "data",
    "mlp": "model",
    "mlp_act": None,
    "qheads": "model",
    "kvheads": "model",
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "batch": ("pod", "data"),
    "seq": "model",
    "kv_seq": None,
    # block-sparse junction slabs: the (n_rb, d_in_b, bL, bR) weight's
    # block-row dim AND the shard_map partition of the junction compute
    # (kernels.ops sharded csd_matmul). One rule drives both, so the
    # storage chunks and the per-device patterns always line up; dw/db
    # come back shard-local, which keeps Adam state sharded ZeRO-style.
    "slab": "model",
}

SERVE_RULES = {
    "embed_table": None,
    "embed": None,
    "mlp": "model",
    "mlp_act": "model",
    "qheads": None,
    "kvheads": None,
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",
    # decode runs the same sharded junctions as training (TP FFN = the
    # column-parallel FF shard); see TRAIN_RULES["slab"]
    "slab": "model",
}

LONG_RULES = dict(SERVE_RULES, batch=None, kv_seq=("data", "model"))


def rules_for(kind: str, global_batch: int, mesh: Mesh,
              cfg=None) -> dict:
    """Pick + prune rules for a mesh. kind: train | prefill | decode.

    Mamba-family archs (``cfg.block_kind == 'mamba'``) cannot shard the
    sequence through the SSD recurrence, so in train/prefill the model axis
    folds into the batch axes instead (pure DP over as many axes as the
    global batch divides) — otherwise the model axis would sit idle while
    every shard holds full-sequence SSD intermediates.
    """
    if kind in ("train", "prefill"):
        rules = dict(TRAIN_RULES)
        if kind == "prefill":
            rules["kv_seq"] = "model"
        if cfg is not None and getattr(cfg, "block_kind", "") == "mamba":
            batch_axes = []
            n = 1
            for a in ("pod", "data", "model"):
                if a in mesh.axis_names and \
                        global_batch % (n * mesh.shape[a]) == 0:
                    batch_axes.append(a)
                    n *= mesh.shape[a]
            rules["batch"] = tuple(batch_axes) or None
            rules["seq"] = None
            if "model" in batch_axes:
                # model axis consumed by batch: weights shard on data only
                # (§Perf cell 2 iteration 1 tried forcing this in the
                # non-folded case too: the 513x collective cut was
                # outweighed by 16x replicated compute/memory — refuted
                # and reverted; the real reclaim is context-parallel SSD)
                rules["mlp"] = "data"
                rules["embed"] = None
                rules["qheads"] = None
                rules["kvheads"] = None
                rules["vocab"] = None
                # no tensor axis left for the junction shard_map either
                rules["slab"] = None
    else:
        data_size = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                                 if a in ("pod", "data")]))
        rules = dict(LONG_RULES) if global_batch < data_size \
            else dict(SERVE_RULES)
    # prune axes absent from this mesh
    names = set(mesh.axis_names)

    def prune(ax):
        if ax is None:
            return None
        if isinstance(ax, (tuple, list)):
            kept = tuple(a for a in ax if a in names)
            return kept if kept else None
        return ax if ax in names else None

    return {k: prune(v) for k, v in rules.items()}


def _to_pspec(axes: Sequence[Optional[str]], rules: dict) -> P:
    resolved = []
    for a in axes:
        r = rules.get(a) if a is not None else None
        resolved.append(r)
    return P(*resolved)


def param_pspecs(spec_tree: Any, rules: dict) -> Any:
    """model.spec() tree (leaves = tuples of logical names) -> P tree."""
    return jax.tree.map(
        lambda axes: _to_pspec(axes, rules), spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def opt_pspecs(param_specs: Any) -> dict:
    """Adam state mirrors parameter sharding; step is replicated."""
    return {"m": param_specs, "v": param_specs, "step": P()}


def cache_pspecs(cache_shapes: Any, rules: dict) -> Any:
    """Sharding for a KV/SSM cache pytree, matched by key path + rank.

    k/v:  (B, S, H, D) or (G, B, S, H, D)  -> batch, kv_seq
    ssd:  (B, H, P, N) or (G, ...)         -> batch
    conv: (B, K, C) or (G, ...)            -> batch
    pos / anything scalar                  -> replicated
    """
    b = rules.get("batch")
    s = rules.get("kv_seq")

    def leaf(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        rank = len(x.shape)
        if "k" in names or "v" in names:
            if rank == 4:
                return P(b, s, None, None)
            if rank == 5:
                return P(None, b, s, None, None)
        if "ssd" in names:
            return P(None, b, None, None, None) if rank == 5 else \
                P(b, None, None, None)
        if "conv" in names:
            return P(None, b, None, None) if rank == 4 else \
                P(b, None, None)
        if rank == 0:
            return P()
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def paged_cache_pspecs(cache_shapes: Any, rules: dict) -> Any:
    """Sharding for a *paged* KV/SSM cache pytree (serving engine).

    k_pages/v_pages: (P+1, page, Hkv, Dh) or (G, ...)  -> pages shard over
    ``kv_seq`` (context-parallel KV: pages ARE the cache's sequence axis;
    choose ``total_pages ≡ -1 mod axis_size`` so P+1 divides — otherwise
    ``sanitize`` falls back to replication on that dim).
    SSM state (ssd/conv, slot-major) and page tables stay replicated: the
    per-slot recurrent state is tiny and the gather/scatter by slot id is
    host-driven.
    """
    s = rules.get("kv_seq")

    def leaf(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        rank = len(x.shape)
        if "k_pages" in names or "v_pages" in names:
            if rank == 4:
                return P(s, None, None, None)
            if rank == 5:
                return P(None, s, None, None, None)
        if "k_scale" in names or "v_scale" in names:
            # int8 KV per-token scales (P+1, page) or (G, P+1, page):
            # the page dim shards exactly like its pages, so the (phys,
            # off) addresses computed on the host index shard-local rows
            # on every device identically
            if rank == 2:
                return P(s, None)
            if rank == 3:
                return P(None, s, None)
        return P(*([None] * rank))

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def batch_pspecs(batch: dict, rules: dict) -> dict:
    """tokens/labels (B, S); embeds (B, S, F)."""
    b = rules.get("batch")
    out = {}
    for k, v in batch.items():
        if k in ("tokens", "labels"):
            out[k] = P(b, None)
        elif k == "embeds":
            out[k] = P(b, None, None)
        else:
            out[k] = P(*([None] * len(v.shape)))
    return out


def sanitize(pspec_tree: Any, struct_tree: Any, mesh: Mesh) -> Any:
    """Drop per-dim sharding where the dim is not divisible by the shard
    count — block-sparse weight layouts (n_rb blocks), odd vocab sizes
    (granite-moe's 49155) and SSD projection dims are not all multiples of
    16. Dropping falls back to replication on that dim only."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, struct):
        shape = struct.shape
        out = []
        resolved = list(spec) + [None] * (len(shape) - len(spec))
        for dim, ax in zip(shape, resolved):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sizes[a] for a in axes]))
            out.append(ax if dim % n == 0 else None)
        return P(*out)

    return jax.tree.map(fix, pspec_tree, struct_tree,
                        is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, tree: Any, struct: Any = None) -> Any:
    if struct is not None:
        tree = sanitize(tree, struct, mesh)
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))
