"""repro.sharding — logical-axis sharding policies for train/serve."""
from .policy import (  # noqa: F401
    TRAIN_RULES, SERVE_RULES, LONG_RULES, rules_for,
    param_pspecs, opt_pspecs, cache_pspecs, batch_pspecs, named,
)
