"""Per-junction static complexity accounting — the paper's numbers, live.

The source paper's headline claim is that pre-defined sparsity cuts a
junction's storage and computational complexity by the density factor
rho = |W_sparse| / |W_dense| (>5X at the operating points of Table III).
This module computes exactly those quantities from a ``BlockPattern`` at
``fit_block_pattern`` time and exports them as labeled gauges, so any
running trainer/engine (or a scrape of ``/metrics``) can *observe* the
reduction instead of trusting two offline benchmark scripts:

* ``repro_junction_density``           — rho (block density == element
  density: surviving blocks are dense tiles);
* ``repro_junction_sparse_macs``       — MACs per input row through the
  sparse junction, ``n_rb * d_in_b * bL * bR`` (== rho * dense);
* ``repro_junction_dense_macs``        — MACs per input row of the dense
  equivalent, ``n_in * n_out``;
* ``repro_junction_speedup``           — dense/sparse MAC ratio (= 1/rho,
  the paper's complexity-reduction factor);
* ``repro_junction_weight_bytes``      — slab storage at the given weight
  width, plus ``repro_junction_index_bytes`` for the int32 gather pattern
  (the analog of the FPGA's address-generation ROM);
* ``repro_junction_dense_weight_bytes``— dense-equivalent storage.

One gauge series per distinct junction signature (shape, rho, block
sizes); ``repro_junction_patterns_total`` counts every registration, so
repeated layers sharing a signature are still visible.

Duck-typed on the pattern (any object with the ``BlockPattern`` fields):
obs imports nothing from ``repro.core``, keeping the dependency arrow
core -> obs only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import metrics


@dataclasses.dataclass(frozen=True)
class JunctionStats:
    """Static per-junction complexity numbers (per input row / token)."""
    n_in: int
    n_out: int
    block_in: int
    block_out: int
    density: float            # the paper's rho
    sparse_macs: int          # rho * n_in * n_out
    dense_macs: int           # n_in * n_out
    weight_bytes: int         # sparse slab storage (actual dtype width)
    dense_weight_bytes: int
    index_bytes: int          # gather-form pattern (int32)
    quant_bits: Optional[int] = None   # inference bitwidth, None = full
    quant_weight_bytes: int = 0        # int slab storage at quant_bits
    quant_scale_bytes: int = 0         # per-block f32 scales

    @property
    def speedup(self) -> float:
        """The paper's computational-complexity reduction factor."""
        return self.dense_macs / max(self.sparse_macs, 1)

    @property
    def storage_ratio(self) -> float:
        """Sparse (weights + pattern) over dense storage."""
        return (self.weight_bytes + self.index_bytes) \
            / max(self.dense_weight_bytes, 1)

    @property
    def quant_compression(self) -> float:
        """Dense f32 storage over the quantized sparse footprint (int
        slab + f32 scales + index pattern) — the multiplicative
        rho x bits/32 factor, ~= 32 / (rho x bits) when the scale and
        index overheads are small."""
        if self.quant_bits is None:
            return 1.0
        dense_f32 = self.dense_macs * 4
        sparse = self.quant_weight_bytes + self.quant_scale_bytes \
            + self.index_bytes
        return dense_f32 / max(sparse, 1)

    @property
    def label(self) -> str:
        return (f"{self.n_in}x{self.n_out}"
                f"b{self.block_in}x{self.block_out}"
                f"r{self.density:g}")


def junction_stats(bp, weight_bytes_per_elem: int = 4,
                   quant_bits: Optional[int] = None) -> JunctionStats:
    """Compute :class:`JunctionStats` from a ``BlockPattern``-shaped
    object. MAC counts are per input row: ``y = x @ W`` costs one MAC per
    stored weight element. ``weight_bytes_per_elem`` is the slab's actual
    storage width (2 for bf16, 4 for f32); ``quant_bits`` adds the
    inference-path int-quantized accounting (slab at ``quant_bits`` plus
    one f32 scale per surviving block)."""
    sparse = int(bp.n_rb) * int(bp.d_in_b) * int(bp.block_in) \
        * int(bp.block_out)
    dense = int(bp.n_in) * int(bp.n_out)
    n_blocks = int(bp.n_rb) * int(bp.d_in_b)
    return JunctionStats(
        n_in=int(bp.n_in), n_out=int(bp.n_out),
        block_in=int(bp.block_in), block_out=int(bp.block_out),
        density=float(bp.density),
        sparse_macs=sparse, dense_macs=dense,
        weight_bytes=sparse * weight_bytes_per_elem,
        dense_weight_bytes=dense * weight_bytes_per_elem,
        index_bytes=int(bp.block_idx.size) * 4,
        quant_bits=quant_bits,
        quant_weight_bytes=sparse * quant_bits // 8 if quant_bits else 0,
        quant_scale_bytes=n_blocks * 4 if quant_bits else 0,
    )


def register(bp, registry: Optional[metrics.Registry] = None,
             weight_bytes_per_elem: int = 4,
             quant_bits: Optional[int] = None) -> JunctionStats:
    """Export one junction's static accounting as gauges (called from
    ``core.block_pattern.fit_block_pattern`` for every junction the model
    instantiates). Idempotent per signature: same-shaped junctions share
    one series."""
    reg = metrics.resolve(registry)
    st = junction_stats(bp, weight_bytes_per_elem, quant_bits)
    if reg.enabled:
        j = st.label
        reg.counter(
            "repro_junction_patterns_total",
            "BlockPattern registrations (repeats share gauge series)",
        ).inc(junction=j)
        g = [("repro_junction_density", st.density,
              "junction density rho = |W_sparse|/|W_dense|"),
             ("repro_junction_sparse_macs", st.sparse_macs,
              "MACs per input row through the sparse junction"),
             ("repro_junction_dense_macs", st.dense_macs,
              "MACs per input row of the dense equivalent"),
             ("repro_junction_speedup", st.speedup,
              "dense/sparse MAC ratio (the paper's reduction factor)"),
             ("repro_junction_weight_bytes", st.weight_bytes,
              "sparse weight-slab storage bytes"),
             ("repro_junction_dense_weight_bytes", st.dense_weight_bytes,
              "dense-equivalent weight storage bytes"),
             ("repro_junction_index_bytes", st.index_bytes,
              "gather-form pattern index storage bytes (int32)")]
        if st.quant_bits:
            g += [("repro_junction_quant_weight_bytes",
                   st.quant_weight_bytes,
                   f"int{st.quant_bits}-quantized slab storage bytes"),
                  ("repro_junction_quant_scale_bytes",
                   st.quant_scale_bytes,
                   "per-block f32 dequant scale storage bytes"),
                  ("repro_junction_quant_compression",
                   st.quant_compression,
                   "dense f32 storage over quantized sparse footprint "
                   "(the multiplicative rho x bits/32 factor)")]
        for name, v, help in g:
            reg.gauge(name, help).set(v, junction=j)
    return st
