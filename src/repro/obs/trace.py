"""Span API + XLA profiler bridging.

``span("prefill", seq=3)`` times a host-side phase: the duration lands in
the registry (``repro_span_seconds`` histogram, labeled by span name, plus
a raw-duration ring the benchmarks read) and — when jax is importable —
the span body is bracketed with ``jax.profiler.TraceAnnotation`` so
engine/trainer phases show up *named* in XLA profile traces captured via
:func:`profile_trace`.

Spans wrap host code *around* jitted calls; they never enter a traced
program, so the jitted executables are identical with tracing on or off
(the same purity contract as ``obs.metrics``).
"""
from __future__ import annotations

import contextlib
import time
from typing import Optional

from . import metrics

try:  # obs stays importable without jax (dependency-free contract)
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover - jax is present in this repo
    _TraceAnnotation = None


@contextlib.contextmanager
def span(name: str, registry: Optional[metrics.Registry] = None,
         annotate: bool = True, **attrs):
    """Time a named host-side phase.

    * records the wall-clock duration into ``registry`` (process default
      when ``None``) as a ``repro_span_seconds`` histogram sample + a raw
      duration + a JSONL ``span`` event (with ``attrs``);
    * brackets the body with ``jax.profiler.TraceAnnotation(name)`` (when
      available and ``annotate``), so a concurrently captured XLA profile
      shows the phase by name.
    """
    reg = metrics.resolve(registry)
    ann = _TraceAnnotation(name) if (annotate and _TraceAnnotation
                                     is not None and reg.enabled) else None
    if ann is not None:
        ann.__enter__()
    t0 = time.perf_counter()
    try:
        yield
    finally:
        dt = time.perf_counter() - t0
        if ann is not None:
            ann.__exit__(None, None, None)
        if reg.enabled:
            reg.record_span(name, dt, attrs or None)


@contextlib.contextmanager
def profile_trace(log_dir: Optional[str]):
    """Capture a real ``jax.profiler.trace`` into ``log_dir`` for the
    duration of the block (no-op when ``log_dir`` is falsy — callers wire
    a ``--profile-dir`` knob straight through). Spans inside the block
    appear as named TraceAnnotations in the captured timeline."""
    if not log_dir:
        yield
        return
    import jax
    with jax.profiler.trace(log_dir):
        yield


def timed_call(fn, *args, iters: int = 10, warmup: int = 2,
               repeats: int = 1, name: str = "call",
               registry: Optional[metrics.Registry] = None) -> float:
    """Best-of-``repeats`` median wall-time per call in microseconds,
    measured THROUGH the registry: each timed iteration runs under
    ``span(f"bench/{name}")`` and the return value is the best (minimum)
    over ``repeats`` rounds of the median of each round's ``iters``
    durations — benchmark tables, the autotuner, and live metrics share
    one clock and one stream (they cannot disagree). The best-of-medians
    estimator is robust to one-off scheduler noise in either direction:
    the median absorbs spikes within a round, the min discards whole
    rounds degraded by background load. Blocks on jax arrays."""
    import jax
    import numpy as np

    reg = metrics.resolve(registry)
    sname = f"bench/{name}"
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    best = None
    for _ in range(max(1, repeats)):
        for _ in range(iters):
            with span(sname, registry=reg):
                jax.block_until_ready(fn(*args))
        ds = reg.span_durations(sname)[-iters:]
        med = float(np.median(ds) * 1e6)
        best = med if best is None else min(best, med)
    return best
