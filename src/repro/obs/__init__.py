"""repro.obs — unified metrics, tracing, and sparse-FLOP accounting.

A dependency-free (stdlib-only core) observability layer threaded through
every hot path of the repo:

* ``metrics`` — process-local registry of counters/gauges/fixed-bucket
  histograms with labels, a Prometheus-text exporter, a JSONL event sink
  with monotonic timestamps, and an optional stdlib ``/metrics`` HTTP
  endpoint. All recording is host-side, outside jit: jitted step
  functions are byte-identical with obs on or off.
* ``trace``   — ``span()`` context manager stamping the JSONL stream and
  bracketing phases with ``jax.profiler.TraceAnnotation`` so they appear
  named in XLA profiles; ``profile_trace()`` captures a real profiler
  trace (the ``--profile-dir`` knobs route here).
* ``flops``   — per-junction static accounting from each ``BlockPattern``
  (sparse/dense MACs, storage bytes, the paper's density rho and speedup
  factor), registered at ``fit_block_pattern`` time and exported as
  gauges — the paper's Table-III complexity numbers as live metrics.
* ``dump``    — ``python -m repro.obs.dump``: replay a recorded JSONL
  stream and render it as text/JSON/Prometheus.
"""
from . import flops, metrics, trace  # noqa: F401
from .metrics import (  # noqa: F401
    Registry, disabled_registry, get_registry, serve_http,
)
from .trace import profile_trace, span, timed_call  # noqa: F401

__all__ = ["metrics", "trace", "flops", "Registry", "get_registry",
           "disabled_registry", "serve_http", "span", "profile_trace",
           "timed_call"]
