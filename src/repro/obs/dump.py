"""``python -m repro.obs.dump`` — render a metrics stream or live registry.

The JSONL event stream a :class:`repro.obs.metrics.Registry` writes is
self-describing (``def`` events carry metric kinds and histogram buckets)
and replayable: this CLI reconstructs the registry another process
recorded and renders it as human text, JSON, or Prometheus exposition
format — the same exporters the live ``/metrics`` endpoint uses, so the
offline artifact and the online scrape can never disagree.

    python -m repro.obs.dump --input metrics.jsonl --format prom
    python -m repro.obs.dump --input metrics.jsonl --format json -o out.json

Without ``--input`` the path is taken from ``REPRO_METRICS_JSONL``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

from .metrics import DEFAULT_BUCKETS, Registry


def replay(path: str) -> Registry:
    """Reconstruct a registry from a JSONL event stream."""
    reg = Registry()
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            kind = ev.get("kind")
            name = ev.get("name", "")
            labels = ev.get("labels", {})
            if kind == "def":
                if ev["mtype"] == "counter":
                    reg.counter(name, ev.get("help", ""))
                elif ev["mtype"] == "gauge":
                    reg.gauge(name, ev.get("help", ""))
                elif ev["mtype"] == "histogram":
                    reg.histogram(name, ev.get("help", ""),
                                  buckets=ev.get("buckets",
                                                 DEFAULT_BUCKETS))
            elif kind == "counter":
                reg.counter(name).inc(ev["v"], **labels)
            elif kind == "gauge":
                reg.gauge(name).set(ev["v"], **labels)
            elif kind == "hist":
                reg.histogram(name).observe(ev["v"], **labels)
            elif kind == "span":
                # bypass record_span: the hist/span events were ALSO
                # written by the recorder, so only refill the raw ring
                ring = reg._spans.setdefault(name, __import__(
                    "collections").deque(maxlen=1024))
                ring.append(float(ev["dur"]))
            # "meta" lines are informational
    return reg


def render_text(reg: Registry) -> str:
    """Human-readable summary: one line per series."""
    snap = reg.snapshot()
    lines = []
    for kind in ("counters", "gauges"):
        for name, m in sorted(snap[kind].items()):
            for s in m["series"]:
                lab = ",".join(f"{k}={v}" for k, v in
                               sorted(s["labels"].items()))
                lines.append(f"{kind[:-1]:9s} {name}"
                             f"{'{' + lab + '}' if lab else ''} "
                             f"= {s['value']:g}")
    for name, m in sorted(snap["histograms"].items()):
        for s in m["series"]:
            lab = ",".join(f"{k}={v}" for k, v in sorted(s["labels"].items()))
            mean = s["sum"] / s["count"] if s["count"] else 0.0
            lines.append(f"histogram {name}"
                         f"{'{' + lab + '}' if lab else ''} "
                         f"count={s['count']} sum={s['sum']:g} "
                         f"mean={mean:g}")
    for name, s in sorted(snap["spans"].items()):
        lines.append(f"span      {name} count={s['count']} "
                     f"total_s={s['total_s']:g} mean_s={s['mean_s']:g}")
    return "\n".join(lines) + ("\n" if lines else "")


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.dump",
        description="render a repro.obs JSONL metrics stream")
    ap.add_argument("--input", default=None,
                    help="JSONL stream (default: $REPRO_METRICS_JSONL)")
    ap.add_argument("--format", choices=("text", "json", "prom"),
                    default="text")
    ap.add_argument("--output", "-o", default="-",
                    help="output file ('-' = stdout)")
    args = ap.parse_args(argv)
    path = args.input or os.environ.get("REPRO_METRICS_JSONL")
    if not path:
        ap.error("no --input and REPRO_METRICS_JSONL is unset")
    if not os.path.exists(path):
        ap.error(f"metrics stream not found: {path}")
    reg = replay(path)
    if args.format == "prom":
        out = reg.prometheus_text()
    elif args.format == "json":
        out = json.dumps(reg.snapshot(), indent=2) + "\n"
    else:
        out = render_text(reg)
    if args.output == "-":
        sys.stdout.write(out)
    else:
        with open(args.output, "w") as fh:
            fh.write(out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
