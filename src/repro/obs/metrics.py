"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Design rules (the contract the rest of the repo instruments against):

* **Host-side only.** Recording is plain Python on the host, outside every
  ``jax.jit`` boundary. Nothing here emits a jax primitive, a callback, or
  any op that could appear in a traced program — the jitted step functions
  are byte-identical with metrics enabled or disabled (proven by the
  jit-purity test in ``tests/test_obs.py`` and by sparselint's SL201 pass
  over the traced subjects).
* **Dependency-free.** stdlib only; ``jax`` is never imported here.
* **Cheap when off.** A disabled registry's handles are no-ops; call sites
  keep one ``if``'s worth of overhead.
* **Replayable.** With a JSONL sink attached every mutation appends one
  event line stamped with a monotonic timestamp; ``repro.obs.dump``
  reconstructs the full registry from the stream in another process, so
  the CI artifact and the live ``/metrics`` endpoint can never disagree.

Label sets are free-form keyword arguments; per-metric series cardinality
is capped (``max_series``) and a breach raises — a runaway label (e.g. a
request id used as a label) is a bug, not a scaling strategy.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

# latency-oriented default buckets (seconds): 0.5 ms .. 30 s
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

_SPAN_RING = 1024  # raw span durations kept per span name (benchmarks read)


def _labels_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _prom_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + body + "}"


class CardinalityError(ValueError):
    """A metric exceeded its label-cardinality budget."""


class _Metric:
    """One named metric: a family of label-keyed series."""

    kind = "untyped"

    def __init__(self, registry: "Registry", name: str, help: str,
                 max_series: int):
        self._reg = registry
        self.name = name
        self.help = help
        self.max_series = max_series
        self.series: Dict[Tuple[Tuple[str, str], ...], object] = {}

    def _series(self, labels: Dict[str, object], default):
        key = _labels_key(labels)
        s = self.series.get(key)
        if s is None:
            if len(self.series) >= self.max_series:
                raise CardinalityError(
                    f"metric {self.name!r} exceeded {self.max_series} label "
                    f"sets (offending labels: {dict(key)!r}) — an unbounded "
                    f"label (request id? timestamp?) is leaking into the "
                    f"label space")
            s = self.series[key] = default()
        return key, s


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if not self._reg.enabled:
            return
        if value < 0:
            raise ValueError(f"counter {self.name} increment must be >= 0")
        key, _ = self._series(labels, float)
        self.series[key] += value
        self._reg._event("counter", self.name, key, value)

    def value(self, **labels) -> float:
        return float(self.series.get(_labels_key(labels), 0.0))


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        if not self._reg.enabled:
            return
        key, _ = self._series(labels, float)
        self.series[key] = float(value)
        self._reg._event("gauge", self.name, key, float(value))

    def set_max(self, value: float, **labels) -> None:
        """High-water-mark update: keep the max of old and new."""
        if not self._reg.enabled:
            return
        key, _ = self._series(labels, float)
        new = max(self.series[key], float(value))
        self.series[key] = new
        self._reg._event("gauge", self.name, key, new)

    def value(self, **labels) -> float:
        return float(self.series.get(_labels_key(labels), 0.0))


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)  # +1 = +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, registry, name, help, max_series,
                 buckets=DEFAULT_BUCKETS):
        super().__init__(registry, name, help, max_series)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError(f"histogram {name} needs at least one bucket")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        if not self._reg.enabled:
            return
        key, s = self._series(
            labels, lambda: _HistSeries(len(self.buckets)))
        v = float(value)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        s.counts[i] += 1
        s.sum += v
        s.count += 1
        self._reg._event("hist", self.name, key, v)

    def stats(self, **labels) -> Tuple[int, float]:
        """(count, sum) for one series — 0s when never observed."""
        s = self.series.get(_labels_key(labels))
        return (0, 0.0) if s is None else (s.count, s.sum)


class Registry:
    """A process-local metric registry + optional JSONL event sink.

    ``enabled=False`` turns every handle into a no-op (creation still
    succeeds so call sites need no branching).
    """

    def __init__(self, enabled: bool = True, max_series: int = 256,
                 jsonl_path: Optional[str] = None):
        self.enabled = enabled
        self.max_series = max_series
        self._metrics: Dict[str, _Metric] = {}
        self._spans: Dict[str, deque] = {}
        self._lock = threading.Lock()
        self._sink = None
        self._t0 = time.monotonic()
        if jsonl_path:
            self.set_jsonl(jsonl_path)

    # -- metric construction (get-or-create) -------------------------------

    def _get(self, cls, name: str, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self, name, help,
                                              self.max_series, **kw)
                self._def_event(m)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    # -- spans (recorded by repro.obs.trace) --------------------------------

    def record_span(self, name: str, duration_s: float,
                    attrs: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        with self._lock:
            ring = self._spans.get(name)
            if ring is None:
                if len(self._spans) >= self.max_series:
                    raise CardinalityError(
                        f"span name cardinality exceeded {self.max_series} "
                        f"(offending span: {name!r})")
                ring = self._spans[name] = deque(maxlen=_SPAN_RING)
            ring.append(float(duration_s))
        self.histogram("repro_span_seconds",
                       "wall-clock duration of named host spans").observe(
            duration_s, span=name)
        if self._sink is not None:
            self._write({"t": time.monotonic(), "kind": "span",
                         "name": name, "dur": float(duration_s),
                         "attrs": attrs or {}})

    def span_durations(self, name: str) -> List[float]:
        """Raw recent durations (seconds) for one span name, oldest first."""
        return list(self._spans.get(name, ()))

    # -- JSONL sink ---------------------------------------------------------

    def set_jsonl(self, path: Optional[str]) -> None:
        """Attach (or with ``None`` detach) a JSONL event sink. Definition
        events for already-registered metrics are replayed into a fresh
        sink so the stream is self-describing."""
        if self._sink is not None:
            self._sink.close()
            self._sink = None
        if path is None:
            return
        self._sink = open(path, "a", buffering=1)
        self._write({"t": time.monotonic(), "kind": "meta",
                     "clock": "monotonic", "pid": os.getpid()})
        with self._lock:
            for m in self._metrics.values():
                self._def_event(m)

    def close(self) -> None:
        self.set_jsonl(None)

    def _write(self, event: dict) -> None:
        try:
            self._sink.write(json.dumps(event) + "\n")
        except ValueError:  # sink closed under us
            self._sink = None

    def _def_event(self, m: _Metric) -> None:
        if self._sink is None:
            return
        ev = {"t": time.monotonic(), "kind": "def", "mtype": m.kind,
              "name": m.name, "help": m.help}
        if isinstance(m, Histogram):
            ev["buckets"] = list(m.buckets)
        self._write(ev)

    def _event(self, kind: str, name: str,
               key: Tuple[Tuple[str, str], ...], value: float) -> None:
        if self._sink is None:
            return
        self._write({"t": time.monotonic(), "kind": kind, "name": name,
                     "labels": dict(key), "v": value})

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-friendly dump of every series."""
        out = {"counters": {}, "gauges": {}, "histograms": {}, "spans": {}}
        with self._lock:
            for m in self._metrics.values():
                if isinstance(m, Histogram):
                    out["histograms"][m.name] = {
                        "help": m.help, "buckets": list(m.buckets),
                        "series": [
                            {"labels": dict(k), "count": s.count,
                             "sum": s.sum,
                             "bucket_counts": list(s.counts)}
                            for k, s in m.series.items()]}
                else:
                    dest = out["counters"] if isinstance(m, Counter) \
                        else out["gauges"]
                    dest[m.name] = {
                        "help": m.help,
                        "series": [{"labels": dict(k), "value": v}
                                   for k, v in m.series.items()]}
            for name, ring in self._spans.items():
                ds = list(ring)
                out["spans"][name] = {
                    "count": len(ds), "total_s": sum(ds),
                    "mean_s": (sum(ds) / len(ds)) if ds else 0.0}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                if isinstance(m, Histogram):
                    for key, s in sorted(m.series.items()):
                        cum = 0
                        for b, c in zip(m.buckets, s.counts):
                            cum += c
                            lk = _prom_labels(key + (("le", f"{b:g}"),))
                            lines.append(f"{name}_bucket{lk} {cum}")
                        cum += s.counts[-1]
                        lk = _prom_labels(key + (("le", "+Inf"),))
                        lines.append(f"{name}_bucket{lk} {cum}")
                        lines.append(
                            f"{name}_sum{_prom_labels(key)} {s.sum:g}")
                        lines.append(
                            f"{name}_count{_prom_labels(key)} {s.count}")
                else:
                    for key, v in sorted(m.series.items()):
                        lines.append(f"{name}{_prom_labels(key)} {v:g}")
        return "\n".join(lines) + ("\n" if lines else "")


# -- process-global default registry ----------------------------------------

_default: Optional[Registry] = None
_disabled: Optional[Registry] = None
_lock = threading.Lock()


def get_registry() -> Registry:
    """The process-default registry. On first use, attaches a JSONL sink
    if ``REPRO_METRICS_JSONL`` names a path."""
    global _default
    with _lock:
        if _default is None:
            _default = Registry(
                jsonl_path=os.environ.get("REPRO_METRICS_JSONL") or None)
        return _default


def disabled_registry() -> Registry:
    """A shared always-off registry: handles exist, every record is a
    no-op. What ``metrics=False`` configs route through."""
    global _disabled
    with _lock:
        if _disabled is None:
            _disabled = Registry(enabled=False)
        return _disabled


def resolve(registry: Optional[Registry], enabled: bool = True) -> Registry:
    """The registry a component should record into: an explicit instance
    wins, else the process default, else (``enabled=False``) the no-op."""
    if registry is not None:
        return registry
    return get_registry() if enabled else disabled_registry()


# -- optional stdlib /metrics endpoint ---------------------------------------


def serve_http(registry: Registry, port: int, host: str = "127.0.0.1"):
    """Serve ``/metrics`` (Prometheus text) and ``/metrics.json`` from a
    daemon thread. Returns the ``ThreadingHTTPServer``; call
    ``.shutdown()`` to stop. ``port=0`` binds an ephemeral port
    (``server.server_address[1]`` has the real one — tests use this)."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.split("?")[0] == "/metrics":
                body = registry.prometheus_text().encode()
                ctype = "text/plain; version=0.0.4"
            elif self.path.split("?")[0] == "/metrics.json":
                body = json.dumps(registry.snapshot()).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # quiet: metrics scrapes are not news
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    t = threading.Thread(target=server.serve_forever, daemon=True,
                         name="repro-obs-metrics-http")
    t.start()
    return server
