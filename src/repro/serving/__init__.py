"""repro.serving — continuous-batching inference over the sparse kernels.

* ``kv_cache``  — paged KV cache: page pool, free-list allocator, jnp
                  page tables (jit-compatible address translation).
* ``scheduler`` — admit/evict/preempt + chunked-prefill planning under a
                  per-step token budget (the paper's flexible-``z`` time
                  multiplexing applied to requests).
* ``spec``      — model-free prompt-lookup drafter for speculative
                  multi-token decode (greedy-verified by the engine).
* ``engine``    — ``ServingEngine``: prefill through the flash-attention
                  + csd_matmul path, decode through the paged-attention
                  kernel (Pallas on TPU, gather-XLA elsewhere).

``engine`` is imported lazily: ``kv_cache``/``scheduler``/``spec`` are
dependency-light (the model stack imports them), while the engine pulls
in the full ``repro.nn`` stack.
"""
from . import kv_cache, scheduler, spec  # noqa: F401
from .kv_cache import PageState, init_page_state  # noqa: F401
from .scheduler import Request, Scheduler, StepPlan  # noqa: F401
from .spec import PromptLookupDrafter, propose_drafts  # noqa: F401

__all__ = ["kv_cache", "scheduler", "spec", "engine", "PageState",
           "init_page_state", "Request", "Scheduler", "StepPlan",
           "PromptLookupDrafter", "propose_drafts",
           "ServingEngine", "EngineConfig"]


def __getattr__(name):
    if name in ("engine", "ServingEngine", "EngineConfig"):
        import importlib
        mod = importlib.import_module(".engine", __name__)
        if name == "engine":
            return mod
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
