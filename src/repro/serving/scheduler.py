"""Continuous-batching scheduler: admit / evict / preempt + chunked prefill.

The scheduler is the software analog of the paper's flexible degree of
parallelism ``z``: a fixed per-step **token budget** is time-multiplexed
over however many requests are in flight, exactly as the FPGA's ``z``
multiply-accumulate lanes are time-multiplexed over a junction of any
size. Knob mapping (see README/ROADMAP):

* ``token_budget``  <->  ``z`` (work issued per hardware cycle / step)
* ``page_size``     <->  junction sub-block granularity (the unit of
  storage allocation; smaller = less fragmentation, more table walks)
* ``max_slots``     <->  pipeline depth (concurrent sequences resident)

Policy (deliberately simple, latency-first):

1. **decode first** — every running, fully-prefilled sequence gets one
   token of budget per step (continuous batching: decode never waits for
   a long prompt to finish prefilling);
2. **chunked prefill** fills the remaining budget, one sequence at a
   time, oldest first, in power-of-two chunks (``1,2,4,..,prefill_chunk``)
   so the jitted chunk function compiles O(log chunk) variants;
3. **admission** when a slot and at least one page are free;
4. **preemption** when a page allocation fails: the *youngest* running
   sequence is evicted (its pages freed) and re-queued for full
   recompute with its generated tokens folded into the prompt — the
   vLLM recompute-preemption policy.

All page accounting goes through ``kv_cache.PageState`` — the scheduler
is the single owner of the allocator, and the property tests drive this
class directly to certify no page leaks or double-frees.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import metrics as obs_metrics
from . import kv_cache
from .kv_cache import PageState


@dataclasses.dataclass
class Request:
    """One generation request (prompt token ids + a budget of new tokens)."""
    req_id: int
    prompt: np.ndarray            # (L,) int32 token ids
    max_new_tokens: int
    # original prompt length; after recompute-preemption the working
    # prompt grows to include already-generated tokens, but outputs are
    # reported relative to this
    orig_prompt_len: int = -1

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.orig_prompt_len < 0:
            self.orig_prompt_len = len(self.prompt)


@dataclasses.dataclass
class ActiveSeq:
    """A request resident in a slot."""
    req: Request
    admit_order: int
    tokens: List[int]             # prompt + generated (grows during decode)
    n_prefilled: int = 0          # tokens whose KV is written to pages

    @property
    def prompt_len(self) -> int:
        return len(self.req.prompt)

    @property
    def n_generated(self) -> int:
        return len(self.tokens) - self.req.orig_prompt_len

    @property
    def prefilling(self) -> bool:
        return self.n_prefilled < self.prompt_len

    @property
    def pending_token(self) -> int:
        """The sampled-but-not-yet-cached token fed to the next decode."""
        return self.tokens[self.n_prefilled]

    @property
    def done(self) -> bool:
        return (not self.prefilling
                and self.n_generated >= self.req.max_new_tokens)


@dataclasses.dataclass
class StepPlan:
    """What one engine step should execute."""
    decode_slots: List[int]
    # (slot, start_position, chunk_tokens) — chunk lengths are powers of two
    prefills: List[Tuple[int, int, np.ndarray]]
    admitted: List[int] = dataclasses.field(default_factory=list)
    preempted: List[int] = dataclasses.field(default_factory=list)
    # speculative draft tokens per decode slot (absent key = no drafts):
    # the engine verifies pending + drafts in one multi-token step
    drafts: Dict[int, List[int]] = dataclasses.field(default_factory=dict)

    @property
    def n_tokens(self) -> int:
        """Tokens of work this plan issues (draft/verify tokens count:
        each draft occupies one lane of the per-step budget exactly like
        a decode or prefill token)."""
        return (len(self.decode_slots)
                + sum(len(d) for d in self.drafts.values())
                + sum(len(c) for _, _, c in self.prefills))

    @property
    def prefill_groups(self) -> List[List[Tuple[int, int, np.ndarray]]]:
        """Prefill work packed for batched execution: chunks of EQUAL
        length from different sequences form one group, executed as one
        B>1 ``paged_step`` call (equal length keeps the batched call
        rectangular with every row fully valid — required for SSM layers,
        whose full-scan path cannot mask a partial row). Chunk lengths
        are powers of two, so there are O(log prefill_chunk) groups."""
        groups: Dict[int, List[Tuple[int, int, np.ndarray]]] = {}
        for item in self.prefills:
            groups.setdefault(len(item[2]), []).append(item)
        return [groups[c] for c in sorted(groups)]


def _pow2_chunk(n: int, cap: int) -> int:
    """Largest power of two <= min(n, cap) (n, cap >= 1)."""
    m = min(n, cap)
    return 1 << (m.bit_length() - 1)


class Scheduler:
    """Owns the slot map and the page allocator; emits per-step plans."""

    def __init__(self, *, slots: int, total_pages: int, page_size: int,
                 max_pages_per_seq: int, token_budget: int,
                 prefill_chunk: int, window: Optional[int] = None,
                 spec_k: int = 0,
                 drafter: Optional[Callable[[Sequence[int], int],
                                            List[int]]] = None,
                 obs: Optional[obs_metrics.Registry] = None):
        if prefill_chunk < 1 or token_budget < 1:
            raise ValueError("prefill_chunk and token_budget must be >= 1")
        if window is not None and window < 1:
            raise ValueError("window must be >= 1 (or None)")
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        self.page_size = page_size
        self.token_budget = token_budget
        self.prefill_chunk = prefill_chunk
        # speculative decode: up to spec_k draft tokens per decode slot,
        # proposed by ``drafter(tokens, k)`` (model-free prompt lookup),
        # verified by the engine in one multi-token step
        self.spec_k = spec_k
        self.drafter = drafter
        # sliding-window page reclamation: when every attention layer's
        # window is <= ``window``, pages whose tokens have all fallen out
        # of the window are freed eagerly after each advance — fixed-pool
        # occupancy per sequence becomes O(window), not O(seq_len). (The
        # table ROW still spans the logical length, so max_pages_per_seq
        # continues to bound sequence length; it's pool pressure —
        # admissions/preemptions — that the window relieves.)
        self.window = window
        self.state: PageState = kv_cache.init_page_state(
            slots, total_pages, max_pages_per_seq)
        self.waiting: Deque[Request] = deque()
        self.active: List[Optional[ActiveSeq]] = [None] * slots
        self._admit_counter = 0
        self.stats = {"admitted": 0, "preempted": 0, "finished": 0,
                      "steps": 0, "reclaimed_pages": 0,
                      "spec_drafted": 0, "spec_accepted": 0}
        # host-side mirrors of the PageState counters: every read on the
        # per-token scheduling path uses these (a device sync per read
        # would put O(slots) round-trips on the decode hot path); the jnp
        # state stays authoritative for the jitted step and the mirrors
        # are asserted against it in check_invariants()
        self._free = total_pages
        self._n_pages = [0] * slots
        self._seq_lens = [0] * slots
        self._first_page = [0] * slots
        # obs: per-phase plan composition + allocator pressure. Recording
        # is host-side (this whole class is host-side); a disabled
        # registry makes every record a no-op.
        self.obs = obs if obs is not None else obs_metrics.disabled_registry()
        self._m_plan = self.obs.counter(
            "sched_plan_tokens_total",
            "tokens of work scheduled per phase (decode/prefill/draft)")
        self._m_events = self.obs.counter(
            "sched_events_total",
            "scheduler lifecycle events (admitted/preempted/finished/"
            "reclaimed_pages)")
        self._m_free = self.obs.gauge(
            "sched_free_pages", "free pages in the KV pool after planning")
        self._m_waiting = self.obs.gauge(
            "sched_waiting_requests", "requests queued but not resident")

    # -- bookkeeping the engine reports back ------------------------------

    def add(self, req: Request) -> None:
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting) or any(
            s is not None for s in self.active)

    def advance_prefill(self, slot: int, n: int) -> None:
        seq = self.active[slot]
        seq.n_prefilled += n
        self.state = kv_cache.advance_fast(self.state, slot, n)
        self._seq_lens[slot] += n
        self._reclaim(slot)

    def append_token(self, slot: int, token: int) -> None:
        """Record a sampled token (after prefill completes or a decode)."""
        self.active[slot].tokens.append(int(token))

    def note_decoded(self, slot: int) -> None:
        """A decode step wrote the pending token's KV at position
        ``n_prefilled``."""
        seq = self.active[slot]
        seq.n_prefilled += 1
        self.state = kv_cache.advance_fast(self.state, slot, 1)
        self._seq_lens[slot] += 1
        self._reclaim(slot)

    def note_verified(self, slot: int, n_written: int,
                      n_accepted: int) -> None:
        """A speculative verify step wrote ``n_written`` tokens of KV
        (pending + drafts) starting at ``n_prefilled``, of which the first
        ``n_accepted`` were committed by greedy verification. Rejected
        tail KV is rolled back via ``kv_cache.truncate`` and its
        now-empty tail pages return to the pool. Window reclamation runs
        only AFTER the rollback: reclaiming against the transiently
        inflated length could free pages that the rollback then brings
        back inside the window."""
        assert 1 <= n_accepted <= n_written
        seq = self.active[slot]
        seq.n_prefilled += n_accepted
        self.state = kv_cache.advance_fast(self.state, slot, n_written)
        rejected = n_written - n_accepted
        if rejected:
            # host mirror of truncate's data-dependent page release
            first = self._first_page[slot]
            end = first + self._n_pages[slot]
            new_len = self._seq_lens[slot] + n_accepted
            keep = min(max(-(-new_len // self.page_size), first), end)
            self.state = kv_cache.truncate_fast(self.state, slot, rejected,
                                           self.page_size)
            self._n_pages[slot] = keep - first
            self._free += end - keep
        self._seq_lens[slot] += n_accepted
        self.stats["spec_accepted"] += n_accepted - 1
        self._reclaim(slot)

    def _reclaim(self, slot: int) -> None:
        """Free leading pages whose tokens are out of every window.

        With L tokens cached, every future query (decode at position >= L,
        or the next prefill chunk starting at L) attends key positions
        ``kpos > pos - window >= L - window`` — positions ``0 .. L-window``
        (count ``L - window + 1``) are dead, and any page lying entirely
        below that boundary is returned to the pool."""
        if self.window is None:
            return
        dead_tokens = self._seq_lens[slot] - self.window + 1
        if dead_tokens <= 0:
            return
        target_first = dead_tokens // self.page_size
        n = target_first - self._first_page[slot]
        if n <= 0:
            return
        self.state = kv_cache.release_prefix_fast(self.state, slot, n)
        self._first_page[slot] = target_first
        self._n_pages[slot] -= n
        self._free += n
        self.stats["reclaimed_pages"] += n
        self._m_events.inc(n, event="reclaimed_pages")

    def finish(self, slot: int) -> Tuple[Request, np.ndarray]:
        """Release the slot; returns (request, generated token ids)."""
        seq = self.active[slot]
        self.state = kv_cache.free_slot(self.state, slot)
        self._release_mirror(slot)
        self.active[slot] = None
        self.stats["finished"] += 1
        self._m_events.inc(event="finished")
        out = np.asarray(seq.tokens[seq.req.orig_prompt_len:], np.int32)
        return seq.req, out

    # -- page helpers -----------------------------------------------------

    def _release_mirror(self, slot: int) -> None:
        self._free += self._n_pages[slot]
        self._n_pages[slot] = 0
        self._seq_lens[slot] = 0
        self._first_page[slot] = 0

    def _pages_for(self, slot: int, new_len: int) -> int:
        """Additional pages needed for ``slot`` to hold ``new_len`` tokens.
        The logical extent already mapped is ``first_page + n_pages``
        (window-reclaimed leading pages count: their positions are dead)."""
        have = self._first_page[slot] + self._n_pages[slot]
        return max(0, kv_cache.pages_needed(new_len, self.page_size) - have)

    def _try_alloc(self, slot: int, need: int,
                   protected: set, preempted: List[int]) -> bool:
        """Allocate ``need`` pages for ``slot``, preempting younger,
        unprotected sequences if the pool is exhausted."""
        if self._first_page[slot] + self._n_pages[slot] + need \
                > self.state.max_pages_per_seq:
            raise RuntimeError(
                f"slot {slot} exceeds max_pages_per_seq="
                f"{self.state.max_pages_per_seq}")
        while self._free < need:
            victim = self._youngest_victim(exclude=protected | {slot})
            if victim is None:
                return False
            self._preempt(victim)
            preempted.append(victim)
        if need:
            self.state = kv_cache.alloc_pages(self.state, slot, need)
            self._free -= need
            self._n_pages[slot] += need
        return True

    def _youngest_victim(self, exclude: set) -> Optional[int]:
        """Youngest preemptible sequence that actually owns pages.
        Zero-page residents (e.g. a sequence admitted earlier in this
        same ``schedule()`` call, before its first chunk allocated
        anything) are skipped: preempting one frees nothing — it would
        be evicted and re-queued for no pool gain."""
        cands = [(s.admit_order, i) for i, s in enumerate(self.active)
                 if s is not None and i not in exclude
                 and self._n_pages[i] > 0]
        return max(cands)[1] if cands else None

    def _preempt(self, slot: int) -> None:
        """Evict ``slot`` for recompute: its pages go back to the pool and
        the request is re-queued (front) with generated tokens folded into
        the prompt, so no sampled output is lost."""
        seq = self.active[slot]
        self.state = kv_cache.free_slot(self.state, slot)
        self._release_mirror(slot)
        self.active[slot] = None
        # max_new_tokens stays the *original* budget: n_generated keeps
        # counting from orig_prompt_len, so already-generated tokens now
        # living in the recompute prompt still count toward it
        self.waiting.appendleft(Request(
            req_id=seq.req.req_id,
            prompt=np.asarray(seq.tokens, np.int32),
            max_new_tokens=seq.req.max_new_tokens,
            orig_prompt_len=seq.req.orig_prompt_len))
        self.stats["preempted"] += 1

    # -- the step plan ----------------------------------------------------

    def schedule(self) -> StepPlan:
        plan = StepPlan(decode_slots=[], prefills=[])
        budget = self.token_budget
        self.stats["steps"] += 1

        # 1) admissions: empty slots + at least one free page each
        free_slots = [i for i, s in enumerate(self.active) if s is None]
        while self.waiting and free_slots and \
                self._free > len(plan.admitted):
            slot = free_slots.pop(0)
            req = self.waiting.popleft()
            self.active[slot] = ActiveSeq(
                req=req, admit_order=self._admit_counter,
                tokens=list(map(int, req.prompt)))
            self._admit_counter += 1
            self.stats["admitted"] += 1
            plan.admitted.append(slot)

        # 2) decode: every running fully-prefilled sequence, one token each
        protected: set = set()
        decode_slots = sorted(
            (s.admit_order, i) for i, s in enumerate(self.active)
            if s is not None and not s.prefilling and not s.done)
        for _, slot in decode_slots:
            if budget <= 0:
                break
            seq = self.active[slot]
            if seq is None:          # preempted by an earlier allocation
                continue
            need = self._pages_for(slot, seq.n_prefilled + 1)
            if not self._try_alloc(slot, need, protected, plan.preempted):
                continue             # pool exhausted even after preemption
            drafts = self._propose_drafts(slot, budget)
            while drafts and not self._alloc_extra(
                    slot, self._pages_for(slot,
                                          seq.n_prefilled + 1
                                          + len(drafts))):
                drafts.pop()         # shrink drafts to what fits for free
            if drafts:
                plan.drafts[slot] = drafts
                self.stats["spec_drafted"] += len(drafts)
            plan.decode_slots.append(slot)
            protected.add(slot)
            budget -= 1 + len(drafts)

        # 3) chunked prefill with the remaining budget, oldest first
        prefillers = sorted(
            (s.admit_order, i) for i, s in enumerate(self.active)
            if s is not None and s.prefilling)
        for _, slot in prefillers:
            if budget <= 0:
                break
            seq = self.active[slot]
            if seq is None:
                continue
            remaining = seq.prompt_len - seq.n_prefilled
            chunk = _pow2_chunk(remaining, min(budget, self.prefill_chunk))
            need = self._pages_for(slot, seq.n_prefilled + chunk)
            while chunk > 1 and not self._can_fit(slot, need, protected):
                chunk //= 2
                need = self._pages_for(slot, seq.n_prefilled + chunk)
            if not self._try_alloc(slot, need, protected, plan.preempted):
                continue
            # _try_alloc never preempts `slot` itself (it is excluded from
            # victim selection), so the sequence must still be resident
            assert self.active[slot] is seq
            start = seq.n_prefilled
            toks = np.asarray(seq.tokens[start:start + chunk], np.int32)
            plan.prefills.append((slot, start, toks))
            protected.add(slot)
            budget -= chunk

        self._m_plan.inc(len(plan.decode_slots), phase="decode")
        self._m_plan.inc(sum(len(c) for _, _, c in plan.prefills),
                         phase="prefill")
        self._m_plan.inc(sum(len(d) for d in plan.drafts.values()),
                         phase="draft")
        self._m_events.inc(len(plan.admitted), event="admitted")
        self._m_events.inc(len(plan.preempted), event="preempted")
        self._m_free.set(self._free)
        self._m_waiting.set(len(self.waiting))
        return plan

    def _propose_drafts(self, slot: int, budget: int) -> List[int]:
        """Draft tokens for a decode slot, capped so the verify step can
        never overshoot: the generation budget (a verify emitting m+1
        tokens must have m+1 <= remaining), the step token budget (the
        verify consumes 1 + k lanes), and ``spec_k`` itself."""
        if self.spec_k <= 0 or self.drafter is None:
            return []
        seq = self.active[slot]
        remaining = seq.req.max_new_tokens - seq.n_generated
        k = min(self.spec_k, budget - 1, remaining - 1)
        if k <= 0:
            return []
        return [int(t) for t in self.drafter(seq.tokens, k)][:k]

    def _alloc_extra(self, slot: int, need: int) -> bool:
        """Allocate ``need`` pages for optional (draft) tokens — never
        preempts and never exceeds the slot's table row: draft KV is a
        throughput bet, not mandatory work, so it only takes pages that
        are free anyway."""
        if need == 0:
            return True
        if need > self._free or self._first_page[slot] \
                + self._n_pages[slot] + need > self.state.max_pages_per_seq:
            return False
        self.state = kv_cache.alloc_pages(self.state, slot, need)
        self._free -= need
        self._n_pages[slot] += need
        return True

    def _can_fit(self, slot: int, need: int, protected: set) -> bool:
        """Would ``need`` pages fit, counting preemptible victims' pages?"""
        avail = self._free
        for i, s in enumerate(self.active):
            if s is not None and i not in protected and i != slot:
                avail += self._n_pages[i]
        return avail >= need

    # -- invariant check (used by the property tests) ----------------------

    def check_invariants(self) -> None:
        st = self.state
        total = st.total_pages
        free_n = st.free()
        # host mirrors must agree with the device-side allocator state
        assert free_n == self._free, \
            f"free mirror diverged: host={self._free} device={free_n}"
        assert list(np.asarray(st.n_pages)) == self._n_pages, \
            "n_pages mirror diverged"
        assert list(np.asarray(st.seq_lens)) == self._seq_lens, \
            "seq_lens mirror diverged"
        assert list(np.asarray(st.first_page)) == self._first_page, \
            "first_page mirror diverged"
        owned = int(np.sum(np.asarray(st.n_pages)))
        assert free_n + owned == total, \
            f"page leak: free={free_n} owned={owned} total={total}"
        seen: set = set(np.asarray(st.free_stack)[:free_n].tolist())
        assert len(seen) == free_n, "duplicate ids on the free stack"
        table = np.asarray(st.page_table)
        n_pages = np.asarray(st.n_pages)
        first = np.asarray(st.first_page)
        for i in range(st.slots):
            lo, hi = int(first[i]), int(first[i] + n_pages[i])
            row = table[i][lo:hi]
            assert (row >= 0).all() and (row < total).all(), \
                f"slot {i} maps invalid pages {row}"
            for p in row.tolist():
                assert p not in seen, f"page {p} double-mapped"
                seen.add(p)
            assert (table[i][:lo] == -1).all(), \
                f"slot {i} has mapped pages below first_page"
            assert (table[i][hi:] == -1).all(), \
                f"slot {i} has mapped pages beyond its extent"
            assert int(st.seq_lens[i]) <= hi * self.page_size
            # rollback safety: truncate must never pull the write head
            # behind the first still-mapped page (positions below it were
            # window-reclaimed and are unrecoverable), and a slot that
            # owns tokens must still own the pages that hold them
            assert int(st.seq_lens[i]) >= lo * self.page_size, \
                f"slot {i} truncated into reclaimed positions"
            if self.window is not None and n_pages[i] > 0:
                # reclamation keeps every in-window position mapped
                dead = int(st.seq_lens[i]) - self.window + 1
                assert lo * self.page_size <= max(0, dead), \
                    f"slot {i} reclaimed live pages"
        assert seen == set(range(total)), "pages lost from the pool"
