"""ServingEngine: continuous-batching inference over the paged cache.

One ``step()`` executes a scheduler plan: chunked prefill for sequences
still consuming their prompt (through the same fused csd_matmul junctions
as training; attention over previously-cached pages by gather) interleaved
with batched decode for every running sequence (through the
paged-attention kernel — Pallas on TPU, gather-XLA elsewhere). Fixed
accelerator memory (the page pool) serves any number / length of requests
by time-multiplexing the per-step token budget — the serving analog of the
paper's flexible-``z`` junction hardware.

Two throughput multipliers keep that budget (the ``z`` lanes) busy when
decode dominates:

* **speculative decode** (``spec_k > 0``): a model-free prompt-lookup
  drafter proposes up to ``k`` continuation tokens per slot; the engine
  verifies pending + drafts in ONE multi-token ``paged_step`` (the chunk
  path prefill already uses) and accepts the longest greedily-matching
  prefix, rolling rejected KV back via ``kv_cache.truncate``. Greedy
  acceptance keeps the output token-identical to plain decode.
* **batched prefill**: the scheduler packs equal-length power-of-two
  chunks from different sequences into one B>1 call, collapsing
  O(slots) sequential chunk launches into O(log prefill_chunk) batched
  ones.

The jitted step function has one signature for both phases; distinct chunk
lengths trace separate executables (the scheduler emits power-of-two
chunks, so there are O(log prefill_chunk) of them, plus at most one
verify shape at ``1 + spec_k``). Prompt chunks are exact — rows are
either fully valid or fully inactive, never partially padded — so SSM
recurrent state advances over real tokens only and stays bit-identical
to a full-sequence prefill.

Sharded decode (``mesh=...``): the engine jits ``LM.paged_step`` once
under the SERVE mesh rules — params placed by ``policy.param_pspecs``
(block-sparse slabs row-sharded on the ``slab`` axis so every junction
runs the model-parallel ``csd_matmul`` shard_map), the paged KV pools
partitioned on the same axis (``policy.paged_cache_pspecs``: pages are
the cache's sequence axis -> context-parallel KV; pick ``total_pages ≡ -1
mod axis_size`` so the +1 trash page divides). Scheduling stays on the
host and is byte-identical to the single-device engine, so sharded decode
is token-parity testable against it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.quant import QuantConfig, quantize_tree
from ..nn.common import dtype_of, mesh_context
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .scheduler import Request, Scheduler, StepPlan
from .spec import PromptLookupDrafter


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine knobs. ``token_budget`` is the per-step work quantum (the
    paper's degree-of-parallelism ``z``); ``page_size`` the KV allocation
    granularity; ``max_slots`` the number of resident sequences."""
    max_slots: int = 8
    page_size: int = 16
    total_pages: int = 128
    max_pages_per_seq: int = 32
    token_budget: int = 64
    prefill_chunk: int = 32
    backend: str = "auto"       # auto | xla | pallas (paged decode kernel)
    interpret: bool = False     # Pallas interpret mode (CPU tests)
    greedy: bool = True
    temperature: float = 1.0
    # speculative decode: up to spec_k prompt-lookup draft tokens per
    # decode slot, verified in one multi-token step (0 = off). Greedy
    # only, and auto-disabled for stacks with recurrent (mamba) layers:
    # KV pages can be truncated after a rejected draft, a recurrence
    # that already stepped over it cannot.
    spec_k: int = 0
    spec_ngram: int = 3         # longest suffix n-gram the drafter matches
    # observability: ``metrics`` routes the engine's host-side counters/
    # gauges/histograms through the process obs registry (False = no-op
    # registry; the jitted step functions are identical either way —
    # recording never enters a traced program). ``metrics_port`` serves
    # the registry at http://127.0.0.1:<port>/metrics (0 = ephemeral).
    metrics: bool = True
    metrics_port: Optional[int] = None
    # int8 inference (core.quant.QuantConfig): quantize the checkpoint's
    # block-sparse slabs per-block at load (weights=True) and/or store KV
    # pages as int8 with per-token scales (kv=True). None falls back to
    # the model's SparsityConfig.quant, so a model built with the knob
    # serves quantized without any engine-side flag.
    quant: Optional["QuantConfig"] = None


class ServingEngine:
    """Continuous-batching engine: add requests any time, call ``step()``
    (or ``run()``) and collect finished generations."""

    def __init__(self, model, params, config: Optional[EngineConfig] = None,
                 *, key: Optional[jax.Array] = None, mesh=None, rules=None,
                 registry: Optional[obs_metrics.Registry] = None,
                 **overrides):
        cfg = config or EngineConfig(**overrides)
        if overrides and config is not None:
            raise ValueError("pass EngineConfig or overrides, not both")
        mc = model.cfg
        if getattr(mc, "enc_dec", None) is not None:
            raise NotImplementedError(
                "paged serving supports decoder-only models (enc-dec "
                "serves through the legacy loop)")
        if mc.input_mode != "tokens":
            raise NotImplementedError(
                "paged serving expects token inputs")
        moe = getattr(mc, "moe", None)
        if moe is not None and moe.capacity_factor * moe.top_k \
                < moe.n_routed:
            # the batched decode step runs garbage rows for inactive
            # slots; with finite expert capacity those rows would compete
            # with (and can evict) real tokens from their expert buckets,
            # silently corrupting active requests. Serving MoE requires
            # dropless decode: capacity_factor >= n_routed / top_k.
            raise NotImplementedError(
                f"paged serving with capacity-constrained MoE "
                f"(capacity_factor={moe.capacity_factor}): rebuild the "
                f"model with capacity_factor >= n_routed/top_k = "
                f"{moe.n_routed / moe.top_k:.1f} (dropless decode) or "
                f"use the legacy dense-cache loop")
        self.model = model
        self.config = cfg
        # -- int8 inference: quantize once at load, serve quantized ------
        # Training stays full width; the engine is the one place the
        # QuantConfig is applied. quantize_tree rewrites every block-sparse
        # slab to int8 + per-block scales and extends the sharding spec in
        # lock-step, so the mesh path below places the scale leaves with
        # the same rules as their slabs.
        qc = cfg.quant if cfg.quant is not None \
            else getattr(getattr(mc, "sparsity", None), "quant", None)
        self.quant = qc
        spec = model.spec()
        if qc is not None and qc.weights:
            params, spec = quantize_tree(params, spec)
        self._spec = spec
        self.params = params
        self.key = key if key is not None else jax.random.key(0)
        # speculative decode is greedy-only (acceptance compares argmax
        # continuations) and needs rollback: paged KV truncates, mamba
        # recurrent state does not — clamp k to 0 for recurrent stacks
        self.spec_k = cfg.spec_k if cfg.greedy \
            and "mamba" not in mc.layer_kinds else 0
        drafter = PromptLookupDrafter(cfg.spec_ngram) if self.spec_k \
            else None
        # -- observability: all recording is host-side, around (never
        # inside) the jitted step — with metrics off the same executables
        # compile byte-identically (tests/test_obs.py proves it on HLO)
        self.obs = obs_metrics.resolve(registry, enabled=cfg.metrics)
        self._m_req = self.obs.counter(
            "serving_requests_total",
            "request lifecycle events (added/finished/rejected)")
        self._m_tok = self.obs.counter(
            "serving_tokens_total",
            "tokens processed per phase (prefill/decode/spec_draft)")
        self._m_emit = self.obs.counter(
            "serving_emitted_tokens_total", "generated tokens emitted")
        self._m_spec = self.obs.counter(
            "serving_spec_tokens_total",
            "speculative draft tokens by outcome "
            "(proposed/accepted/rolled_back)")
        self._m_ttft = self.obs.histogram(
            "serving_ttft_seconds", "time from add_request to first token")
        self._m_itl = self.obs.histogram(
            "serving_itl_seconds",
            "inter-token latency per slot (consecutive emitted tokens)")
        self._m_step = self.obs.histogram(
            "serving_step_seconds", "engine step wall-clock duration")
        self._m_tps = self.obs.gauge(
            "serving_tokens_per_s",
            "instantaneous step throughput (plan tokens / step seconds)")
        self._m_queue = self.obs.gauge(
            "serving_queue_depth", "requests waiting for admission")
        self._m_slots = self.obs.gauge(
            "serving_active_slots", "resident sequences")
        self._m_occ = self.obs.gauge(
            "serving_page_occupancy", "fraction of the KV page pool in use")
        self._m_pages_hw = self.obs.gauge(
            "serving_pages_highwater", "max pages ever in use at once")
        self.sched = Scheduler(
            slots=cfg.max_slots, total_pages=cfg.total_pages,
            page_size=cfg.page_size,
            max_pages_per_seq=cfg.max_pages_per_seq,
            token_budget=cfg.token_budget,
            prefill_chunk=cfg.prefill_chunk,
            window=self._reclaim_window(mc),
            spec_k=self.spec_k, drafter=drafter, obs=self.obs)
        self._http = obs_metrics.serve_http(self.obs, cfg.metrics_port) \
            if cfg.metrics_port is not None else None
        # -- measured decode dispatch (PR 10): surface which decode
        # kernel this engine's regime will run. The authoritative consult
        # happens at trace time inside paged_decode_attention (so it sees
        # the actual q dtype); this lookup records the decision as an obs
        # counter so ``repro.obs.dump`` shows tuned vs heuristic serving.
        heads = getattr(mc, "n_heads", 0)
        if cfg.backend == "auto" and heads:
            from .. import tune
            hkv = getattr(mc, "n_kv_heads", heads) or heads
            ent = tune.decide_decode(
                b=cfg.max_slots, h_kv=hkv, groups=heads // hkv,
                head_dim=mc.head_dim, page_size=cfg.page_size,
                n_pages=cfg.max_pages_per_seq, pool=cfg.total_pages,
                quant=bool(qc is not None and qc.kv),
                dtype=str(dtype_of(mc)))
            self.obs.counter(
                "repro_tune_engine_decode_total",
                "engine decode-kernel selection (tuned=cache hit)",
            ).inc(backend=ent["backend"] if ent else "heuristic",
                  tuned=str(ent is not None).lower())
        self.cache = model.stack.init_paged_cache(
            cfg.max_slots, cfg.total_pages, cfg.page_size, dtype_of(mc),
            quant_kv=bool(qc is not None and qc.kv))
        self._next_id = 0
        self.outputs: Dict[int, np.ndarray] = {}
        # per-request admission timestamps, pruned at first token (TTFT
        # recorded) and again at finish — bounded by in-flight requests.
        # TTFT/ITL themselves live in the obs histograms (label-free, so
        # state cannot grow with request count — the PR-7 ``ttft`` dict
        # grew forever).
        self._t_added: Dict[int, float] = {}
        self._last_tok: List[Optional[float]] = [None] * cfg.max_slots

        self.mesh = mesh
        self.rules = rules
        if mesh is not None:
            from ..sharding import policy
            if rules is None:
                self.rules = policy.rules_for("decode", cfg.max_slots,
                                              mesh, mc)
            pspec = policy.param_pspecs(self._spec, self.rules)
            self._param_sh = policy.named(mesh, pspec, params)
            cspec = policy.paged_cache_pspecs(self.cache, self.rules)
            self._cache_sh = policy.named(mesh, cspec, self.cache)
            self.params = jax.device_put(params, self._param_sh)
            self.cache = jax.device_put(self.cache, self._cache_sh)

        def raw_step(params, cache, page_table, tokens, pos, n_new,
                     slot_ids):
            return model.paged_step(
                params, tokens, pos, n_new, cache, page_table, slot_ids,
                backend=cfg.backend, interpret=cfg.interpret)

        def raw_verify(params, cache, page_table, tokens, pos, n_new,
                       slot_ids):
            # speculative verify: logits at EVERY chunk position, so the
            # host can accept the longest greedily-matching draft prefix
            return model.paged_step(
                params, tokens, pos, n_new, cache, page_table, slot_ids,
                backend=cfg.backend, interpret=cfg.interpret,
                all_logits=True)

        if mesh is not None:
            # one executable per phase under the SERVE mesh: params and the
            # paged pools keep their placement across steps, logits come
            # back replicated for host-side sampling
            jit_kw = dict(
                donate_argnums=(1,),
                in_shardings=(self._param_sh, self._cache_sh, None, None,
                              None, None, None),
                out_shardings=(None, self._cache_sh))
            self._step = jax.jit(raw_step, **jit_kw)
            self._verify = jax.jit(raw_verify, **jit_kw)
        else:
            self._step = jax.jit(raw_step, donate_argnums=(1,))
            self._verify = jax.jit(raw_verify, donate_argnums=(1,))

    @staticmethod
    def _reclaim_window(mc) -> Optional[int]:
        """Sliding-window page reclamation is sound only when EVERY
        attention layer is windowed (all page pools share one page table,
        so a page may be freed only when no layer can still read it);
        mamba layers carry no pages and don't constrain it."""
        kinds = set(mc.layer_kinds)
        if mc.attn_window is not None and kinds <= {"local", "mamba"} \
                and "local" in kinds and mc.hybrid is None:
            return int(mc.attn_window)
        return None

    def _in_ctx(self):
        return mesh_context(self.mesh, self.rules) if self.mesh is not None \
            else contextlib.nullcontext()

    # -- request intake ----------------------------------------------------

    def _reject(self, reason: str, msg: str) -> ValueError:
        """Admission rejection: count it, return the error to raise."""
        self._m_req.inc(event="rejected", reason=reason)
        return ValueError(msg)

    def add_request(self, prompt, max_new_tokens: int,
                    req_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            raise self._reject("empty_prompt", "empty prompt")
        if max_new_tokens < 1:
            raise self._reject("bad_budget",
                               "max_new_tokens must be >= 1")
        need = len(prompt) + max_new_tokens
        cap = min(self.config.max_pages_per_seq,
                  self.config.total_pages) * self.config.page_size
        if need > cap:
            raise self._reject(
                "too_long",
                f"request needs {need} tokens but a sequence can hold at "
                f"most {cap} (min(max_pages_per_seq, total_pages) * "
                f"page_size)")
        if req_id is None:
            req_id = self._next_id
        elif any(r.req_id == req_id for r in self.sched.waiting) or any(
                s is not None and s.req.req_id == req_id
                for s in self.sched.active):
            # a duplicate would silently cross-wire outputs/_t_added
            # between the two requests (dict keys collide)
            raise self._reject(
                "duplicate_id",
                f"req_id {req_id} is already queued or in flight")
        self._next_id = max(self._next_id, req_id) + 1
        self.sched.add(Request(req_id=req_id, prompt=prompt,
                               max_new_tokens=max_new_tokens))
        self._t_added[req_id] = time.perf_counter()
        self._m_req.inc(event="added")
        return req_id

    # -- sampling ----------------------------------------------------------

    def _sample(self, logits: jax.Array, slot: int) -> int:
        if self.config.greedy:
            return int(jnp.argmax(logits))
        seq = self.sched.active[slot]
        # per-request stream, folded by absolute position: a preempted and
        # recomputed sequence re-draws identical tokens
        k = jax.random.fold_in(self.key, seq.req.req_id)
        k = jax.random.fold_in(k, len(seq.tokens))
        return int(jax.random.categorical(
            k, logits.astype(jnp.float32) / self.config.temperature))

    def _emit(self, slot: int) -> None:
        seq = self.sched.active[slot]
        now = time.perf_counter()
        if seq.n_generated == 1:
            # first token of this request: record TTFT and drop the
            # admission timestamp (pop = the leak fix; after a preemption
            # recompute n_generated > 1, so nothing double-records)
            t0 = self._t_added.pop(seq.req.req_id, None)
            if t0 is not None:
                self._m_ttft.observe(now - t0)
        prev = self._last_tok[slot]
        if prev is not None:
            self._m_itl.observe(now - prev)
        self._last_tok[slot] = now
        self._m_emit.inc()

    # -- the step ----------------------------------------------------------

    def step(self) -> Tuple[StepPlan, List[Tuple[int, np.ndarray]]]:
        """Run one engine step; returns (plan, finished) where finished is
        a list of (req_id, generated token ids)."""
        t0 = time.perf_counter()
        with self._in_ctx(), obs_trace.span("engine/step",
                                            registry=self.obs):
            plan, finished = self._step_impl()
        dt = time.perf_counter() - t0
        self._m_step.observe(dt)
        if plan.n_tokens and dt > 0:
            self._m_tps.set(plan.n_tokens / dt)
        self._m_queue.set(len(self.sched.waiting))
        self._m_slots.set(sum(s is not None for s in self.sched.active))
        total = self.config.total_pages
        used = total - self.sched._free
        self._m_occ.set(used / total)
        self._m_pages_hw.set_max(used)
        return plan, finished

    def _step_impl(self) -> Tuple[StepPlan, List[Tuple[int, np.ndarray]]]:
        cfg = self.config
        plan = self.sched.schedule()

        # a re-admitted slot may have hosted another sequence: clear its
        # recurrent (SSM) state before the first prefill chunk touches it
        for slot in plan.admitted:
            self.cache = self.model.stack.reset_slot_state(self.cache,
                                                           slot)
            self._last_tok[slot] = None

        slots = cfg.max_slots
        if plan.prefill_groups:
            n_pf = sum(len(toks) for group in plan.prefill_groups
                       for _, _, toks in group)
            self._m_tok.inc(n_pf, phase="prefill")
        for group in plan.prefill_groups:
            # equal-length chunks from different sequences packed into
            # ONE batched call (rows are slot-indexed; slots without a
            # chunk this step ride along inactive with n_new == 0, so
            # there are O(log prefill_chunk) compiled shapes, not
            # O(slots) sequential launches)
            c = len(group[0][2])
            tokens = np.zeros((slots, c), np.int32)
            pos = np.zeros((slots,), np.int32)
            n_new = np.zeros((slots,), np.int32)
            for slot, start, toks in group:
                tokens[slot, :len(toks)] = toks
                pos[slot] = start
                n_new[slot] = len(toks)
            with obs_trace.span("engine/prefill", registry=self.obs,
                                chunk=c, rows=len(group)):
                logits, self.cache = self._step(
                    self.params, self.cache, self.sched.state.page_table,
                    jnp.asarray(tokens), jnp.asarray(pos),
                    jnp.asarray(n_new),
                    jnp.arange(slots, dtype=jnp.int32))
            for slot, start, toks in group:
                self.sched.advance_prefill(slot, len(toks))
                seq = self.sched.active[slot]
                if not seq.prefilling \
                        and len(seq.tokens) == seq.n_prefilled:
                    # prompt fully cached and no pending token yet (also
                    # true right after a preemption recompute): sample it
                    self.sched.append_token(
                        slot, self._sample(logits[slot, 0], slot))
                    self._emit(slot)

        kmax = max((len(plan.drafts.get(s, ()))
                    for s in plan.decode_slots), default=0)
        if plan.decode_slots:
            self._m_tok.inc(len(plan.decode_slots), phase="decode")
        if plan.decode_slots and kmax == 0:
            # plain decode (C == 1): the PR-3 baseline path, bit-for-bit
            tokens = np.zeros((slots, 1), np.int32)
            n_new = np.zeros((slots,), np.int32)
            for s in plan.decode_slots:
                tokens[s, 0] = self.sched.active[s].pending_token
                n_new[s] = 1
            with obs_trace.span("engine/decode", registry=self.obs,
                                rows=len(plan.decode_slots)):
                logits, self.cache = self._step(
                    self.params, self.cache, self.sched.state.page_table,
                    jnp.asarray(tokens), self.sched.state.seq_lens,
                    jnp.asarray(n_new),
                    jnp.arange(slots, dtype=jnp.int32))
            greedy_toks = np.asarray(
                jnp.argmax(logits[:, 0, :], axis=-1)) \
                if cfg.greedy else None
            for s in plan.decode_slots:
                self.sched.note_decoded(s)
                tok = int(greedy_toks[s]) if cfg.greedy \
                    else self._sample(logits[s, 0], s)
                self.sched.append_token(s, tok)
                self._emit(s)
        elif plan.decode_slots:
            self._verify_decode(plan)

        finished = []
        for s in range(cfg.max_slots):
            seq = self.sched.active[s]
            if seq is not None and seq.done:
                req, gen = self.sched.finish(s)
                self.outputs[req.req_id] = gen
                self._t_added.pop(req.req_id, None)
                self._last_tok[s] = None
                self._m_req.inc(event="finished")
                finished.append((req.req_id, gen))
        return plan, finished

    def _verify_decode(self, plan: StepPlan) -> None:
        """Speculative decode: verify pending + draft tokens for every
        decode slot in ONE multi-token ``paged_step`` (``n_new`` = 1 +
        drafts per row, chunk padded to ``1 + spec_k`` so exactly one
        extra executable is ever compiled). Greedy verification accepts
        the longest prefix of drafts matching the model's own argmax
        continuations — so accepted tokens are exactly what plain decode
        would have produced — and rejected tail KV rolls back through
        ``kv_cache.truncate``."""
        slots = self.config.max_slots
        c = 1 + self.spec_k
        tokens = np.zeros((slots, c), np.int32)
        n_new = np.zeros((slots,), np.int32)
        n_prop = 0
        for s in plan.decode_slots:
            row = [self.sched.active[s].pending_token] \
                + plan.drafts.get(s, [])
            tokens[s, :len(row)] = row
            n_new[s] = len(row)
            n_prop += len(row) - 1
        if n_prop:
            self._m_spec.inc(n_prop, result="proposed")
            self._m_tok.inc(n_prop, phase="spec_draft")
        with obs_trace.span("engine/verify", registry=self.obs,
                            rows=len(plan.decode_slots), chunk=c):
            logits, self.cache = self._verify(
                self.params, self.cache, self.sched.state.page_table,
                jnp.asarray(tokens), self.sched.state.seq_lens,
                jnp.asarray(n_new), jnp.arange(slots, dtype=jnp.int32))
        greedy = np.asarray(jnp.argmax(logits, axis=-1))    # (slots, C)
        for s in plan.decode_slots:
            drafts = plan.drafts.get(s, [])
            g = greedy[s]
            m = 0
            while m < len(drafts) and drafts[m] == int(g[m]):
                m += 1
            if m:
                self._m_spec.inc(m, result="accepted")
            if len(drafts) - m:
                self._m_spec.inc(len(drafts) - m, result="rolled_back")
            # committed: the pending token + m accepted drafts; emitted:
            # their greedy continuations g[0..m] (g[m] is the bonus token
            # from the last accepted position — it becomes the new
            # pending token, exactly as in plain decode)
            self.sched.note_verified(s, n_written=1 + len(drafts),
                                     n_accepted=1 + m)
            for i in range(m + 1):
                self.sched.append_token(s, int(g[i]))
                self._emit(s)

    # -- drain loop --------------------------------------------------------

    def run(self, prompts: Sequence, max_new_tokens,
            max_steps: int = 100_000) -> List[np.ndarray]:
        """Submit ``prompts`` (list of 1-D int arrays) and step until all
        finish; returns generated ids per prompt, in submission order.
        ``max_new_tokens`` is an int or a per-prompt list."""
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        ids = [self.add_request(p, n)
               for p, n in zip(prompts, max_new_tokens)]
        steps = 0
        while self.sched.has_work():
            plan, _ = self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("engine failed to drain (stuck plan?)")
            if plan.n_tokens == 0 and not plan.admitted \
                    and not plan.preempted:
                # a preempt-only plan is NOT stuck: preemption just freed
                # pages (after the allocations that triggered it failed),
                # so the next step can admit/prefill into them
                raise RuntimeError(
                    "scheduler produced an empty plan with work pending — "
                    "page pool too small for any resident sequence")
        # pop: a long-lived engine must not hold every generation forever
        # (latency telemetry lives in the obs registry histograms, which
        # are fixed-size — nothing here grows with request count)
        return [self.outputs.pop(i) for i in ids]
