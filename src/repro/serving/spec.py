"""Model-free speculative drafting: prompt-lookup (n-gram) proposal.

The paper's flexible-``z`` junction keeps a fixed pool of multiply-
accumulate lanes busy every cycle regardless of junction size; the
serving engine's per-step token budget is the software analog of those
lanes. Plain decode issues exactly ONE token per sequence per step, so
whenever decode dominates, most of the budget idles. Speculative decode
refills it: a cheap drafter proposes up to ``k`` continuation tokens per
sequence, and the engine verifies pending + drafts in ONE multi-token
``paged_step`` (the same chunk path prefill uses), accepting the longest
greedily-matching prefix. Greedy acceptance makes the output
token-identical to plain decode — speculation changes throughput, never
content — which is exactly the invariant the serving certification
tests pin.

The drafter here is the simplest one that wins in practice on
repetitive text (prompt-lookup decoding): match the sequence's own
trailing n-gram against its earlier history and propose the tokens that
followed the most recent earlier occurrence. No draft model, no extra
parameters, no device work — the proposal is pure host-side list
matching, so a miss costs only the wasted verify lanes.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["PromptLookupDrafter", "propose_drafts"]


def propose_drafts(tokens: Sequence[int], k: int, *, max_ngram: int = 3,
                   min_ngram: int = 1) -> List[int]:
    """Propose up to ``k`` draft tokens continuing ``tokens``.

    Tries suffix n-grams from ``max_ngram`` down to ``min_ngram``; for
    the first length with an earlier occurrence in the history, returns
    the (up to ``k``) tokens that followed the MOST RECENT such
    occurrence. Returns ``[]`` on no match — the engine then falls back
    to plain single-token decode for that slot.

    The match runs as ``n`` vectorised comparisons over the history (this
    sits on the per-slot-per-step decode hot path; a Python scan over
    positions costs more than the drafts save).
    """
    if k <= 0:
        return []
    toks = np.asarray(tokens, np.int64)
    n_tok = len(toks)
    for n in range(max_ngram, min_ngram - 1, -1):
        if n_tok <= n:
            continue
        pat = toks[-n:]
        # candidate starts 0..n_tok-n-1 (the suffix occurrence itself is
        # excluded); overlapping matches are fine: they capture periodic
        # runs
        hit = toks[:n_tok - n] == pat[0]
        for j in range(1, n):
            hit &= toks[j:j + n_tok - n] == pat[j]
        idx = np.flatnonzero(hit)
        if idx.size:
            i = int(idx[-1])          # most recent occurrence
            return [int(t) for t in toks[i + n:i + n + k]]
    return []


class PromptLookupDrafter:
    """Callable drafter the scheduler holds: ``drafter(tokens, k)``."""

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def __call__(self, tokens: Sequence[int], k: int) -> List[int]:
        return propose_drafts(tokens, k, max_ngram=self.max_ngram,
                              min_ngram=self.min_ngram)
