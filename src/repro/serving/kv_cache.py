"""Paged KV cache: fixed-size pages, free-list allocator, jnp page tables.

The serving analog of the paper's junction time-multiplexing: a fixed pool
of ``total_pages`` KV pages (fixed hardware) serves sequences of any length
by mapping logical token positions to physical pages through per-sequence
page tables. All state lives in jnp arrays and every operation is a pure
function ``PageState -> PageState``, so the allocator can run inside or
outside ``jit`` (page counts per call are compile-time static, mirroring
the paper's compile-time-static sparsity patterns).

Layout conventions shared with the model stack:

* per-layer page buffers are ``(total_pages + 1, page_size, Hkv, Dh)`` —
  the **last** page is a write-discard ("trash") page that absorbs writes
  from inactive batch rows, so the jitted step never branches on activity;
* ``page_table`` is ``(slots, max_pages_per_seq)`` int32 with ``-1`` for
  unmapped entries; valid physical page ids are in ``[0, total_pages)``;
* a sequence occupying ``n`` tokens maps the logical pages
  ``first_page[slot] .. ceil(n/page_size)-1`` of its table row, in order —
  token position ``p`` lives at ``(page_table[slot, p // page_size],
  p % page_size)``. ``first_page`` is 0 until sliding-window reclamation
  (``release_prefix``) frees fully-out-of-window leading pages; their
  table entries return to ``-1`` (reads of those positions are masked by
  the attention window, writes land on the trash page).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PageState:
    """Allocator + mapping state for one page pool (all jnp arrays)."""

    page_table: jax.Array  # (slots, max_pages_per_seq) int32, -1 = unmapped
    n_pages: jax.Array     # (slots,) int32 — pages owned per slot
    seq_lens: jax.Array    # (slots,) int32 — tokens written per slot
    free_stack: jax.Array  # (total_pages,) int32 — free ids, top at count-1
    free_count: jax.Array  # () int32
    first_page: jax.Array  # (slots,) int32 — first still-mapped logical page

    # -- pytree ------------------------------------------------------------

    def tree_flatten(self):
        return ((self.page_table, self.n_pages, self.seq_lens,
                 self.free_stack, self.free_count, self.first_page), None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    # -- host-side views ---------------------------------------------------

    @property
    def total_pages(self) -> int:
        return self.free_stack.shape[0]

    @property
    def slots(self) -> int:
        return self.page_table.shape[0]

    @property
    def max_pages_per_seq(self) -> int:
        return self.page_table.shape[1]

    def free(self) -> int:
        """Host-side free-page count (forces a sync; scheduler use only)."""
        return int(self.free_count)


def init_page_state(slots: int, total_pages: int,
                    max_pages_per_seq: int) -> PageState:
    return PageState(
        page_table=jnp.full((slots, max_pages_per_seq), -1, jnp.int32),
        n_pages=jnp.zeros((slots,), jnp.int32),
        seq_lens=jnp.zeros((slots,), jnp.int32),
        free_stack=jnp.arange(total_pages, dtype=jnp.int32),
        free_count=jnp.asarray(total_pages, jnp.int32),
        first_page=jnp.zeros((slots,), jnp.int32),
    )


def alloc_pages(st: PageState, slot, n: int) -> PageState:
    """Pop ``n`` pages (static count) from the free list onto ``slot``'s
    table, appended after its currently-mapped pages (logical position
    ``first_page + n_pages``). The caller (the scheduler) must guarantee
    ``free_count >= n`` and that the row has room; this function does not
    check (it must stay jit-traceable)."""
    if n == 0:
        return st
    ids = jax.lax.dynamic_slice(st.free_stack, (st.free_count - n,), (n,))
    row = jax.lax.dynamic_slice(st.page_table, (slot, 0),
                                (1, st.max_pages_per_seq))[0]
    row = jax.lax.dynamic_update_slice(
        row, ids, (st.first_page[slot] + st.n_pages[slot],))
    table = jax.lax.dynamic_update_slice(st.page_table, row[None],
                                         (slot, 0))
    return dataclasses.replace(
        st, page_table=table,
        n_pages=st.n_pages.at[slot].add(n),
        free_count=st.free_count - n)


def free_slot(st: PageState, slot) -> PageState:
    """Return all of ``slot``'s pages to the free list and clear its row."""
    m = st.max_pages_per_seq
    row = st.page_table[slot]                          # (m,)
    lg = jnp.arange(m)
    first = st.first_page[slot]
    owned = (lg >= first) & (lg < first + st.n_pages[slot])
    # push owned ids above the current top; masked entries index OOB and
    # are dropped by the scatter
    dst = jnp.where(owned, st.free_count + lg - first, st.total_pages)
    stack = st.free_stack.at[dst].set(jnp.where(owned, row, 0),
                                      mode="drop")
    return dataclasses.replace(
        st,
        page_table=st.page_table.at[slot].set(-1),
        n_pages=st.n_pages.at[slot].set(0),
        seq_lens=st.seq_lens.at[slot].set(0),
        free_stack=stack,
        free_count=st.free_count + st.n_pages[slot],
        first_page=st.first_page.at[slot].set(0))


def release_prefix(st: PageState, slot, n: int) -> PageState:
    """Sliding-window reclamation: return the first ``n`` still-mapped
    logical pages of ``slot`` to the free list (their token positions have
    fallen fully out of every attention window). Table entries revert to
    ``-1``; ``first_page`` advances so later allocations keep appending at
    the logical tail. ``n`` is a static (host-side) count."""
    if n == 0:
        return st
    m = st.max_pages_per_seq
    row = st.page_table[slot]
    first = st.first_page[slot]
    rel = jnp.arange(m) - first
    dead = (rel >= 0) & (rel < n)
    dst = jnp.where(dead, st.free_count + rel, st.total_pages)
    stack = st.free_stack.at[dst].set(jnp.where(dead, row, 0),
                                      mode="drop")
    return dataclasses.replace(
        st,
        page_table=st.page_table.at[slot].set(
            jnp.where(dead, -1, row)),
        n_pages=st.n_pages.at[slot].add(-n),
        free_stack=stack,
        free_count=st.free_count + n,
        first_page=st.first_page.at[slot].add(n))


def truncate(st: PageState, slot, n_tokens: int,
             page_size: int) -> PageState:
    """Speculative-decode rollback — the mirror of ``release_prefix``:
    un-record the last ``n_tokens`` tokens of ``slot`` (rejected draft KV)
    and return tail pages that now hold no live token to the free list.
    ``n_tokens`` is a static (host-side) count; the page-release count is
    data-dependent (it depends on where the new length falls within a
    page) and is computed with the same masked-scatter idiom as
    ``free_slot``, so the whole op stays jit-traceable. The caller must
    guarantee ``n_tokens <= seq_lens[slot]`` and that the truncated length
    does not fall below ``first_page * page_size`` (window-reclaimed
    positions are dead forever and cannot be rolled back into)."""
    if n_tokens == 0:
        return st
    m = st.max_pages_per_seq
    row = st.page_table[slot]
    first = st.first_page[slot]
    end = first + st.n_pages[slot]
    new_len = st.seq_lens[slot] - n_tokens
    # first logical page to free: everything at or beyond the page that
    # holds the (new) write head stays; clip keeps the op total even if
    # the caller's precondition is violated
    keep = jnp.clip((new_len + page_size - 1) // page_size, first, end)
    lg = jnp.arange(m)
    dead = (lg >= keep) & (lg < end)
    dst = jnp.where(dead, st.free_count + lg - keep, st.total_pages)
    stack = st.free_stack.at[dst].set(jnp.where(dead, row, 0),
                                      mode="drop")
    return dataclasses.replace(
        st,
        page_table=st.page_table.at[slot].set(jnp.where(dead, -1, row)),
        n_pages=st.n_pages.at[slot].set(keep - first),
        seq_lens=st.seq_lens.at[slot].add(-n_tokens),
        free_stack=stack,
        free_count=st.free_count + (end - keep))


def advance(st: PageState, slot, n_tokens: int) -> PageState:
    """Record ``n_tokens`` more tokens written for ``slot``."""
    return dataclasses.replace(
        st, seq_lens=st.seq_lens.at[slot].add(n_tokens))


def pages_needed(seq_len: int, page_size: int) -> int:
    return -(-seq_len // page_size)


# Jitted fast paths for the scheduler's per-step host loop. Called
# eagerly, the ops above dispatch one scatter at a time — at smoke scale
# that costs more than the engine's entire jitted model step (``truncate``
# runs ~15 eager ops per rollback). ``slot`` stays dynamic (one executable
# across slots); the count arguments are static where a host ``if`` guards
# them, and their value sets are tiny (draft depths, window shifts), so
# this lands a handful of executables at most.
advance_fast = jax.jit(advance)
truncate_fast = jax.jit(truncate,
                        static_argnames=("n_tokens", "page_size"))
release_prefix_fast = jax.jit(release_prefix, static_argnames=("n",))


# ---------------------------------------------------------------------------
# Address translation + page buffer I/O (used by the model's paged path)
# ---------------------------------------------------------------------------


def physical_addresses(page_table: jax.Array,   # (B, max_pages)
                       positions: jax.Array,    # (B, C) token positions
                       valid: jax.Array,        # (B, C) bool
                       page_size: int,
                       trash_page: int) -> Tuple[jax.Array, jax.Array]:
    """Map token positions to (physical_page, offset); invalid rows are
    redirected to the write-discard page."""
    logical = jnp.clip(positions // page_size, 0,
                       page_table.shape[1] - 1)
    phys = jnp.take_along_axis(page_table, logical, axis=1)
    phys = jnp.where(valid & (phys >= 0), phys, trash_page)
    return phys, positions % page_size


def write_kv(k_pages: jax.Array,  # (P+1, page, Hkv, Dh)
             v_pages: jax.Array,
             k_new: jax.Array,    # (B, C, Hkv, Dh)
             v_new: jax.Array,
             phys: jax.Array,     # (B, C)
             off: jax.Array       # (B, C)
             ) -> Tuple[jax.Array, jax.Array]:
    """Scatter new KV into the page buffers (batched token writes)."""
    k_pages = k_pages.at[phys, off].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[phys, off].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def gather_kv(pages: jax.Array,       # (P+1, page, Hkv, Dh)
              page_table: jax.Array   # (B, max_pages)
              ) -> jax.Array:
    """Gather a contiguous (B, max_pages*page, Hkv, Dh) logical view of a
    batch of sequences (the XLA fallback read path). Unmapped entries
    (-1) are clamped to page 0; the caller masks them by sequence length."""
    b, m = page_table.shape
    _, page, hkv, dh = pages.shape
    flat = pages[jnp.clip(page_table, 0, pages.shape[0] - 1)]
    return flat.reshape(b, m * page, hkv, dh)


# ---------------------------------------------------------------------------
# Int8-quantized pages (inference): one symmetric f32 scale per stored
# token, written at append time next to the page buffers. Scale buffers are
# (total_pages + 1, page_size) and share the trash-page convention, so the
# same physical addresses drive both scatters.
# ---------------------------------------------------------------------------


def quantize_kv(x: jax.Array  # (B, C, Hkv, Dh)
                ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-token int8 quantization: the amax reduces over
    (Hkv, Dh), one scale per (batch, token). Returns (int8, (B, C) f32)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None, None]), -127.0, 127.0)
    return q.astype(jnp.int8), scale


def write_kv_quant(k_pages: jax.Array,  # (P+1, page, Hkv, Dh) int8
                   v_pages: jax.Array,
                   k_scale: jax.Array,  # (P+1, page) f32
                   v_scale: jax.Array,
                   k_new: jax.Array,    # (B, C, Hkv, Dh) full-width
                   v_new: jax.Array,
                   phys: jax.Array,     # (B, C)
                   off: jax.Array       # (B, C)
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Quantize-at-append: new KV is reduced to int8 + per-token scale
    and both are scattered through the same (phys, off) addresses."""
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    k_pages = k_pages.at[phys, off].set(kq)
    v_pages = v_pages.at[phys, off].set(vq)
    k_scale = k_scale.at[phys, off].set(ks)
    v_scale = v_scale.at[phys, off].set(vs)
    return k_pages, v_pages, k_scale, v_scale


def gather_scales(scales: jax.Array,     # (P+1, page)
                  page_table: jax.Array  # (B, max_pages)
                  ) -> jax.Array:
    """Scale-side twin of :func:`gather_kv`: (B, max_pages*page) f32."""
    b, m = page_table.shape
    _, page = scales.shape
    flat = scales[jnp.clip(page_table, 0, scales.shape[0] - 1)]
    return flat.reshape(b, m * page)
