"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` builds the abstract inputs for a cell:

* train/prefill: token (and stub-frontend embedding) batches;
* decode: one new token + the full KV/SSM cache ShapeDtypeStructs, built
  with ``jax.eval_shape`` over the cache constructor.

``step_fns`` returns the jit-able step callables the dry-run lowers:
``train_step`` (loss+grad+AdamW update, donated), ``prefill_step`` and
``serve_step`` (one token against the cache).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.common import ModelConfig, ShapeConfig, dtype_of
from ..nn.model import EncDec, LM
from ..optim import adam


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model=None
                ) -> Dict[str, Any]:
    """Abstract inputs for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    cdt = dtype_of(cfg)
    needs_embeds = (cfg.input_mode == "embeddings"
                    or cfg.enc_dec is not None)

    if shape.kind == "train":
        batch = {"tokens": _sd((b, s), jnp.int32),
                 "labels": _sd((b, s), jnp.int32)}
        if needs_embeds:
            batch["embeds"] = _sd((b, s, cfg.frontend_dim), cdt)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": _sd((b, s), jnp.int32)}
        if needs_embeds:
            batch["embeds"] = _sd((b, s, cfg.frontend_dim), cdt)
            if cfg.input_mode == "embeddings" and cfg.enc_dec is None:
                del batch["tokens"]  # vlm/audio prefill is embeddings-only
        return {"batch": batch}

    # decode: one token + cache of capacity seq_len
    assert model is not None
    token = _sd((b, 1), jnp.int32)
    if cfg.enc_dec is not None:
        stack = model.decoder
        enc_len = min(s, 4096)  # encoder output length for cross KV

        def mk():
            return {"layers": stack.init_cache(b, s, dtype_of(cfg),
                                               enc_len=enc_len),
                    "pos": jnp.zeros((), jnp.int32)}
    else:
        stack = model.stack

        def mk():
            return {"layers": stack.init_cache(b, s, dtype_of(cfg)),
                    "pos": jnp.zeros((), jnp.int32)}

    cache = jax.eval_shape(mk)
    return {"token": token, "cache": cache}


def make_train_step(model, opt_cfg: adam.AdamWConfig):
    def train_step(params, opt, batch):
        (loss, metrics), g = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt, om = adam.update(opt_cfg, g, opt, params)
        return params, opt, dict(metrics, **om)
    return train_step


def make_prefill_step(model, s_max: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, s_max)
    return prefill_step


def make_serve_step(model):
    def serve_step(params, token, cache):
        return model.decode_step(params, token, cache)
    return serve_step


def abstract_params(model) -> Any:
    """ShapeDtypeStructs of the parameter tree (no allocation)."""
    return jax.eval_shape(model.init, jax.random.key(0))


def abstract_opt(params_struct) -> Any:
    return jax.eval_shape(adam.init, params_struct)
