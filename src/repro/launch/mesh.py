"""Production meshes. A FUNCTION, not a module constant, so importing this
module never touches jax device state (the dry-run must set XLA_FLAGS before
any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    With more devices available than the mesh needs (the dry-run forces 512
    host devices and then builds the single-pod 256-chip mesh), the first
    prod(shape) devices are used.
    """
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small host-platform meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
