"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Single-host entry point (on a real cluster each host runs this under
``jax.distributed.initialize()``; the mesh/axis logic is identical). Smoke
scale by default so it runs on CPU; pass --full for the published config.

Fault tolerance: the step loop runs under ``RestartLoop`` — any RuntimeError
(device loss on real hardware; injectable in tests) triggers
checkpoint-restore and continue. ``--simulate-failure-at N`` demonstrates
the restart path end-to-end.
"""
from __future__ import annotations

import argparse
import os
from functools import partial

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="published config (needs real TPUs)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--diloco", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2,2x data,model' for a local device mesh")
    ap.add_argument("--simulate-failure-at", type=int, default=0)
    ap.add_argument("--rho", type=float, default=None,
                    help="override FFN sparsity density (paper's rho)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the run here")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append obs registry events to this JSONL file")
    args = ap.parse_args()

    from ..configs import get_config
    from ..data import BigramLM
    from ..nn import build_model
    from ..nn.common import SparsityConfig
    from ..optim import AdamWConfig
    from ..train import RestartLoop, RestartPolicy, Trainer, TrainerConfig

    cfg = get_config(args.arch, smoke=not args.full)
    if args.rho is not None:
        sp = cfg.sparsity
        cfg = cfg.with_(sparsity=SparsityConfig(
            enabled=args.rho < 1.0, rho_ffn=(args.rho, min(1.0, args.rho * 1.5)),
            block_in=sp.block_in, block_out=sp.block_out))
    model = build_model(cfg)

    mesh = None
    if args.mesh:
        shape_s, axes_s = args.mesh.split("x ")
        shape = tuple(int(x) for x in shape_s.split(","))
        axes = tuple(axes_s.split(","))
        mesh = jax.make_mesh(shape, axes)

    if args.metrics_jsonl:
        from ..obs import get_registry
        get_registry().set_jsonl(args.metrics_jsonl)

    tc = TrainerConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps),
        grad_accum=args.grad_accum,
        diloco_period=args.diloco,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        profile_dir=args.profile_dir,
    )
    trainer = Trainer(model, tc, mesh=mesh)
    data = BigramLM(vocab_size=cfg.vocab_size, seed=0)

    def make_iter(start):
        it = data.iterate(args.batch, args.seq, start_step=start)
        if cfg.input_mode == "embeddings" or cfg.enc_dec is not None:
            rng = np.random.default_rng(0)

            def gen():
                for b in it:
                    b["embeds"] = rng.normal(
                        size=(args.batch, args.seq, cfg.frontend_dim)
                    ).astype(np.float32)
                    yield b
            return gen()
        return it

    log = partial(print, flush=True)
    state = {"params": None, "opt": None, "failed": False}

    fail_at = args.simulate_failure_at

    def run():
        start = (trainer.ckpt.latest_step() or 0) if trainer.ckpt else 0
        it = make_iter(start)
        steps = args.steps
        if fail_at and not state["failed"] and start < fail_at <= steps:
            state["failed"] = True
            # run to the failure point, then raise like a lost device
            p, o, h = trainer.fit(it, fail_at, resume=True,
                                  on_step=lambda s, m: log(f"step {s}: {m}"))
            raise RuntimeError("simulated device loss")
        p, o, h = trainer.fit(it, steps, resume=True,
                              on_step=lambda s, m: log(f"step {s}: {m}"))
        state["params"], state["opt"] = p, o

    if args.checkpoint_dir:
        loop = RestartLoop(
            RestartPolicy(checkpoint_every=args.checkpoint_every),
            save_fn=lambda s: None,     # trainer checkpoints internally
            restore_fn=lambda: (trainer.ckpt.latest_step() or 0))
        tries = 0
        while True:
            try:
                run()
                break
            except RuntimeError as e:
                tries += 1
                log(f"[restart] {e} — resuming from checkpoint "
                    f"(attempt {tries})")
                if tries > 3:
                    raise
    else:
        run()
    log("training done")


if __name__ == "__main__":
    main()
