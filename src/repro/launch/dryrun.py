import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, prove memory fits, and extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import — jax locks the device count at first init)::

    PYTHONPATH=src python -m repro.launch.dryrun --all            # 16x16
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --shape train_4k --multi-pod

Results (memory_analysis, cost_analysis, per-kind collective bytes,
roofline terms) are cached as JSON under experiments/dryrun/. The roofline
table in EXPERIMENTS.md §Roofline is generated from these files by
``benchmarks/roofline.py``.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCHS, canonical, get_config, shapes_for  # noqa: E402
from ..nn import build_model  # noqa: E402
from ..nn.common import SHAPES, mesh_context  # noqa: E402
from ..optim import AdamWConfig  # noqa: E402
from ..sharding import policy  # noqa: E402
from . import analysis, hlo_cost, specs  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True,
             save_hlo_dir: str = "experiments/hlo") -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(cfg)
    rules = policy.rules_for(shape.kind, shape.global_batch, mesh, cfg)

    params_struct = specs.abstract_params(model)
    pspec = policy.param_pspecs(model.spec(), rules)
    p_sh = policy.named(mesh, pspec, params_struct)
    inp = specs.input_specs(cfg, shape, model)

    with mesh, mesh_context(mesh, rules):
        if shape.kind == "train":
            opt_struct = specs.abstract_opt(params_struct)
            o_sh = policy.named(mesh, policy.opt_pspecs(pspec), opt_struct)
            b_sh = policy.named(mesh,
                                policy.batch_pspecs(inp["batch"], rules),
                                inp["batch"])
            step = specs.make_train_step(model, AdamWConfig())
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_struct, opt_struct, inp["batch"])
        elif shape.kind == "prefill":
            b_sh = policy.named(mesh,
                                policy.batch_pspecs(inp["batch"], rules),
                                inp["batch"])
            step = specs.make_prefill_step(model, shape.seq_len)
            cache_struct = jax.eval_shape(step, params_struct, inp["batch"])
            c_sh = policy.named(mesh,
                                policy.cache_pspecs(cache_struct[1], rules),
                                cache_struct[1])
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, b_sh),
                out_shardings=(None, c_sh),
            ).lower(params_struct, inp["batch"])
        else:  # decode
            tok_sh = policy.named(
                mesh, policy.batch_pspecs({"tokens": inp["token"]},
                                          rules))["tokens"]
            c_sh = policy.named(mesh,
                                policy.cache_pspecs(inp["cache"], rules),
                                inp["cache"])
            step = specs.make_serve_step(model)
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, tok_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(params_struct, inp["token"], inp["cache"])

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = hlo_cost.xla_cost_analysis(compiled)
    if verbose:
        print(f"--- {arch} x {shape_name} ({'multi' if multi_pod else 'single'}-pod, {chips} chips)")
        print(mem)
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    if save_hlo_dir:
        import zstandard
        os.makedirs(save_hlo_dir, exist_ok=True)
        zpath = os.path.join(
            save_hlo_dir,
            f"{canonical(arch)}__{shape_name}__"
            f"{'multi' if multi_pod else 'single'}.hlo.zst")
        with open(zpath, "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(
                hlo.encode()))
    # loop-aware rollup: cost_analysis() counts while bodies once; the
    # layer-scan / flash / loss loops need trip-count multiplication
    rolled = hlo_cost.analyze(hlo)
    coll = rolled["collective_bytes"]

    n_params = sum(x.size for x in jax.tree.leaves(params_struct))
    n_embed = analysis.count_embed_params(params_struct)
    n_active = analysis.moe_active_params(cfg, n_params)
    mf_global = analysis.model_flops(cfg, n_params, n_embed, shape,
                                     n_active)
    roof = analysis.roofline(
        float(rolled["flops"]),
        float(rolled["bytes"]),
        float(coll["total"]),
        model_flops_per_chip=mf_global / chips)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "n_params": int(n_params),
        "n_embed_params": int(n_embed),
        "n_active_params": int(n_active) if n_active else None,
        "memory_analysis": _mem_dict(mem),
        "flops_per_chip": float(rolled["flops"]),
        "bytes_per_chip": float(rolled["bytes"]),
        "xla_cost_analysis": {
            "flops_once": float(cost.get("flops", 0.0)),
            "bytes_once": float(cost.get("bytes accessed", 0.0))},
        "collective_bytes": {k: int(v) for k, v in coll.items()},
        "roofline": roof,
        "compile_seconds": time.time() - t0,
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print({k: result[k] for k in
               ("flops_per_chip", "bytes_per_chip")},
              "coll:", coll["total"], "dominant:", roof["dominant"],
              f"compile {result['compile_seconds']:.1f}s")
    return result


def cell_path(out_dir: str, arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "multi" if multi_pod else "single"
    return os.path.join(out_dir, f"{canonical(arch)}__{shape}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCHS:
            for s in shapes_for(a):
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape
        cells = [(canonical(args.arch), args.shape)]

    failures = []
    for arch, shape in cells:
        path = cell_path(args.out, arch, shape, args.multi_pod)
        if args.skip_existing and os.path.exists(path):
            print(f"skip {arch} x {shape} (cached)")
            continue
        try:
            result = run_cell(arch, shape, multi_pod=args.multi_pod)
            with open(path, "w") as f:
                json.dump(result, f, indent=1)
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cells "
          f"({'multi' if args.multi_pod else 'single'}-pod)")


if __name__ == "__main__":
    main()
