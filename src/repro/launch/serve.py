"""Serving driver: continuous-batching engine (default) + legacy loops.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 32
--gen 16`` runs a smoke-scale batched generation. Token-input decoder-only
models route through ``repro.serving.ServingEngine`` (paged KV cache +
chunked prefill); stub-frontend and enc-dec models use the legacy dense
-cache loop. On real hardware the same code path serves the production
mesh with the SERVE sharding rules (TP FFN + context-parallel KV,
DESIGN.md §5).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _sample_tok(logits, key):
    """Categorical sample from (B, 1, V) logits -> (B, 1) int32."""
    return jax.random.categorical(key, logits[:, 0]).astype(jnp.int32)[:, None]


def generate_cached(model, params, prompt, s_max, steps, *, greedy=True,
                    key=None, extra_batch=None):
    """Legacy batched generation: monolithic prefill + dense-cache decode
    loop. Kept for enc-dec / stub-frontend models and engine A/B tests.
    Returns (tokens, tokens/sec over the decode loop)."""
    batch = {"tokens": prompt}
    if extra_batch:
        batch.update(extra_batch)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, s_max))(params, batch)
    step = jax.jit(model.decode_step, donate_argnums=(2,))
    if greedy:
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    else:
        # the first token is a draw too — and every draw uses a fresh
        # split, never the raw key
        key, sub = jax.random.split(key)
        tok = _sample_tok(logits, sub)
    out = [tok]
    t0 = time.time()
    for i in range(steps - 1):
        logits, cache = step(params, tok, cache)
        if greedy:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = _sample_tok(logits, sub)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    toks.block_until_ready()
    dt = time.time() - t0
    tps = prompt.shape[0] * max(steps - 1, 1) / max(dt, 1e-9)
    return toks, tps


def generate(model, params, prompt, s_max, steps, *, greedy=True, key=None,
             extra_batch=None, page_size: int = 16):
    """Batched generation; returns (tokens (B, steps), tokens/sec).

    Thin wrapper over the continuous-batching ``ServingEngine`` (paged KV
    cache, chunked prefill, paged-attention decode). Models the engine
    cannot serve (enc-dec, stub-frontend embeddings, MoE with finite
    expert capacity — see the engine's dropless-decode guard) fall back
    to ``generate_cached``. The reported tok/s covers only tokens decoded
    after the prefill drain (compiles + prompt processing excluded).
    """
    moe = getattr(model.cfg, "moe", None)
    if extra_batch or getattr(model.cfg, "enc_dec", None) is not None \
            or model.cfg.input_mode != "tokens" \
            or (moe is not None
                and moe.capacity_factor * moe.top_k < moe.n_routed):
        return generate_cached(model, params, prompt, s_max, steps,
                               greedy=greedy, key=key,
                               extra_batch=extra_batch)
    from ..serving import EngineConfig, ServingEngine

    b, prompt_len = prompt.shape
    pages_per_seq = -(-s_max // page_size)
    eng = ServingEngine(
        model, params,
        EngineConfig(max_slots=b, page_size=page_size,
                     total_pages=b * pages_per_seq,
                     max_pages_per_seq=pages_per_seq,
                     token_budget=b + max(prompt_len, 1),
                     prefill_chunk=64, greedy=greedy),
        key=key)
    for i in range(b):
        eng.add_request(np.asarray(prompt[i]), steps, req_id=i)
    # run prefill (and its jit compiles) before the timer, mirroring the
    # legacy loop's prefill-outside-t0 convention; the tok/s reported is
    # the decode regime, modulo the first decode step's compile
    guard = 0
    while any(s is not None and s.prefilling for s in eng.sched.active) \
            or eng.sched.waiting:
        eng.step()
        guard += 1
        if guard > 10_000:
            raise RuntimeError("prefill failed to drain")
    # tokens decoded during the drain (continuous batching decodes
    # already-prefilled sequences while others prefill) don't count
    # toward the timed rate
    pre = sum(len(o) for o in eng.outputs.values()) \
        + sum(s.n_generated for s in eng.sched.active if s is not None)
    t0 = time.time()
    steps_run = 0
    while eng.sched.has_work():
        eng.step()
        steps_run += 1
        if steps_run > 100_000:
            raise RuntimeError("engine failed to drain")
    dt = time.time() - t0
    toks = jnp.asarray(np.stack([eng.outputs[i] for i in range(b)]))
    tps = max(b * steps - pre, 0) / max(dt, 1e-9)
    return toks, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--enc-len", type=int, default=None,
                    help="encoder frames for enc-dec archs "
                         "(default: --prompt-len)")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from ..configs import get_config
    from ..nn import build_model

    cfg = get_config(args.arch, smoke=not args.full)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    # the decoder prompt (text tokens); for enc-dec archs this seeds the
    # decoder while the frontend embeddings feed the encoder
    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    def frames(length):
        return jnp.asarray(rng.normal(
            size=(args.batch, length, cfg.frontend_dim)), jnp.float32)

    extra = None
    if cfg.enc_dec is not None:
        # enc-dec (seamless-style): stub frontend frames for the encoder,
        # token prompt for the decoder
        extra = {"embeds": frames(args.enc_len or args.prompt_len)}
    elif cfg.input_mode == "embeddings":
        # decoder-only with stub frontend (vlm/audio): the prefill consumes
        # embeddings aligned with the prompt span; decode embeds text tokens
        extra = {"embeds": frames(args.prompt_len)}
    toks, tps = generate(model, params, prompt,
                         args.prompt_len + args.gen, args.gen,
                         extra_batch=extra)
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[0])


if __name__ == "__main__":
    main()
