"""Serving driver: batched prefill + decode loop.

``python -m repro.launch.serve --arch <id> --batch 4 --prompt-len 32
--gen 16`` runs a smoke-scale batched generation. On real hardware the same
code path serves the production mesh with the SERVE sharding rules
(TP FFN + context-parallel KV, DESIGN.md §5).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def generate(model, params, prompt, s_max, steps, *, greedy=True, key=None,
             extra_batch=None):
    """Batched generation; returns (tokens, tokens/sec)."""
    batch = {"tokens": prompt}
    if extra_batch:
        batch.update(extra_batch)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, s_max))(params, batch)
    step = jax.jit(model.decode_step, donate_argnums=(2,))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(steps - 1):
        logits, cache = step(params, tok, cache)
        if greedy:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, 0]).astype(jnp.int32)[:, None]
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    toks.block_until_ready()
    dt = time.time() - t0
    tps = prompt.shape[0] * max(steps - 1, 1) / max(dt, 1e-9)
    return toks, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--enc-len", type=int, default=None,
                    help="encoder frames for enc-dec archs "
                         "(default: --prompt-len)")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from ..configs import get_config
    from ..nn import build_model

    cfg = get_config(args.arch, smoke=not args.full)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    # the decoder prompt (text tokens); for enc-dec archs this seeds the
    # decoder while the frontend embeddings feed the encoder
    prompt = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    def frames(length):
        return jnp.asarray(rng.normal(
            size=(args.batch, length, cfg.frontend_dim)), jnp.float32)

    extra = None
    if cfg.enc_dec is not None:
        # enc-dec (seamless-style): stub frontend frames for the encoder,
        # token prompt for the decoder
        extra = {"embeds": frames(args.enc_len or args.prompt_len)}
    elif cfg.input_mode == "embeddings":
        # decoder-only with stub frontend (vlm/audio): the prefill consumes
        # embeddings aligned with the prompt span; decode embeds text tokens
        extra = {"embeds": frames(args.prompt_len)}
    toks, tps = generate(model, params, prompt,
                         args.prompt_len + args.gen, args.gen,
                         extra_batch=extra)
    print(f"generated {toks.shape} tokens at {tps:.1f} tok/s")
    print(toks[0])


if __name__ == "__main__":
    main()
