"""Offline re-analysis of saved dry-run HLO.

Every dry-run compile persists its optimized HLO to
``experiments/hlo/<cell>.hlo.zst``; this tool re-derives roofline terms
from those artifacts WITHOUT recompiling — so cost-model improvements (or
alternative hardware constants) can be swept over all 66 cells in seconds::

    PYTHONPATH=src python -m repro.launch.reanalyze \
        [--hlo experiments/hlo] [--out experiments/dryrun] \
        [--peak 197e12 --hbm 819e9 --link 50e9]

Updates the roofline block of each matching dry-run JSON in place (the
memory_analysis and n_params fields from the original compile are kept).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import zstandard

from . import analysis, hlo_cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="experiments/hlo")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--peak", type=float, default=analysis.PEAK_FLOPS)
    ap.add_argument("--hbm", type=float, default=analysis.HBM_BW)
    ap.add_argument("--link", type=float, default=analysis.LINK_BW)
    args = ap.parse_args()

    analysis.PEAK_FLOPS = args.peak
    analysis.HBM_BW = args.hbm
    analysis.LINK_BW = args.link

    dctx = zstandard.ZstdDecompressor()
    n = 0
    for zpath in sorted(glob.glob(os.path.join(args.hlo, "*.hlo.zst"))):
        cell = os.path.basename(zpath).replace(".hlo.zst", "")
        jpath = os.path.join(args.out, f"{cell}.json")
        if not os.path.exists(jpath):
            print(f"skip {cell}: no dry-run JSON")
            continue
        with open(jpath) as f:
            rec = json.load(f)
        hlo = dctx.decompress(open(zpath, "rb").read()).decode()
        rolled = hlo_cost.analyze(hlo)
        mf_chip = rec["roofline"].get("model_flops_per_chip")
        rec["flops_per_chip"] = rolled["flops"]
        rec["bytes_per_chip"] = rolled["bytes"]
        rec["collective_bytes"] = {k: int(v) for k, v in
                                   rolled["collective_bytes"].items()}
        rec["roofline"] = analysis.roofline(
            rolled["flops"], rolled["bytes"],
            rolled["collective_bytes"]["total"],
            model_flops_per_chip=mf_chip)
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
        print(f"reanalyzed {cell}: dominant={rec['roofline']['dominant']} "
              f"frac={rec['roofline'].get('roofline_fraction', 0):.4f}")
    print(f"done: {n} cells")


if __name__ == "__main__":
    main()
