"""Loop-aware HLO cost model.

``compiled.cost_analysis()`` counts each while-loop body ONCE, but the
layer stack / flash-attention / loss-chunk loops execute their bodies tens
to thousands of times — for a scanned 34-layer model the built-in numbers
are ~30x low (verified in tests/test_hlo_cost.py). This module re-derives
FLOPs, HBM bytes and per-kind collective bytes from the optimized HLO text
with while-loops rolled up by their ``known_trip_count``:

* FLOPs: dot/convolution instructions (2 x out_elems x contraction);
  elementwise flops are ignored (matmul-dominated models; same convention
  as XLA's own cost analysis which dominates on dots).
* bytes: per instruction, operand + output buffer sizes — the standard
  producer/consumer traffic model; fusion bodies are NOT recursed (their
  internals live in registers/VMEM), the fusion call site's operands/outputs
  are the HBM traffic.
* collectives: operand/output max per instruction, by kind, multiplied
  through loop trip counts.

Rollup: ENTRY -> (while: trip x body + cond), (fusion: flops recursed,
bytes at call site), (call: recursed), (conditional: max over branches).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "add-dependency", "iota",
               "partition-id", "replica-id"}

_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_type: str          # text before the op token (output type)
    args_text: str         # inside the op's parens
    tail: str              # after the closing paren (attrs)

    @property
    def operands(self) -> List[str]:
        return _OPERAND_RE.findall(self.args_text)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in _COLLECTIVES:
            self.coll[k] += mult * other.coll[k]

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _split_args(op_start: str) -> Tuple[str, str]:
    """Given text starting at the op's '(' return (inside, tail)."""
    depth = 0
    for i, ch in enumerate(op_start):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return op_start[1:i], op_start[i + 1:]
    return op_start[1:], ""


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.shapes: Dict[str, str] = {}  # instr name -> output type text
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._ptraffic: Dict[str, Dict[int, float]] = {}

    # -- parsing -------------------------------------------------------------

    def _parse(self, text: str):
        cur: Optional[str] = None
        header_re = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{")
        for raw in text.splitlines():
            line = raw.rstrip()
            hm = header_re.match(line)
            if hm and not line.startswith(" "):
                cur = hm.group(2)
                self.comps[cur] = []
                if hm.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, rest = im.group(2), im.group(3)
            om = _OP_RE.search(rest)
            if not om:
                continue
            op = om.group(1)
            out_type = rest[:om.start()]
            inside, tail = _split_args(rest[om.end() - 1:])
            instr = Instr(name, op, out_type, inside, tail)
            self.comps[cur].append(instr)
            self.shapes[name] = out_type

    # -- per-instruction ------------------------------------------------------

    def _dot_flops(self, instr: Instr) -> float:
        out = _first_shape_dims(instr.out_type)
        if out is None:
            return 0.0
        out_elems = 1
        for d in out[1]:
            out_elems *= d
        lhs = instr.operands[0] if instr.operands else None
        lhs_type = self.shapes.get(lhs, "")
        lhs_shape = _first_shape_dims(lhs_type)
        contract = 1
        m = _LHS_CONTRACT_RE.search(instr.tail)
        if m and lhs_shape:
            for idx in m.group(1).split(","):
                if idx:
                    contract *= lhs_shape[1][int(idx)]
        return 2.0 * out_elems * contract

    def _operand_bytes(self, instr: Instr) -> float:
        total = 0
        for name in instr.operands:
            total += _type_bytes(self.shapes.get(name, ""))
        return total

    # -- slice-aware traffic ----------------------------------------------
    #
    # dynamic-slice / gather / dynamic-update-slice touch only the sliced
    # region, not the whole operand. Counting full operands makes every
    # scan iteration "read" the entire stacked-layers buffer — a layers^2
    # overcount (measured ~100x on an 88-layer model).

    _SLICERS = {"dynamic-slice", "gather"}

    def _instr_bytes(self, instr: Instr) -> float:
        op = instr.op
        out_b = _type_bytes(instr.out_type)
        ops_ = instr.operands
        if op in self._SLICERS:
            # read the sliced region + write the output (+ indices)
            idx_b = sum(_type_bytes(self.shapes.get(n, ""))
                        for n in ops_[1:])
            return 2 * out_b + idx_b
        if op == "dynamic-update-slice":
            upd = _type_bytes(self.shapes.get(ops_[1], "")) if len(ops_) > 1 \
                else out_b
            return 3 * upd  # read region + read update + write region
        if op == "scatter":
            upd = _type_bytes(self.shapes.get(ops_[-1], "")) if ops_ else 0
            idx = _type_bytes(self.shapes.get(ops_[1], "")) \
                if len(ops_) > 2 else 0
            return 3 * upd + idx
        return out_b + self._operand_bytes(instr)

    def _param_traffic(self, comp: str) -> Dict[int, float]:
        """Per-parameter traffic of a fusion body: if a parameter is only
        consumed by slicing ops, its traffic is the slice outputs, not the
        full buffer (scan bodies slice their stacked inputs)."""
        if comp in self._ptraffic:
            return self._ptraffic[comp]
        instrs = self.comps.get(comp, [])
        param_of: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                m = re.match(r"\s*(\d+)", ins.args_text)
                if m:
                    param_of[ins.name] = int(m.group(1))
        uses: Dict[str, List[Instr]] = {}
        for ins in instrs:
            for o in ins.operands:
                if o in param_of:
                    uses.setdefault(o, []).append(ins)
        out: Dict[int, float] = {}
        for pname, pidx in param_of.items():
            puses = uses.get(pname, [])
            if puses and all(
                    u.op in self._SLICERS and u.operands
                    and u.operands[0] == pname for u in puses):
                out[pidx] = sum(2 * _type_bytes(u.out_type) for u in puses)
        self._ptraffic[comp] = out
        return out

    # -- rollup ----------------------------------------------------------------

    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        cost = Cost()
        self._memo[comp] = cost  # guards malformed recursion
        for instr in self.comps.get(comp, []):
            op = instr.op
            if op == "while":
                trip = 1
                tm = _TRIP_RE.search(instr.tail)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(instr.tail)
                cm = _COND_RE.search(instr.tail)
                if bm:
                    cost.add(self.comp_cost(bm.group(1)), trip)
                if cm:
                    cost.add(self.comp_cost(cm.group(1)), trip + 1)
            elif op == "fusion":
                cm = _CALLS_RE.search(instr.tail)
                b = _type_bytes(instr.out_type)
                if cm:
                    inner = self.comp_cost(cm.group(1))
                    cost.flops += inner.flops       # dots inside fusions
                    for k in _COLLECTIVES:
                        cost.coll[k] += inner.coll[k]
                    ptraf = self._param_traffic(cm.group(1))
                    for i, name in enumerate(instr.operands):
                        b += ptraf.get(
                            i, _type_bytes(self.shapes.get(name, "")))
                else:
                    b += self._operand_bytes(instr)
                cost.bytes += b
            elif op in ("call", "async-start"):
                cm = _CALLS_RE.search(instr.tail)
                if cm:
                    cost.add(self.comp_cost(cm.group(1)))
                cost.bytes += (_type_bytes(instr.out_type)
                               + self._operand_bytes(instr))
            elif op == "conditional":
                bm = _BRANCHES_RE.search(instr.tail)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    if branches:
                        costs = [self.comp_cost(b) for b in branches]
                        worst = max(costs, key=lambda c: c.flops + c.bytes)
                        cost.add(worst)
            elif op in ("dot", "convolution"):
                cost.flops += self._dot_flops(instr)
                cost.bytes += (_type_bytes(instr.out_type)
                               + self._operand_bytes(instr))
            elif op.rstrip("-start").rstrip("-done") in _COLLECTIVES or \
                    any(op.startswith(c) for c in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                b_out = _type_bytes(instr.out_type)
                b_in = self._operand_bytes(instr)
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                cost.coll[kind] += max(b_in, b_out)
                cost.bytes += b_out + b_in
            elif op in _SKIP_BYTES:
                continue
            else:
                cost.bytes += self._instr_bytes(instr)
        self._memo[comp] = cost
        return cost

    def total(self) -> Cost:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        return self.comp_cost(self.entry)


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Old JAX (<= 0.4.x) returns a *list* of per-program dicts (usually one);
    newer JAX returns the dict directly. Always returns one flat dict,
    summing duplicate keys across programs.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, dict):
        return cost
    out: Dict[str, float] = {}
    for prog in cost or []:
        for k, v in (prog or {}).items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + v
    return out


def analyze(hlo_text: str) -> dict:
    cost = HloCostModel(hlo_text).total()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": {**{k: cost.coll[k] for k in _COLLECTIVES},
                             "total": cost.coll_total},
    }
