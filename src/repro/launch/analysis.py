"""Compiled-artifact analysis: collective-byte extraction + roofline terms.

The dry-run compiles per-device SPMD modules, so ``cost_analysis()`` FLOPs /
bytes and the collective bytes parsed from the HLO text are all *per chip*.
Roofline terms (TPU v5e targets):

    compute_s    = flops_per_chip / 197e12         (bf16 MXU peak)
    memory_s     = bytes_per_chip / 819e9           (HBM bandwidth)
    collective_s = coll_bytes_per_chip / 50e9       (per-link ICI)

The dominant term is the bottleneck the §Perf loop iterates on.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-chip bytes moved by each collective kind, from HLO text.

    For each collective instruction (skipping ``-done`` halves of async
    pairs) we count max(input bytes, output bytes) — all-gather's cost is
    its output, reduce-scatter's its input.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "fusion" in stripped[:60]:
            continue
        for kind in _COLLECTIVES:
            tok = f" {kind}("
            tok_start = f" {kind}-start("
            if tok in stripped or tok_start in stripped:
                eq = stripped.find("= ")
                if eq < 0:
                    continue
                opname = tok_start if tok_start in stripped else tok
                op_at = stripped.find(opname)
                out_shapes = _SHAPE_RE.findall(stripped[eq:op_at])
                in_shapes = _SHAPE_RE.findall(stripped[op_at:])
                b_out = sum(_shape_bytes(d, s) for d, s in out_shapes)
                b_in = sum(_shape_bytes(d, s) for d, s in in_shapes)
                out[kind] += max(b_in, b_out)
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline(flops: float, bytes_accessed: float, coll_bytes: float,
             *, model_flops_per_chip: Optional[float] = None) -> dict:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    result = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_lower_bound_s": bound,
    }
    if model_flops_per_chip is not None and flops > 0:
        result["model_flops_per_chip"] = model_flops_per_chip
        result["useful_flop_ratio"] = model_flops_per_chip / flops
        # fraction of roofline: useful work at peak vs the binding term
        result["roofline_fraction"] = (
            model_flops_per_chip / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return result


def model_flops(cfg, n_params: int, n_embed_params: int, shape,
                n_active_params: Optional[int] = None) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = non-embedding params
    (active params for MoE)."""
    n = (n_active_params if n_active_params is not None
         else n_params) - n_embed_params
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def count_embed_params(params_struct) -> int:
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_struct)[0]:
        keys = [getattr(p, "key", "") for p in path]
        if "table" in keys or "head" in keys or "embed" in keys:
            total += leaf.size
    return total


def moe_active_params(cfg, n_params: int) -> Optional[int]:
    """Approximate active params for MoE archs: experts scaled k/E."""
    if cfg.moe is None:
        return None
    mc = cfg.moe
    d, de = cfg.d_model, mc.d_expert
    per_expert = 3 * d * de
    n_moe_layers = cfg.n_layers - (1 if mc.first_layer_dense else 0)
    routed_total = mc.n_routed * per_expert * n_moe_layers
    routed_active = mc.top_k * per_expert * n_moe_layers
    return n_params - routed_total + routed_active
