"""repro.launch — mesh construction, dry-run, train/serve drivers.

NOTE: ``dryrun`` must be executed as its own process (it sets XLA_FLAGS
before importing jax); do not import it from library code.
"""
from .mesh import make_production_mesh, make_mesh  # noqa: F401
from . import specs, analysis  # noqa: F401
