"""Primitive layers: Linear (dense or pre-defined-sparse), norms, embeddings,
rotary position embeddings. Functional modules: ``init(key) -> params`` and
``__call__(params, x)``; parameters are plain nested dicts (pjit-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_pattern import BlockPattern, fit_block_pattern
from ..kernels import ops as kops
from .common import ModelConfig, SparsityConfig, shard


# ---------------------------------------------------------------------------
# Linear — every weight junction in the framework goes through here, so
# pre-defined sparsity is a first-class option for all of them.
# ---------------------------------------------------------------------------


class Linear:
    """A junction. Dense by default; pre-defined block-sparse when ``rho<1``.

    ``logical_axes`` name the (in, out) sharding axes for the dense weight;
    the block-sparse weight inherits the output-dim axis on its right-block
    dimension and keeps fan-in dims replicated (the pattern is tiny).
    """

    def __init__(self, n_in: int, n_out: int, *, bias: bool = False,
                 rho: float = 1.0, sp: Optional[SparsityConfig] = None,
                 seed: int = 0, dtype: str = "float32",
                 logical_axes: Tuple[Optional[str], Optional[str]] = (None, None),
                 name: str = "linear"):
        self.n_in, self.n_out, self.bias = n_in, n_out, bias
        self.dtype = jnp.dtype(dtype)
        self.logical_axes = logical_axes
        self.name = name
        self.pattern: Optional[BlockPattern] = None
        self.backend = "xla"
        if sp is not None:
            # fit_block_pattern applies the shared block-size adaptation +
            # micro-block guard; None -> this junction stays dense.
            self.pattern = fit_block_pattern(n_in, n_out, rho, sp,
                                             seed=seed,
                                             weight_dtype=self.dtype)
            if self.pattern is not None:
                self.backend = sp.backend

    @property
    def is_sparse(self) -> bool:
        return self.pattern is not None

    @property
    def n_params(self) -> int:
        n = self.pattern.n_weight_elems if self.is_sparse else self.n_in * self.n_out
        return n + (self.n_out if self.bias else 0)

    def init(self, key: jax.Array) -> dict:
        if self.is_sparse:
            bp = self.pattern
            fan_in = bp.d_in_b * bp.block_in
            w = jax.random.normal(
                key, (bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out),
                self.dtype) * np.sqrt(1.0 / fan_in)
        else:
            w = jax.random.normal(key, (self.n_in, self.n_out),
                                  self.dtype) * np.sqrt(1.0 / self.n_in)
        p = {"w": w}
        if self.bias:
            p["b"] = jnp.zeros((self.n_out,), self.dtype)
        return p

    def spec(self) -> dict:
        """Logical sharding axes per parameter (consumed by sharding.policy)."""
        if self.is_sparse:
            # (n_rb, d_in_b, bL, bR): the block-row dim carries the "slab"
            # logical axis — the SAME rule that drives the shard_map
            # partition of the junction compute, so the weight chunks a
            # NamedSharding produces are exactly the per-device slabs the
            # sharded csd_matmul expects (no resharding at entry)
            s = {"w": ("slab", None, None, None)}
        else:
            s = {"w": self.logical_axes}
        if self.bias:
            s["b"] = (None,)
        return s

    def __call__(self, params: dict, x: jax.Array,
                 activation: Optional[str] = None) -> jax.Array:
        """``activation(x @ W + b)``. For sparse junctions the bias and
        activation ride the fused ``csd_matmul`` epilogue (one kernel, no
        HBM round-trip of the pre-activation); dense junctions apply them
        inline. ``activation`` is ``None | "relu" | "gelu"``.

        Under a mesh whose rules resolve the ``"slab"`` axis (TRAIN and
        SERVE both map it to ``model``), a partitionable sparse junction
        transparently runs model-parallel: pattern + slab split across the
        axis, FF column-parallel, BP psum'd, UP shard-local (see
        ``kernels.ops``)."""
        w = params["w"]
        cdt = x.dtype
        if self.is_sparse:
            from .common import junction_shard_kwargs, logical_to_spec
            b = params["b"].astype(cdt) if self.bias else None
            kw = junction_shard_kwargs(self.pattern)
            if kw:
                # leading dims keep their batch sharding through the
                # shard_map; the seq dim replicates over the slab axis
                # (the Megatron-style all-gather at junction entry)
                kw["lead_spec"] = tuple(logical_to_spec(
                    *(("batch",) + (None,) * (x.ndim - 2))))
            if "w_scale" in params:
                # quantize_tree left an int8 slab + per-block scales: the
                # slab must enter csd_matmul uncast (SL206)
                return kops.csd_matmul(x, w, self.pattern, bias=b,
                                       activation=activation,
                                       backend=self.backend,
                                       w_scale=params["w_scale"], **kw)
            return kops.csd_matmul(x, w.astype(cdt), self.pattern,
                                   bias=b, activation=activation,
                                   backend=self.backend, **kw)
        y = x @ w.astype(cdt)
        if self.bias:
            y = y + params["b"].astype(cdt)
        return kops.apply_activation(y, activation)


class RMSNorm:
    def __init__(self, dim: int, eps: float = 1e-6, dtype: str = "float32",
                 zero_centered: bool = True):
        self.dim, self.eps = dim, eps
        self.dtype = jnp.dtype(dtype)
        self.zero_centered = zero_centered  # gemma-style (1 + scale)

    def init(self, key=None) -> dict:
        return {"scale": jnp.zeros((self.dim,), self.dtype)
                if self.zero_centered else jnp.ones((self.dim,), self.dtype)}

    def spec(self) -> dict:
        return {"scale": (None,)}

    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        dt = x.dtype
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        xf = xf * jax.lax.rsqrt(var + self.eps)
        scale = params["scale"].astype(jnp.float32)
        if self.zero_centered:
            scale = 1.0 + scale
        return (xf * scale).astype(dt)


class Embedding:
    def __init__(self, vocab: int, dim: int, dtype: str = "float32"):
        self.vocab, self.dim = vocab, dim
        self.dtype = jnp.dtype(dtype)

    def init(self, key: jax.Array) -> dict:
        w = jax.random.normal(key, (self.vocab, self.dim), self.dtype)
        return {"table": w * (1.0 / np.sqrt(self.dim))}

    def spec(self) -> dict:
        # NOTE: the table's model dim gets its own logical name — sharding
        # it like weight matrices' "embed" (over data) makes every lookup /
        # tied-head matmul reshard through a global-batch intermediate
        # (measured ~4 GB of f32 scatter-adds per step at gemma3 scale).
        # vocab->model + embed-dim replicated keeps both the gather and
        # h @ table.T local with one small all-reduce.
        return {"table": ("vocab", "embed_table")}

    def __call__(self, params: dict, tokens: jax.Array,
                 dtype=None) -> jax.Array:
        t = params["table"]
        if dtype is not None:
            t = t.astype(dtype)  # gather + psum in compute dtype
        out = self._lookup(t, tokens)
        return out.astype(dtype or t.dtype)

    def _lookup(self, t: jax.Array, tokens: jax.Array) -> jax.Array:
        """Vocab-shard-local lookup via shard_map (mask + psum).

        GSPMD's default gather strategy for a vocab-sharded table
        materializes global-batch intermediates (measured GBs of f32
        scatter-adds in the backward). The mask+psum form keeps everything
        local: each shard serves the token rows it owns, zeros elsewhere,
        and one small psum over the vocab axis assembles the rows.
        """
        from jax.sharding import PartitionSpec as P

        from ..compat import shard_map
        from .common import current_mesh, logical_to_spec

        mesh = current_mesh()
        spec_t = logical_to_spec("vocab", "embed_table")
        vax = spec_t[0]
        if mesh is None or vax is None:
            return jnp.take(t, tokens, axis=0)
        n_shards = int(np.prod([mesh.shape[a] for a in
                                (vax if isinstance(vax, tuple)
                                 else (vax,))]))
        if self.vocab % n_shards:
            return jnp.take(t, tokens, axis=0)
        vshard = self.vocab // n_shards
        spec_i = logical_to_spec("batch", None)

        def local(tbl, tok):
            rel = tok - jax.lax.axis_index(vax) * vshard
            ok = (rel >= 0) & (rel < vshard)
            g = jnp.take(tbl, jnp.clip(rel, 0, vshard - 1), axis=0)
            g = jnp.where(ok[..., None], g, jnp.zeros((), g.dtype))
            return jax.lax.psum(g, vax)

        fn = shard_map(
            local, mesh=mesh, in_specs=(spec_t, spec_i),
            out_specs=P(spec_i[0], None, None), check_vma=False)
        return fn(t, tokens)

    def attend(self, params: dict, h: jax.Array) -> jax.Array:
        """Tied output head: h @ table^T -> logits."""
        return h @ params["table"].astype(h.dtype).T


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]
