"""Decoder blocks and the scanned layer stack.

Layers are grouped into repeating *pattern units* (e.g. gemma3's
5 local + 1 global) and the stack is a ``lax.scan`` over groups with
parameters stacked on a leading group axis. This keeps the HLO size O(unit)
instead of O(depth) — essential for granite-34b's 88 layers at 512-device
compile — and is also the direct analogue of the paper's junction pipeline:
one "junction cycle" of hardware reused across layers, weights streamed
per-stage (§III-A; with FSDP sharding the per-iteration weight all-gather
is literally the stream).

The zamba2-style hybrid uses a *shared* attention block (one parameter set
applied at every hybrid position) — parameter sharing exactly as published,
and incidentally the strongest form of the paper's storage-reduction goal.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import Attention
from .common import ModelConfig, shard
from .ffn import FFN, MoE
from .layers import RMSNorm
from .ssm import Mamba2Block


class TransformerBlock:
    """Pre-norm attention + FFN/MoE block (optionally sandwich-normed)."""

    def __init__(self, cfg: ModelConfig, kind: str, seed: int = 0,
                 cross: bool = False, layer_idx: int = 0):
        self.cfg = cfg
        self.kind = kind
        window = cfg.attn_window if kind == "local" else None
        self.attn = Attention(cfg, window=window, seed=seed,
                              qk_norm=cfg.post_norms)
        self.cross_attn = Attention(cfg, cross=True, seed=seed + 100) \
            if cross else None
        if cfg.moe is not None and not (
                cfg.moe.first_layer_dense and layer_idx == 0):
            self.ffn = MoE(cfg, seed=seed)
            self.is_moe = True
        else:
            d_ff = cfg.moe.dense_d_ff if (
                cfg.moe is not None and cfg.moe.first_layer_dense) else cfg.d_ff
            self.ffn = FFN(cfg, d_ff=d_ff, seed=seed)
            self.is_moe = False
        pd = cfg.param_dtype
        self.ln_attn = RMSNorm(cfg.d_model, cfg.rms_eps, pd)
        self.ln_ffn = RMSNorm(cfg.d_model, cfg.rms_eps, pd)
        if cross:
            self.ln_cross = RMSNorm(cfg.d_model, cfg.rms_eps, pd)
        if cfg.post_norms:
            self.ln_attn_post = RMSNorm(cfg.d_model, cfg.rms_eps, pd)
            self.ln_ffn_post = RMSNorm(cfg.d_model, cfg.rms_eps, pd)

    def init(self, key: jax.Array) -> dict:
        ks = jax.random.split(key, 4)
        p = {"attn": self.attn.init(ks[0]), "ffn": self.ffn.init(ks[1]),
             "ln_attn": self.ln_attn.init(), "ln_ffn": self.ln_ffn.init()}
        if self.cross_attn is not None:
            p["cross"] = self.cross_attn.init(ks[2])
            p["ln_cross"] = self.ln_cross.init()
        if self.cfg.post_norms:
            p["ln_attn_post"] = self.ln_attn_post.init()
            p["ln_ffn_post"] = self.ln_ffn_post.init()
        return p

    def spec(self) -> dict:
        s = {"attn": self.attn.spec(), "ffn": self.ffn.spec(),
             "ln_attn": self.ln_attn.spec(), "ln_ffn": self.ln_ffn.spec()}
        if self.cross_attn is not None:
            s["cross"] = self.cross_attn.spec()
            s["ln_cross"] = self.ln_cross.spec()
        if self.cfg.post_norms:
            s["ln_attn_post"] = self.ln_attn_post.spec()
            s["ln_ffn_post"] = self.ln_ffn_post.spec()
        return s

    def _ffn_res(self, params, x, aux):
        h = self.ln_ffn(params["ln_ffn"], x)
        if self.is_moe:
            h, a = self.ffn(params["ffn"], h)
            aux = {k: aux.get(k, 0.0) + v for k, v in a.items()}
        else:
            h = self.ffn(params["ffn"], h)
        if self.cfg.post_norms:
            h = self.ln_ffn_post(params["ln_ffn_post"], h)
        return x + h, aux

    def __call__(self, params: dict, x: jax.Array, positions: jax.Array,
                 *, enc_out: Optional[jax.Array] = None,
                 causal: bool = True) -> Tuple[jax.Array, dict, dict]:
        """Full-sequence forward. Returns (x, kv_for_cache, aux_losses)."""
        h = self.ln_attn(params["ln_attn"], x)
        h, kv = self.attn(params["attn"], h, positions, causal=causal)
        if self.cfg.post_norms:
            h = self.ln_attn_post(params["ln_attn_post"], h)
        x = x + h
        if self.cross_attn is not None:
            h = self.ln_cross(params["ln_cross"], x)
            h, _ = self.cross_attn(params["cross"], h, positions,
                                   x_kv=enc_out, causal=False)
            x = x + h
        aux: dict = {}
        x, aux = self._ffn_res(params, x, aux)
        return x, kv, aux

    def decode(self, params: dict, x: jax.Array, pos: jax.Array,
               cache: dict) -> Tuple[jax.Array, dict]:
        h = self.ln_attn(params["ln_attn"], x)
        h, new_kv = self.attn.decode(params["attn"], h, pos, cache["self"])
        if self.cfg.post_norms:
            h = self.ln_attn_post(params["ln_attn_post"], h)
        x = x + h
        if self.cross_attn is not None:
            h = self.ln_cross(params["ln_cross"], x)
            h, _ = self.cross_attn.decode(params["cross"], h, pos,
                                          cache["cross"])
            x = x + h
        x, _ = self._ffn_res(params, x, {})
        new_cache = dict(cache)
        new_cache["self"] = new_kv
        return x, new_cache

    def paged_step(self, params: dict, x: jax.Array, pos: jax.Array,
                   n_new: jax.Array, cache: dict, page_table: jax.Array,
                   *, backend: str = "auto", interpret: bool = False
                   ) -> Tuple[jax.Array, dict]:
        """Serving step (decode or prefill chunk) against paged KV."""
        if self.cross_attn is not None:
            raise NotImplementedError("paged serving: no cross-attention")
        h = self.ln_attn(params["ln_attn"], x)
        h, new_kv = self.attn.paged_step(
            params["attn"], h, pos, n_new, cache["self"], page_table,
            backend=backend, interpret=interpret)
        if self.cfg.post_norms:
            h = self.ln_attn_post(params["ln_attn_post"], h)
        x = x + h
        x, _ = self._ffn_res(params, x, {})
        return x, dict(cache, **{"self": new_kv})


class MambaLayer:
    """Norm + Mamba2 mixer with residual (pure-mamba archs have no FFN)."""

    def __init__(self, cfg: ModelConfig, seed: int = 0):
        self.cfg = cfg
        self.mixer = Mamba2Block(cfg, seed=seed)
        self.ln = RMSNorm(cfg.d_model, cfg.rms_eps, cfg.param_dtype)

    def init(self, key: jax.Array) -> dict:
        return {"mixer": self.mixer.init(key), "ln": self.ln.init()}

    def spec(self) -> dict:
        return {"mixer": self.mixer.spec(), "ln": self.ln.spec()}

    def __call__(self, params, x, positions=None, state=None, **_):
        h = self.ln(params["ln"], x)
        h, new_state = self.mixer(params["mixer"], h, state)
        return x + h, new_state, {}

    def decode(self, params, x, pos, cache):
        h = self.ln(params["ln"], x)
        h, new_state = self.mixer.decode(params["mixer"], h, cache)
        return x + h, new_state

    def paged_step(self, params, x, pos, n_new, cache, page_table, *,
                   backend="auto", interpret=False):
        """Serving step: recurrent state rides the same interface as the
        paged KV (cache = per-row {'ssd','conv'}); inactive rows
        (n_new == 0) keep their state unchanged."""
        h = self.ln(params["ln"], x)
        if x.shape[1] == 1:
            h, new_state = self.mixer.decode(params["mixer"], h, cache)
        else:
            h, new_state = self.mixer(params["mixer"], h, cache)
        active = n_new > 0
        new_state = jax.tree.map(
            lambda new, old: jnp.where(
                active.reshape((-1,) + (1,) * (new.ndim - 1)),
                new.astype(old.dtype), old),
            new_state, cache)
        return x + h, new_state


class SharedAttnBlock:
    """zamba2-style shared block: attention + FFN over [h, embedding]
    concatenated input, projected back to d_model. One parameter set,
    applied every ``period`` layers."""

    def __init__(self, cfg: ModelConfig, seed: int = 0):
        self.cfg = cfg
        hc = cfg.hybrid
        d_in = 2 * cfg.d_model if hc.concat_embedding else cfg.d_model
        self.attn = Attention(cfg, seed=seed, d_in=d_in)
        self.ffn = FFN(cfg, d_ff=hc.shared_d_ff, seed=seed, d_in=cfg.d_model)
        pd = cfg.param_dtype
        self.ln_in = RMSNorm(d_in, cfg.rms_eps, pd)
        self.ln_ffn = RMSNorm(cfg.d_model, cfg.rms_eps, pd)

    def init(self, key: jax.Array) -> dict:
        ks = jax.random.split(key, 2)
        return {"attn": self.attn.init(ks[0]), "ffn": self.ffn.init(ks[1]),
                "ln_in": self.ln_in.init(), "ln_ffn": self.ln_ffn.init()}

    def spec(self) -> dict:
        return {"attn": self.attn.spec(), "ffn": self.ffn.spec(),
                "ln_in": self.ln_in.spec(), "ln_ffn": self.ln_ffn.spec()}

    def _input(self, x, emb):
        if self.cfg.hybrid.concat_embedding:
            return jnp.concatenate([x, emb], axis=-1)
        return x

    def __call__(self, params, x, emb, positions):
        h = self.ln_in(params["ln_in"], self._input(x, emb))
        h, kv = self.attn(params["attn"], h, positions)
        x = x + h
        h = self.ln_ffn(params["ln_ffn"], x)
        x = x + self.ffn(params["ffn"], h)
        return x, kv

    def decode(self, params, x, emb, pos, cache):
        h = self.ln_in(params["ln_in"], self._input(x, emb))
        h, new_kv = self.attn.decode(params["attn"], h, pos, cache)
        x = x + h
        h = self.ln_ffn(params["ln_ffn"], x)
        x = x + self.ffn(params["ffn"], h)
        return x, new_kv

    def paged_step(self, params, x, emb, pos, n_new, cache, page_table, *,
                   backend="auto", interpret=False):
        h = self.ln_in(params["ln_in"], self._input(x, emb))
        h, new_kv = self.attn.paged_step(
            params["attn"], h, pos, n_new, cache, page_table,
            backend=backend, interpret=interpret)
        x = x + h
        h = self.ln_ffn(params["ln_ffn"], x)
        x = x + self.ffn(params["ffn"], h)
        return x, new_kv
