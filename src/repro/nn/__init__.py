"""repro.nn — model substrate: layers, attention, FFN/MoE, SSM, models."""
from .common import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, HybridConfig, EncDecConfig,
    SparsityConfig, ShapeConfig, SHAPES, mesh_context, shard, current_mesh,
)
from .layers import Linear, RMSNorm, Embedding, apply_rope  # noqa: F401
from .attention import Attention, chunked_attention, decode_attention  # noqa: F401
from .ffn import FFN, MoE  # noqa: F401
from .ssm import Mamba2Block, ssd_chunked, ssd_decode_step  # noqa: F401
from .transformer import TransformerBlock, MambaLayer, SharedAttnBlock  # noqa: F401
from .model import LM, EncDec, Stack, build_model  # noqa: F401
