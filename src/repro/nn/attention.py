"""GQA attention: XLA-flash (q-chunk scan) for train/prefill, einsum decode.

Why two paths (and when the Pallas kernel is used):

* **train/prefill** — a ``lax.scan`` over query chunks with full-KV logits
  per chunk: memory is O(chunk x S) instead of O(S^2), every op is a plain
  einsum so GSPMD can partition it (sequence-parallel q, sharded heads, or
  both). Sliding-window layers slice a static window span out of KV per
  chunk — structurally skipping out-of-window keys (gemma3's 5:1 local
  layers do 21x less attention work at 32k than a full-attention layer).
* **decode** — one query token: logits are (B, H, 1, S); a single einsum
  chain that GSPMD partitions over a *sequence-sharded* KV cache (context-
  parallel decode; softmax max/sum become all-reduces over the seq axis).
* On real TPUs the Pallas ``kernels.flash_attention`` replaces the q-chunk
  scan inside a ``shard_map`` (hillclimb path); the XLA scan is the
  portable/partitionable reference and what the dry-run lowers.

GQA is computed in grouped form (B, S, Hkv, G, Dh) — KV is never expanded to
Q heads (a 6x memory blowup for granite-34b's 48:1 MQA).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import shard_map
from ..serving import kv_cache as paged_kv
from .common import ModelConfig, current_mesh, logical_to_spec, shard
from .layers import Linear, RMSNorm, apply_rope

_NEG_INF = -1e30


def _softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _attend_block(q_chunk, k_c, v_c, qpos, kpos, *, causal, window,
                  softcap, scale):
    """One (q-block x kv-block) attention with flash-style partials.

    Returns (o_unnormalized_f32, m, l): per-row max, exp-sum, and the
    un-normalized f32 output, so blocks can be merged online.
    """
    logits = jnp.einsum("bqhgd,bkhd->bhgqk",
                        q_chunk.astype(jnp.float32) * scale,
                        k_c.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    m = jnp.max(logits, axis=-1)                      # (B,H,G,Q)
    p = jnp.exp(logits - jnp.maximum(m, _NEG_INF / 2)[..., None])
    p = jnp.where((m > _NEG_INF / 2)[..., None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_c.astype(jnp.float32))
    return o, m, l


def chunked_attention(
    q: jax.Array,  # (B, Sq, Hkv, G, Dh) — grouped query
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    *,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    chunk: int,
    q_offset=0,                 # int or traced (shard-local offset)
    scale: float,
    kv_chunk: Optional[int] = None,  # inner flash loop for long KV
) -> jax.Array:
    """Memory-bounded attention: scan over query chunks, and (for long KV)
    an inner online-softmax scan over KV chunks — the XLA form of flash
    attention, O(chunk_q x chunk_k) live logits."""
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    chunk = min(chunk, sq)
    pad_q = (-sq) % chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    sq_p = sq + pad_q
    n_chunks = sq_p // chunk

    kf = k
    use_window_slice = (window is not None and causal
                        and window + chunk < skv)
    span = min(skv, ((window or 0) + chunk + 127) // 128 * 128) \
        if use_window_slice else skv
    use_kv_scan = (kv_chunk is not None and not use_window_slice
                   and skv > 2 * kv_chunk and skv % kv_chunk == 0)

    def body(_, i):
        q_chunk = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        q_start = i * chunk + q_offset
        qpos = q_start + jnp.arange(chunk)
        if use_window_slice:
            # static-size KV span covering [q_start - window + 1, q_end]
            start = jnp.clip(q_start + chunk - span, 0, skv - span)
            k_c = jax.lax.dynamic_slice_in_dim(kf, start, span, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpos = start + jnp.arange(span)
        else:
            k_c, v_c, kpos = kf, v, jnp.arange(skv)

        if not use_kv_scan:
            o, m, l = _attend_block(q_chunk, k_c, v_c, qpos, kpos,
                                    causal=causal, window=window,
                                    softcap=softcap, scale=scale)
            safe_l = jnp.where(l == 0.0, 1.0, l)
            out = o / jnp.moveaxis(safe_l, -1, 1)[..., None]
            return None, out.astype(q.dtype)

        # online-softmax merge over KV chunks
        nkv = skv // kv_chunk

        def kv_body(carry, j):
            acc, m_run, l_run = carry
            k_j = jax.lax.dynamic_slice_in_dim(k_c, j * kv_chunk, kv_chunk,
                                               axis=1)
            v_j = jax.lax.dynamic_slice_in_dim(v_c, j * kv_chunk, kv_chunk,
                                               axis=1)
            kpos_j = j * kv_chunk + jnp.arange(kv_chunk)
            o_j, m_j, l_j = _attend_block(q_chunk, k_j, v_j, qpos, kpos_j,
                                          causal=causal, window=window,
                                          softcap=softcap, scale=scale)
            m_new = jnp.maximum(m_run, m_j)
            c_old = jnp.where(m_run > _NEG_INF / 2,
                              jnp.exp(m_run - m_new), 0.0)
            c_new = jnp.where(m_j > _NEG_INF / 2,
                              jnp.exp(m_j - m_new), 0.0)
            l_new = l_run * c_old + l_j * c_new
            acc = acc * jnp.moveaxis(c_old, -1, 1)[..., None] \
                + o_j * jnp.moveaxis(c_new, -1, 1)[..., None]
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, chunk, hkv, g, dh), jnp.float32)
        m0 = jnp.full((b, hkv, g, chunk), _NEG_INF)
        l0 = jnp.zeros((b, hkv, g, chunk))
        # checkpoint: without it the scan stashes per-KV-chunk logits
        # residuals (o_j, m_j) for backward — O(S) memory again
        kv_body_ck = jax.checkpoint(
            kv_body, policy=jax.checkpoint_policies.nothing_saveable)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_body_ck, (acc0, m0, l0), jnp.arange(nkv))
        safe_l = jnp.where(l_run == 0.0, 1.0, l_run)
        out = acc / jnp.moveaxis(safe_l, -1, 1)[..., None]
        return None, out.astype(q.dtype)

    if n_chunks == 1:
        _, out = body(None, 0)
    else:
        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
        # (n_chunks, B, chunk, Hkv, G, Dh) -> (B, Sq_p, Hkv, G, Dh)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, hkv, g, dh)
    return out[:, :sq] if pad_q else out


def seq_parallel_attention(
    qg: jax.Array,  # (B, Sq, Hkv, G, Dh) — grouped query, seq shardable
    k: jax.Array,   # (B, Skv, Hkv, Dh)
    v: jax.Array,
    **kw,
) -> jax.Array:
    """Sequence-parallel attention via shard_map over the seq mesh axis.

    Each device runs the chunked-flash scan on its local query span against
    the full KV (replicated into the region — GSPMD inserts the all-gather,
    which for GQA KV is small). Without this, the q-chunk scan's
    dynamic-slice on a sharded seq axis forces GSPMD to *replicate* the
    whole attention computation on every model shard (measured 16x compute
    + memory waste at mesh size 16). This wrapper is also exactly where the
    Pallas flash kernel drops in on real TPUs.
    """
    from jax.sharding import PartitionSpec as P

    mesh = current_mesh()
    spec_q = logical_to_spec("batch", "seq", None, None, None)
    seq_ax = spec_q[1]
    if mesh is None or seq_ax is None:
        return chunked_attention(qg, k, v, **kw)
    n_shards = int(np.prod([mesh.shape[a] for a in
                            (seq_ax if isinstance(seq_ax, tuple)
                             else (seq_ax,))]))
    if qg.shape[1] % n_shards or qg.shape[1] // n_shards < 1:
        return chunked_attention(qg, k, v, **kw)
    s_local = qg.shape[1] // n_shards
    spec_kv = logical_to_spec("batch", None, None, None)

    def local(qg_l, k_l, v_l):
        idx = jax.lax.axis_index(seq_ax)
        return chunked_attention(qg_l, k_l, v_l,
                                 q_offset=idx * s_local, **kw)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec_q, spec_kv, spec_kv),
        out_specs=spec_q, check_vma=False)
    return fn(qg, k, v)


def decode_attention(
    q: jax.Array,  # (B, 1, Hkv, G, Dh)
    k: jax.Array,  # (B, S, Hkv, Dh) — full cache (seq possibly sharded)
    v: jax.Array,
    *,
    pos: jax.Array,  # current absolute position (q attends to <= pos)
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
) -> jax.Array:
    skv = k.shape[1]
    logits = jnp.einsum("bqhgd,bkhd->bhgqk",
                        q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    logits = _softcap(logits, softcap)
    kpos = jnp.arange(skv)
    mask = kpos <= pos
    if window is not None:
        mask &= kpos > pos - window
    logits = jnp.where(mask[None, None, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


class Attention:
    """GQA self- or cross-attention with rope, optional qk-norm, window,
    logit softcap, and optional pre-defined-sparse projections."""

    def __init__(self, cfg: ModelConfig, *, window: Optional[int] = None,
                 cross: bool = False, seed: int = 0, qk_norm: bool = False,
                 d_in: Optional[int] = None):
        self.cfg = cfg
        self.window = window
        self.cross = cross
        self.qk_norm = qk_norm
        h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        self.h, self.kv, self.dh = h, kv, dh
        self.groups = h // kv
        d_in = d_in or cfg.d_model
        sp = cfg.sparsity
        rho = sp.rho_attn
        attn_sp = dataclasses.replace(sp, enabled=sp.enabled and rho is not None)
        pd = cfg.param_dtype
        mk = lambda n_in, n_out, s, ax: Linear(
            n_in, n_out, bias=cfg.qkv_bias and not cross,
            rho=rho if rho is not None else 1.0, sp=attn_sp, seed=seed + s,
            dtype=pd, logical_axes=ax)
        self.wq = mk(d_in, h * dh, 1, ("embed", "qheads"))
        self.wk = mk(d_in, kv * dh, 2, ("embed", "kvheads"))
        self.wv = mk(d_in, kv * dh, 3, ("embed", "kvheads"))
        self.wo = Linear(h * dh, cfg.d_model, bias=False,
                         rho=rho if rho is not None else 1.0, sp=attn_sp,
                         seed=seed + 4, dtype=pd,
                         logical_axes=("qheads", "embed"))
        if qk_norm:
            self.qnorm = RMSNorm(dh, cfg.rms_eps, pd)
            self.knorm = RMSNorm(dh, cfg.rms_eps, pd)

    def init(self, key: jax.Array) -> dict:
        ks = jax.random.split(key, 4)
        p = {"q": self.wq.init(ks[0]), "k": self.wk.init(ks[1]),
             "v": self.wv.init(ks[2]), "o": self.wo.init(ks[3])}
        if self.qk_norm:
            p["qnorm"] = self.qnorm.init()
            p["knorm"] = self.knorm.init()
        return p

    def spec(self) -> dict:
        s = {"q": self.wq.spec(), "k": self.wk.spec(), "v": self.wv.spec(),
             "o": self.wo.spec()}
        if self.qk_norm:
            s["qnorm"] = self.qnorm.spec()
            s["knorm"] = self.knorm.spec()
        return s

    # -- qkv ----------------------------------------------------------------

    def _qkv(self, params, x, x_kv, positions):
        cfg = self.cfg
        b = x.shape[0]
        q = self.wq(params["q"], x).reshape(b, -1, self.h, self.dh)
        src = x if x_kv is None else x_kv
        k = self.wk(params["k"], src).reshape(b, -1, self.kv, self.dh)
        v = self.wv(params["v"], src).reshape(b, -1, self.kv, self.dh)
        if self.qk_norm:
            q = self.qnorm(params["qnorm"], q)
            k = self.knorm(params["knorm"], k)
        if not self.cross:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    # -- full-sequence (train / prefill) -------------------------------------

    def __call__(self, params: dict, x: jax.Array, positions: jax.Array,
                 *, x_kv: Optional[jax.Array] = None,
                 causal: bool = True) -> Tuple[jax.Array, dict]:
        """Returns (output, kv) where kv = {'k','v'} for cache seeding."""
        cfg = self.cfg
        b, sq, _ = x.shape
        q, k, v = self._qkv(params, x, x_kv, positions)
        q = shard(q, "batch", "seq", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        qg = q.reshape(b, sq, self.kv, self.groups, self.dh)
        causal = causal and not self.cross
        o = seq_parallel_attention(
            qg, k, v, causal=causal, window=self.window,
            softcap=cfg.logit_softcap, chunk=cfg.attn_chunk,
            kv_chunk=cfg.attn_kv_chunk, scale=self.dh ** -0.5)
        o = o.reshape(b, sq, self.h * self.dh)
        o = shard(o, "batch", "seq", None)
        return self.wo(params["o"], o), {"k": k, "v": v}

    # -- single-token decode --------------------------------------------------

    def decode(self, params: dict, x: jax.Array, pos: jax.Array,
               cache: dict) -> Tuple[jax.Array, dict]:
        """x: (B, 1, d); cache: {'k','v'}: (B, S_max, Hkv, Dh) seq-sharded.

        Returns (out, updated_cache). For cross-attention the cache holds the
        (static) encoder KV and is not updated.
        """
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        q, k_new, v_new = self._qkv(params, x, None if not self.cross else x,
                                    positions)
        if self.cross:
            k, v = cache["k"], cache["v"]
        else:
            k = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
            cache = {"k": k, "v": v}
        qg = q.reshape(b, 1, self.kv, self.groups, self.dh)
        o = decode_attention(
            qg, k.astype(q.dtype), v.astype(q.dtype),
            pos=pos if not self.cross else k.shape[1] - 1,
            window=self.window if not self.cross else None,
            softcap=self.cfg.logit_softcap, scale=self.dh ** -0.5)
        o = o.reshape(b, 1, self.h * self.dh)
        return self.wo(params["o"], o), cache

    # -- paged serving step (decode or chunked prefill) -----------------------

    def paged_step(self, params: dict, x: jax.Array, pos: jax.Array,
                   n_new: jax.Array, cache: dict, page_table: jax.Array,
                   *, backend: str = "auto", interpret: bool = False
                   ) -> Tuple[jax.Array, dict]:
        """One serving step against a paged KV cache.

        x: (B, C, d) — C == 1 is a decode step (per-row positions, routed
        through the paged-attention kernel); C > 1 is one chunk of prefill
        (causal within the chunk, attending to previously-cached pages via
        gather). pos: (B,) tokens already cached per row; n_new: (B,) valid
        tokens in this chunk (0 = inactive row: its KV writes land on the
        discard page and its output is garbage the engine ignores).
        cache: {'k_pages','v_pages'}: (P+1, page, Hkv, Dh), shared page
        pool addressed through ``page_table`` (B, max_pages). Returns
        (out (B, C, d), updated cache).

        Int8 cache: when the cache carries ``k_scale``/``v_scale``
        ((P+1, page) f32, see ``serving.kv_cache``), pages are int8 —
        writes quantize at append, reads dequantize in kernel/post-gather.
        """
        if self.cross:
            raise NotImplementedError("paged serving: no cross-attention")
        cfg = self.cfg
        b, c = x.shape[:2]
        k_pages, v_pages = cache["k_pages"], cache["v_pages"]
        quant = "k_scale" in cache
        page_size = k_pages.shape[1]
        trash = k_pages.shape[0] - 1
        positions = pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        valid = jnp.arange(c)[None] < n_new[:, None]

        q, k_new, v_new = self._qkv(params, x, None, positions)
        phys, off = paged_kv.physical_addresses(
            page_table, positions, valid, page_size, trash)
        if quant:
            k_scale, v_scale = cache["k_scale"], cache["v_scale"]
            k_pages, v_pages, k_scale, v_scale = paged_kv.write_kv_quant(
                k_pages, v_pages, k_scale, v_scale, k_new, v_new, phys, off)
        else:
            k_scale = v_scale = None
            k_pages, v_pages = paged_kv.write_kv(
                k_pages, v_pages, k_new, v_new, phys, off)
        lengths = pos + n_new
        scale = self.dh ** -0.5

        if c == 1:
            from ..kernels.flash_attention import paged_decode_attention
            qg = q.reshape(b, self.kv, self.groups, self.dh)
            o = paged_decode_attention(
                qg, k_pages, v_pages, page_table, lengths,
                window=self.window, softcap=cfg.logit_softcap,
                scale=scale, backend=backend, interpret=interpret,
                k_scale=k_scale, v_scale=v_scale)
            o = o.reshape(b, 1, self.h * self.dh).astype(x.dtype)
        else:
            # chunk prefill: gather this batch row's logical KV view and
            # run masked grouped attention (causal against everything
            # already in the pages, including this just-written chunk)
            k = paged_kv.gather_kv(k_pages, page_table)
            v = paged_kv.gather_kv(v_pages, page_table)
            if quant:
                ks = paged_kv.gather_scales(k_scale, page_table)
                vs = paged_kv.gather_scales(v_scale, page_table)
                k = k.astype(jnp.float32) * ks[:, :, None, None]
                v = v.astype(jnp.float32) * vs[:, :, None, None]
            k = k.astype(q.dtype)
            v = v.astype(q.dtype)
            qg = q.reshape(b, c, self.kv, self.groups, self.dh)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk",
                                qg.astype(jnp.float32) * scale,
                                k.astype(jnp.float32))
            logits = _softcap(logits, cfg.logit_softcap)
            kpos = jnp.arange(k.shape[1])
            mask = kpos[None, None] <= positions[:, :, None]   # (B, C, S)
            if self.window is not None:
                mask &= kpos[None, None] > positions[:, :, None] \
                    - self.window
            mask &= valid[..., None]
            logits = jnp.where(mask[:, None, None], logits, _NEG_INF)
            m = jnp.max(logits, axis=-1, keepdims=True)
            p = jnp.exp(logits - jnp.maximum(m, _NEG_INF / 2))
            p = jnp.where(m > _NEG_INF / 2, p, 0.0)
            l = jnp.sum(p, axis=-1, keepdims=True)
            p = p / jnp.where(l == 0.0, 1.0, l)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
            o = o.reshape(b, c, self.h * self.dh).astype(x.dtype)
        out = self.wo(params["o"], o)
        new_cache = {"k_pages": k_pages, "v_pages": v_pages}
        if quant:
            new_cache["k_scale"] = k_scale
            new_cache["v_scale"] = v_scale
        return out, new_cache
