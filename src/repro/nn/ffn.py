"""FFN and Mixture-of-Experts blocks.

The FFN junctions (up/gate/down) are where the paper's pre-defined sparsity
attaches in every assigned architecture: they hold the dominant share of
parameters (DESIGN.md §4), mirroring the paper's observation that the big
early junctions tolerate the most sparsity. Per-junction densities follow
the paper's trend 3 (later junctions denser): ``rho_ffn = (rho_up, rho_down)``.

MoE has two interchangeable implementations:

* ``gshard``   — one-hot dispatch/combine einsums. Pure GSPMD data flow; the
                 partitioner shards E over 'model'. Simple and robust, but
                 the dispatch einsum costs O(T*E*C*d) — often more FLOPs than
                 the experts themselves (this shows up in the §Roofline
                 useful-flops ratio and is a hillclimb target).
* ``shardmap`` — explicit expert parallelism: local top-k routing, capacity-
                 bucketed dispatch buffers, ``lax.all_to_all`` over the
                 'model' axis to the expert owners, batched expert FFN,
                 reverse all-to-all, local combine. This is the production
                 path (the all-to-all is visible in the compiled HLO and in
                 the collective roofline term).

Both are differentiable and agree numerically (tests/test_moe.py).

Expert junctions can be pre-defined sparse too
(``SparsityConfig.moe_sparsity``): each expert's up/gate/down weight
becomes a stacked block-sparse slab ``(E, n_rb, d_in_b, bL, bR)`` over ONE
shared ``BlockPattern`` per junction, and ``_expert_ffn`` — the expert
compute of BOTH dispatch modes — executes through the batched
``kernels.ops.csd_matmul`` path (expert-major Pallas grid on TPU, vmapped
slot-sweeps on XLA). The dense stacked einsums live on as the oracle
``kernels.ref.moe_expert_ffn_ref``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import shard_map
from ..core.block_pattern import fit_block_pattern
from ..kernels import ops as kops
from .common import (ModelConfig, MoEConfig, current_mesh,
                     junction_shard_kwargs, shard)
from .layers import Linear, activation

# activation names the fused csd_matmul epilogue understands (the registry
# binds gelu and gelu_tanh to the same tanh-approx function); shared by the
# dense-FFN and MoE expert junction paths.
_FUSABLE = {"relu": "relu", "gelu": "gelu", "gelu_tanh": "gelu"}


class FFN:
    """(Gated) feed-forward junction pair, optionally pre-defined sparse."""

    def __init__(self, cfg: ModelConfig, d_ff: Optional[int] = None,
                 seed: int = 0, d_in: Optional[int] = None):
        self.cfg = cfg
        d_ff = d_ff or cfg.d_ff
        d_in = d_in or cfg.d_model
        sp = cfg.sparsity
        rho_up, rho_down = sp.rho_ffn if sp.enabled else (1.0, 1.0)
        pd = cfg.param_dtype
        self.up = Linear(d_in, d_ff, rho=rho_up, sp=sp, seed=seed + 11,
                         dtype=pd, logical_axes=("embed", "mlp"))
        self.gate = Linear(d_in, d_ff, rho=rho_up, sp=sp, seed=seed + 12,
                           dtype=pd, logical_axes=("embed", "mlp")) \
            if cfg.ffn_gated else None
        self.down = Linear(d_ff, cfg.d_model, rho=rho_down, sp=sp,
                           seed=seed + 13, dtype=pd,
                           logical_axes=("mlp", "embed"))
        self.act = activation(cfg.act)

    def init(self, key: jax.Array) -> dict:
        ks = jax.random.split(key, 3)
        p = {"up": self.up.init(ks[0]), "down": self.down.init(ks[1])}
        if self.gate is not None:
            p["gate"] = self.gate.init(ks[2])
        return p

    def spec(self) -> dict:
        s = {"up": self.up.spec(), "down": self.down.spec()}
        if self.gate is not None:
            s["gate"] = self.gate.spec()
        return s

    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        fused = _FUSABLE.get(self.cfg.act)
        if self.gate is not None:
            h = self.up(params["up"], x)
            # the activation fuses into the *gate* junction's epilogue
            g = self.gate(params["gate"], x, activation=fused)
            if fused is None:
                g = self.act(g)
            h = g * h
        else:
            h = self.up(params["up"], x, activation=fused)
            if fused is None:
                h = self.act(h)
        h = shard(h, "batch", "seq", "mlp_act")
        return self.down(params["down"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


class MoE:
    """Routed experts (+ optional always-on shared experts)."""

    def __init__(self, cfg: ModelConfig, seed: int = 0,
                 impl: str = "shardmap"):
        assert cfg.moe is not None
        self.cfg = cfg
        self.mc = cfg.moe
        self.impl = impl
        self.d = cfg.d_model
        self.d_e = self.mc.d_expert
        self.act = activation(cfg.act)
        pd = cfg.param_dtype
        self.pd = jnp.dtype(pd)
        self.seed = seed
        # Pre-defined sparse expert junctions: one pattern per junction
        # family, shared by every expert (the batched csd_matmul layout).
        sp = cfg.sparsity
        self.backend = sp.backend
        self.up_pat = self.gate_pat = self.down_pat = None
        if sp.enabled and sp.moe_sparsity:
            rho_up, rho_down = sp.rho_ffn
            self.up_pat = fit_block_pattern(self.d, self.d_e, rho_up, sp,
                                            seed=seed + 31,
                                            weight_dtype=self.pd)
            self.gate_pat = fit_block_pattern(self.d, self.d_e, rho_up, sp,
                                              seed=seed + 32,
                                              weight_dtype=self.pd)
            self.down_pat = fit_block_pattern(self.d_e, self.d, rho_down,
                                              sp, seed=seed + 33,
                                              weight_dtype=self.pd)
        if self.mc.n_shared:
            self.shared = FFN(cfg, d_ff=self.mc.n_shared * self.d_e,
                              seed=seed + 29)
        else:
            self.shared = None

    def _expert_w(self, key, pat, n_in, n_out, E):
        """One stacked expert weight: block-sparse slab when the junction
        has a pattern, dense (E, n_in, n_out) otherwise."""
        if pat is not None:
            fan_in = pat.d_in_b * pat.block_in
            return jax.random.normal(
                key, (E, pat.n_rb, pat.d_in_b, pat.block_in, pat.block_out),
                self.pd) * np.sqrt(1.0 / fan_in)
        return jax.random.normal(key, (E, n_in, n_out), self.pd) \
            * np.sqrt(1.0 / n_in)

    # expert weights are stored stacked: (E, d, d_e) / (E, d_e, d) dense,
    # (E, n_rb, d_in_b, bL, bR) when the junction is pre-defined sparse
    def init(self, key: jax.Array) -> dict:
        mc, d, d_e = self.mc, self.d, self.d_e
        ks = jax.random.split(key, 5)
        E = mc.n_routed
        p = {
            "router": jax.random.normal(ks[0], (d, E), self.pd)
            * np.sqrt(1.0 / d),
            "up": self._expert_w(ks[1], self.up_pat, d, d_e, E),
            "gate": self._expert_w(ks[2], self.gate_pat, d, d_e, E),
            "down": self._expert_w(ks[3], self.down_pat, d_e, d, E),
        }
        if self.shared is not None:
            p["shared"] = self.shared.init(ks[4])
        return p

    def spec(self) -> dict:
        def wspec(pat, dense_axes):
            # sparse slab (E, n_rb, d_in_b, bL, bR). The sharded dim must
            # match the dispatch mode's compute partition, or every step
            # pays a reshard at shard_map entry: shardmap dispatch shards
            # experts over the model axis ("expert"); local dispatch runs
            # the model-parallel junction path, which chunks the
            # block-row dim ("slab"). Both rules resolve to the same
            # axis, so they cannot be annotated together.
            if pat is None:
                return dense_axes
            return ("expert", None, None, None, None) \
                if self.impl == "shardmap" \
                else (None, "slab", None, None, None)
        s = {"router": (None, None),
             "up": wspec(self.up_pat, ("expert", "embed", None)),
             "gate": wspec(self.gate_pat, ("expert", "embed", None)),
             "down": wspec(self.down_pat, ("expert", None, "embed"))}
        if self.shared is not None:
            s["shared"] = self.shared.spec()
        return s

    def capacity(self, t_local: int) -> int:
        mc = self.mc
        c = int(np.ceil(t_local * mc.top_k / mc.n_routed
                        * mc.capacity_factor))
        return max(c, 1)

    # -- routing (shared by both impls) -------------------------------------

    def _route(self, params, x2d):
        """x2d: (T, d) -> gates (T,k), ids (T,k), aux losses."""
        mc = self.mc
        logits = (x2d.astype(jnp.float32)
                  @ params["router"].astype(jnp.float32))  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, mc.top_k)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
        # Switch-style load balance + router z-loss. ce = fraction of
        # tokens whose top-1 lands on each expert: a bincount (segment
        # count), not a (T, E) one-hot materialization — ids carry no
        # gradient either way, so only the intermediate changes
        ce = jnp.bincount(ids[:, 0], length=mc.n_routed).astype(
            jnp.float32) / ids.shape[0]
        me = jnp.mean(probs, axis=0)
        lb_loss = mc.n_routed * jnp.sum(me * ce)
        z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        aux = {"moe_lb": lb_loss, "moe_z": mc.router_zloss * z_loss}
        return gates, ids, aux

    def _junction(self, xe, w, pat, activation=None, sharded=False,
                  w_scale=None):
        """One stacked expert junction: batched csd_matmul when pre-defined
        sparse, stacked einsum (the kernels.ref oracle form) when dense.
        ``sharded`` opts into the model-parallel junction path (per-expert
        slabs partitioned over the slab axis) when the installed rules and
        this junction's pattern allow it. ``w_scale`` selects the int8
        slab path (inference only — the slab enters uncast)."""
        cdt = xe.dtype
        if pat is not None:
            kw = junction_shard_kwargs(pat) if sharded else {}
            if w_scale is not None:
                return kops.csd_matmul(xe, w, pat, activation=activation,
                                       backend=self.backend,
                                       w_scale=w_scale, **kw)
            return kops.csd_matmul(xe, w.astype(cdt), pat,
                                   activation=activation,
                                   backend=self.backend, **kw)
        y = jnp.einsum("ecd,edf->ecf", xe, w.astype(cdt))
        return kops.apply_activation(y, activation)

    def _expert_ffn(self, up, gate, down, xe, sharded=False,
                    scales=(None, None, None)):
        """xe: (E_loc, C, d) -> (E_loc, C, d), batched over experts — the
        expert compute of BOTH dispatch modes (gshard-style local and
        shard_map expert-parallel). Each junction routes through the
        batched block-sparse csd_matmul path when it carries a pattern;
        a fusable activation rides the gate junction's epilogue.

        ``sharded=True`` (local dispatch mode only — the shard_map mode
        already spends the model axis on expert parallelism) partitions
        every expert's slab over the slab axis: the 5-D batched kernels
        run shard-local with the expert index still the leading grid dim.

        ``scales`` = (up_scale, gate_scale, down_scale): per-block f32
        scales of int8 expert slabs (from ``quantize_tree``).
        """
        s_up, s_gate, s_down = scales
        fused = _FUSABLE.get(self.cfg.act) if self.gate_pat is not None \
            else None
        h = self._junction(xe, up, self.up_pat, sharded=sharded,
                           w_scale=s_up)
        g = self._junction(xe, gate, self.gate_pat, activation=fused,
                           sharded=sharded, w_scale=s_gate)
        if fused is None:
            g = self.act(g)
        return self._junction(g * h, down, self.down_pat, sharded=sharded,
                              w_scale=s_down)

    # -- local (single-shard) sort-based dispatch ----------------------------

    def _dispatch_local(self, x2d, gates, ids, capacity):
        """Build (E, C) token-index and gate buffers from local routing.

        Gather form: after the stable sort by expert id, expert ``e``'s
        assignments occupy sorted rows ``[starts[e], starts[e]+counts[e])``
        — buffer cell ``(e, c)`` is a ``jnp.take`` at ``starts[e]+c``
        (over-capacity tails fall off the end of the window). This
        replaces the old scatter build (``.at[sid, pos].set``), whose
        (T*k -> E*(C+1)) scatter dominated dispatch cost at low expert
        density; same buffers, same drop policy.
        """
        mc = self.mc
        T = x2d.shape[0]
        k, E, C = mc.top_k, mc.n_routed, capacity
        flat_ids = ids.reshape(-1)
        order = jnp.argsort(flat_ids, stable=True)
        stok = (order // k).astype(jnp.int32)
        sgate = gates.reshape(-1)[order]
        counts = jnp.bincount(flat_ids, length=E)
        starts = jnp.cumsum(counts) - counts
        gidx = starts[:, None] + jnp.arange(C)[None]       # (E, C)
        valid = jnp.arange(C)[None] < counts[:, None]
        gidx = jnp.clip(gidx, 0, T * k - 1)
        buf_tok = jnp.where(valid, jnp.take(stok, gidx),
                            jnp.int32(T))
        buf_gate = jnp.where(valid, jnp.take(sgate, gidx), 0.0)
        return buf_tok, buf_gate

    def _combine_local(self, ye, buf_tok, buf_gate, T):
        """Weight expert outputs by their gates and segment-sum them back
        onto token rows (row T is the dispatch-padding sink)."""
        d = ye.shape[-1]
        yw = ye * buf_gate[..., None].astype(ye.dtype)
        y = jax.ops.segment_sum(yw.reshape(-1, d), buf_tok.reshape(-1),
                                num_segments=T + 1)
        return y[:T]

    def _moe_local(self, params, x2d, capacity):
        gates, ids, aux = self._route(params, x2d)
        buf_tok, buf_gate = self._dispatch_local(x2d, gates, ids, capacity)
        T, d = x2d.shape
        xp = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
        xe = xp[buf_tok]  # (E, C, d)
        ye = self._expert_ffn(params["up"], params["gate"], params["down"],
                              xe, sharded=True,
                              scales=(params.get("up_scale"),
                                      params.get("gate_scale"),
                                      params.get("down_scale")))
        return self._combine_local(ye, buf_tok, buf_gate, T), aux

    # -- expert-parallel shard_map implementation ----------------------------

    def _moe_shardmap(self, params, x2d_shape_hint, x, mesh, ep_axis):
        """x: (B, S, d). Experts sharded over ``ep_axis``; tokens keep their
        (batch, seq) sharding. all_to_all moves capacity buffers to expert
        owners and back within each data row."""
        from jax.sharding import PartitionSpec as P
        from .common import logical_to_spec

        mc = self.mc
        n_ep = mesh.shape[ep_axis]
        E, k = mc.n_routed, mc.top_k
        e_loc = E // n_ep
        x_spec = logical_to_spec("batch", "seq", None)

        def w_spec(pat):
            # expert dim sharded over ep_axis; dense (E, n, n) weights have
            # 2 trailing dims, sparse slabs (E, n_rb, d_in_b, bL, bR) have 4
            return P(ep_axis, *([None] * (2 if pat is None else 4)))
        r_spec = P(None, None)
        all_axes = tuple(mesh.axis_names)
        quant = "up_scale" in params

        def local_fn(router, up, gate, down, xl, *sc):
            scales = sc if quant else (None, None, None)
            b, s, d = xl.shape
            t_loc = b * s
            x2d = xl.reshape(t_loc, d)
            gates, ids, aux = self._route({"router": router}, x2d)
            c_src = self.capacity(t_loc)
            buf_tok, buf_gate = self._dispatch_local(x2d, gates, ids, c_src)
            xp = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
            xe = xp[buf_tok]  # (E, C_src, d)
            # ship capacity buffers to expert owners: E = n_ep * e_loc
            xr = jax.lax.all_to_all(
                xe.reshape(n_ep, e_loc, c_src, d), ep_axis, 0, 0,
                tiled=False)  # (n_ep, e_loc, C_src, d): sources stacked
            xr = jnp.moveaxis(xr, 0, 1).reshape(e_loc, n_ep * c_src, d)
            ye = self._expert_ffn(up, gate, down, xr, scales=scales)
            ye = jnp.moveaxis(ye.reshape(e_loc, n_ep, c_src, d), 1, 0)
            yb = jax.lax.all_to_all(ye, ep_axis, 0, 0, tiled=False)
            yb = yb.reshape(E, c_src, d)  # back at the source, per expert
            y = self._combine_local(yb, buf_tok, buf_gate, t_loc)
            aux = {n: jax.lax.pmean(v, all_axes) for n, v in aux.items()}
            return y.reshape(b, s, d), aux

        in_specs = (r_spec, w_spec(self.up_pat), w_spec(self.gate_pat),
                    w_spec(self.down_pat), x_spec)
        operands = [params["router"], params["up"], params["gate"],
                    params["down"], x]
        if quant:
            # (E, n_rb, d_in_b) scales ride the expert sharding of their slab
            in_specs = in_specs + (P(ep_axis, None, None),) * 3
            operands += [params["up_scale"], params["gate_scale"],
                         params["down_scale"]]
        fn = shard_map(
            local_fn, mesh=mesh, in_specs=in_specs,
            out_specs=(x_spec, {n: P() for n in ("moe_lb", "moe_z")}),
            check_vma=False)
        return fn(*operands)

    # -- public --------------------------------------------------------------

    def __call__(self, params: dict, x: jax.Array) -> Tuple[jax.Array, dict]:
        """x: (B, S, d) -> (y, aux_losses)."""
        cfg, mc = self.cfg, self.mc
        b, s, d = x.shape
        mesh = current_mesh()
        use_sm = (self.impl == "shardmap" and mesh is not None
                  and "model" in mesh.axis_names
                  and mc.n_routed % mesh.shape["model"] == 0)
        if use_sm:
            y, aux = self._moe_shardmap(params, None, x, mesh, "model")
        else:
            x2d = x.reshape(b * s, d)
            y2d, aux = self._moe_local(params, x2d,
                                       self.capacity(b * s))
            y = y2d.reshape(b, s, d)
        if self.shared is not None:
            y = y + self.shared(params["shared"], x)
        return y.astype(x.dtype), aux
