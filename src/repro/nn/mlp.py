"""The paper's own model: a pre-defined sparse MLP (eqs. (2)-(4)).

Faithful reproduction settings (paper §IV-A): ReLU hidden activations,
softmax output, He weight init, bias init 0.1 (0.0 for Reuters-style runs),
Adam, L2 penalty on weights scaled down with sparsity. Per-junction pattern
method/density/z are configurable — exactly the knobs of Tables I/II and
Figs. 6-12.

``mode='mask'`` trains a dense weight under a fixed 0/1 mask: bit-identical
learning dynamics to per-edge processing (the gradient of a masked weight is
the masked gradient), at dense-matmul speed — this is what the benchmark
harness uses. ``mode='gather'`` stores only |W_i| weights (the storage the
hardware sees, Table I). ``mode='block_gather'``/``'block_scatter'`` lift the
pattern to MXU-tile granularity and run forward AND backward through the one
accelerated junction primitive ``kernels.ops.csd_matmul``, with the hidden
ReLU fused into the kernel epilogue (the accelerated-training configuration
of §III).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import sparsity
from ..core.block_pattern import shrink_to_divisor
from ..core.sparse_linear import SparseLinear, SparseLinearSpec


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    n_net: Tuple[int, ...] = (800, 100, 10)
    # per-junction densities; None = fully connected
    rho: Optional[Tuple[float, ...]] = None
    method: str = "clashfree"          # clashfree | structured | random
    cf_type: int = 1
    dither: bool = False
    z: Optional[Tuple[int, ...]] = None  # degree-of-parallelism per junction
    mode: str = "mask"     # mask | gather | block_gather | block_scatter
    block: int = 16        # tile size cap for the block modes (shrunk per
    #                        junction until it divides both dims)
    bias_init: float = 0.1
    seed: int = 0

    @property
    def n_junctions(self) -> int:
        return len(self.n_net) - 1

    def junction_rho(self, i: int) -> float:
        if self.rho is None:
            return 1.0
        return self.rho[i]


class SparseMLP:
    def __init__(self, cfg: MLPConfig):
        self.cfg = cfg
        self.layers = []
        for i in range(cfg.n_junctions):
            rho = cfg.junction_rho(i)
            mode = cfg.mode if rho < 1.0 else "dense"
            if cfg.method == "random" and rho < 1.0:
                mode = "mask"  # random patterns have no fixed degrees
            n_in, n_out = cfg.n_net[i], cfg.n_net[i + 1]
            # no micro-block guard here: paper-scale MLP junctions are tiny
            bi = shrink_to_divisor(n_in, cfg.block)
            bo = shrink_to_divisor(n_out, cfg.block)
            spec = SparseLinearSpec(
                n_in=n_in, n_out=n_out, rho=rho,
                mode=mode, method=cfg.method, cf_type=cfg.cf_type,
                dither=cfg.dither, seed=cfg.seed * 1000 + i,
                block_in=bi, block_out=bo, use_bias=True)
            self.layers.append(SparseLinear(spec))

    # -- parameters -----------------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.layers))
        params = {}
        for i, (layer, k) in enumerate(zip(self.layers, keys)):
            p = layer.init(k)
            p["b"] = jnp.full_like(p["b"], cfg.bias_init)
            params[f"j{i}"] = p
        return params

    def n_weights(self) -> int:
        """|W| summed over junctions (paper's complexity measure)."""
        return sum(l.n_weights for l in self.layers)

    def density(self) -> float:
        num = self.n_weights()
        den = sum(l.spec.n_in * l.spec.n_out for l in self.layers)
        return num / den

    # -- forward / loss ---------------------------------------------------------

    def logits(self, params: dict, x: jax.Array) -> jax.Array:
        h = x
        last = len(self.layers) - 1
        for i, layer in enumerate(self.layers):
            # hidden ReLU fused into the junction (kernel epilogue for the
            # block modes); the output junction stays linear (softmax'd in
            # the loss)
            h = layer(params[f"j{i}"], h,
                      activation="relu" if i < last else None)
        return h

    def loss(self, params: dict, x: jax.Array, y: jax.Array,
             l2: float = 0.0) -> jax.Array:
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))
        if l2 > 0.0:
            wsum = sum(jnp.sum(params[f"j{i}"]["w"] ** 2)
                       for i in range(len(self.layers)))
            nll = nll + l2 * wsum
        return nll

    def accuracy(self, params: dict, x: jax.Array, y: jax.Array) -> jax.Array:
        return jnp.mean((jnp.argmax(self.logits(params, x), -1) == y)
                        .astype(jnp.float32))


def train_mlp(
    model: SparseMLP,
    data: Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray],
    *,
    epochs: int = 20,
    batch: int = 256,
    lr: float = 1e-3,
    l2: float = 1e-4,
    seed: int = 0,
    lr_decay: float = 1e-5,
) -> Tuple[dict, float]:
    """Minimal Adam training loop for the repro benchmarks.

    Returns (params, test_accuracy). L2 is scaled by density (the paper
    reduces the penalty for sparser nets, §IV-A).
    """
    x_tr, y_tr, x_te, y_te = data
    params = model.init(jax.random.key(seed))
    l2_eff = l2 * model.density()

    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, x, y, t):
        g = jax.grad(lambda p: model.loss(p, x, y, l2_eff))(params)
        lr_t = lr / (1.0 + lr_decay * t)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        tt = t + 1.0
        def upd(p, mm, vv):
            mh = mm / (1 - b1 ** tt)
            vh = vv / (1 - b2 ** tt)
            return p - lr_t * mh / (jnp.sqrt(vh) + eps)
        params = jax.tree.map(upd, params, m, v)
        return params, m, v

    n = x_tr.shape[0]
    rng = np.random.default_rng(seed)
    t = 0.0
    for _ in range(epochs):
        order = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            idx = order[s:s + batch]
            params, opt_m, opt_v = step(params, opt_m, opt_v,
                                        jnp.asarray(x_tr[idx]),
                                        jnp.asarray(y_tr[idx]), t)
            t += 1.0
    acc = float(model.accuracy(params, jnp.asarray(x_te),
                               jnp.asarray(y_te)))
    return params, acc
