"""Model configuration and sharding context shared by the whole nn stack."""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.quant import QuantConfig  # noqa: F401 — re-exported config knob


# ---------------------------------------------------------------------------
# Sharding context: model code calls shard(x, ...) with *logical* axes; the
# trainer / dry-run installs a mesh so the constraints become real. With no
# mesh installed (unit tests, CPU smokes) shard() is the identity.
# ---------------------------------------------------------------------------

_MESH: contextvars.ContextVar[Optional[jax.sharding.Mesh]] = \
    contextvars.ContextVar("repro_mesh", default=None)

# logical name -> mesh axis name (or tuple of axes), installed with the mesh
_AXIS_RULES: contextvars.ContextVar[dict] = \
    contextvars.ContextVar("repro_axis_rules", default={})


@contextlib.contextmanager
def mesh_context(mesh: jax.sharding.Mesh, axis_rules: dict):
    t1 = _MESH.set(mesh)
    t2 = _AXIS_RULES.set(dict(axis_rules))
    try:
        yield
    finally:
        _MESH.reset(t1)
        _AXIS_RULES.reset(t2)


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return _MESH.get()


def logical_to_spec(*logical: Optional[str]) -> P:
    rules = _AXIS_RULES.get()
    axes = []
    for name in logical:
        ax = rules.get(name) if name is not None else None
        axes.append(ax)
    return P(*axes)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names (no-op without mesh)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = logical_to_spec(*logical)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def junction_shard_ctx(pattern):
    """(mesh, axis) when the sharded block-sparse junction path applies
    under the installed mesh/rules, else ``None``.

    The decision is the runtime side of the policy's ``"slab"`` rule: the
    rule must resolve to a single mesh axis of size > 1 and the pattern's
    block-rows must split evenly over it (``can_partition`` — the same
    divisibility ``sanitize`` applies to the slab's storage sharding, so
    compute partition and weight chunks always agree)."""
    mesh = _MESH.get()
    if mesh is None or pattern is None:
        return None
    ax = _AXIS_RULES.get().get("slab")
    if not isinstance(ax, str) or ax not in mesh.axis_names:
        return None
    from ..core.block_pattern import can_partition
    if not can_partition(pattern, int(mesh.shape[ax])):
        return None
    return mesh, ax


def junction_shard_kwargs(pattern) -> dict:
    """``csd_matmul`` kwargs selecting the sharded junction path, or ``{}``
    when it doesn't apply — the ONE place the gating decision plus kwarg
    spelling lives, shared by every junction call site (``nn.layers``,
    ``nn.ffn``, ``core.sparse_linear``)."""
    ctx = junction_shard_ctx(pattern)
    if ctx is None:
        return {}
    return {"mesh": ctx[0], "axis": ctx[1]}


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """Where and how pre-defined sparsity is applied inside a model.

    ``rho_ffn`` follows the paper's per-junction density guideline: the
    FFN up/gate junction gets ``rho_ffn[0]`` and the down junction
    ``rho_ffn[1]`` (trend 3: later junctions denser).
    """

    enabled: bool = False
    rho_ffn: Tuple[float, float] = (0.5, 0.75)
    rho_attn: Optional[float] = None  # None = attention projections dense
    # MoE expert junctions (up/gate/down of every routed expert) become
    # pre-defined block-sparse too, executed through the batched
    # (expert-major) csd_matmul path with one pattern shared across
    # experts. Densities follow rho_ffn. Off by default: expert matmuls
    # keep the dense stacked-einsum form unless opted in.
    moe_sparsity: bool = False
    method: str = "clashfree"
    cf_type: int = 1
    dither: bool = False
    # Block aspect adopted after the §Perf hillclimb: slot-gather traffic
    # scales 1/block_out and accumulator traffic 1/block_in, so tall-wide
    # (256 x 1024) tiles cut the sparse-FFN HBM bytes 2.2x vs the square
    # 128x128 MXU-tile baseline (EXPERIMENTS.md §Perf, iterations 2-3).
    block_in: int = 256
    block_out: int = 1024
    seed: int = 0
    # auto = pallas on TPU, xla elsewhere; all junctions route through the
    # one csd_matmul primitive either way
    backend: str = "auto"  # auto | xla | pallas
    # inference-path int8 weight/KV quantization (core.quant.QuantConfig);
    # None = full width. Training always runs full width — the engine (or
    # an explicit quantize_tree call) applies this once at load.
    quant: Optional["QuantConfig"] = None


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0           # per-expert hidden size
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3
    first_layer_dense: bool = False   # deepseek-moe: layer 0 is dense FFN
    dense_d_ff: int = 0               # hidden size of that dense layer


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    a_init_range: Tuple[float, float] = (1.0, 16.0)
    dt_limit: Tuple[float, float] = (1e-3, 1e2)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style: mamba backbone + a single shared attention block applied
    every ``period`` layers (parameter sharing across applications)."""
    period: int = 6
    shared_d_ff: int = 8192
    concat_embedding: bool = True  # shared block sees [h, embedding] (2*d)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    n_decoder_layers: int = 12


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 8192

    # block structure
    block_kind: str = "attn"     # attn | mamba | hybrid (see layer_pattern)
    layer_pattern: Tuple[str, ...] = ()  # per-layer kinds, cycled; () = all attn
    attn_window: Optional[int] = None    # sliding window for 'local' layers
    local_global_ratio: int = 0          # k local : 1 global (0 = all global)
    logit_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    post_norms: bool = False     # gemma2/3 sandwich norms
    act: str = "silu"            # silu | gelu | relu
    ffn_gated: bool = True       # SwiGLU/GeGLU vs plain MLP
    tie_embeddings: bool = True
    scale_embed: bool = False    # gemma multiplies embeddings by sqrt(d)

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    input_mode: str = "tokens"   # tokens | embeddings (audio/vlm frontends)
    frontend_dim: int = 0        # embedding dim delivered by the stub frontend

    sparsity: SparsityConfig = dataclasses.field(default_factory=SparsityConfig)

    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 512        # q-chunk for the XLA flash scan
    attn_kv_chunk: int = 1024    # inner flash KV chunk for long sequences
    loss_chunk: int = 512        # seq chunk for cross-entropy

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived -----------------------------------------------------------

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Resolved per-layer kind: 'global', 'local', 'mamba'."""
        if self.layer_pattern:
            pat = self.layer_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.block_kind == "mamba":
            return ("mamba",) * self.n_layers
        if self.local_global_ratio > 0:
            k = self.local_global_ratio
            out = []
            for i in range(self.n_layers):
                out.append("local" if (i % (k + 1)) != k else "global")
            return tuple(out)
        return ("global",) * self.n_layers

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def param_dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)
