"""Mamba2 — SSD (state-space duality) blocks, chunked scan + O(1) decode.

Implements the SSD algorithm of arXiv:2405.21060 in pure JAX: the sequence
is split into chunks; within a chunk the quadratic (attention-like) form is
used, across chunks a recurrent state (B, H, P, N) is carried by
``lax.scan``. This keeps compute O(S * chunk) and state O(1), which is what
makes the ``long_500k`` cell runnable for mamba2/zamba2 while the pure
attention architectures are skipped (DESIGN.md §4).

Decode is a single recurrence step against a persistent state cache — the
SSM analogue of a KV cache, with constant memory in sequence length.

Note (§Arch-applicability): the paper's pre-defined sparsity applies to the
in/out *projection junctions* here; the SSD recurrence itself has no weight
junction to sparsify.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, SSMConfig, shard
from .layers import Linear, RMSNorm


def _segsum(a: jax.Array) -> jax.Array:
    """Stable 'segment sum' for decay matrices: out[i, j] = sum_{j<k<=i} a_k
    (lower-triangular), -inf above the diagonal. a: (..., Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) — post-softplus
    a: jax.Array,    # (H,) — negative decay rates
    b_in: jax.Array,  # (B, S, G, N)
    c_in: jax.Array,  # (B, S, G, N)
    d_skip: jax.Array,  # (H,)
    *,
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B, S, H, P), final_state: (B, H, P, N))."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[-2:]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # dt=0 at padded steps makes them identity in the recurrence
        # (decay exp(0)=1, update dt*B*x = 0), so the final state is exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // chunk
    reps = h // g

    f32 = jnp.float32
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    af = a.astype(f32)

    # chunked views; heads kept GROUPED (B, nc, Q, G, hg, ...) so B/C are
    # never expanded to per-head copies, and every einsum below is strictly
    # two-operand with an explicit contraction — a 3/4-operand einsum here
    # lets opt_einsum materialize a (.., Q, H, P, N) outer product (tens of
    # GB per layer at train_4k scale).
    hg = reps

    def ck(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xc = ck(xf).reshape(bsz, nc, chunk, g, hg, p)      # (B,nc,Q,G,hg,P)
    dtc = ck(dtf)                                      # (B,nc,Q,H)
    dtg = dtc.reshape(bsz, nc, chunk, g, hg)
    bc = ck(b_in.astype(f32))                          # (B,nc,Q,G,N)
    cc = ck(c_in.astype(f32))
    adt = dtc * af[None, None, None, :]                # (B,nc,Q,H)
    adt_cum = jnp.cumsum(adt, axis=2)                  # within-chunk cumsum

    # intra-chunk (quadratic) term: per-group scores, per-head decay
    lmat = jnp.exp(_segsum(jnp.moveaxis(adt, -1, 2)))  # (B,nc,H,Q,Q)
    lmat = lmat.reshape(bsz, nc, g, hg, chunk, chunk)
    scores = jnp.einsum("bnqgx,bnkgx->bngqk", cc, bc)  # (B,nc,G,Q,Q)
    # mw[q,k] = scores[q,k] * exp(segsum) * dt[k]  (fused elementwise chain)
    mw = scores[:, :, :, None] * lmat \
        * jnp.moveaxis(dtg, 2, 4)[:, :, :, :, None, :]  # (B,nc,G,hg,Q,K)
    y_intra = jnp.einsum("bnghqk,bnkghp->bnqghp", mw, xc)

    # chunk-final states: sum_k decay_k dt_k x_k B_k^T (contract over k)
    decay_to_end = jnp.exp(adt_cum[:, :, -1:, :] - adt_cum)  # (B,nc,Q,H)
    w = (decay_to_end * dtc).reshape(bsz, nc, chunk, g, hg)
    xw = xc * w[..., None]                             # (B,nc,Q,G,hg,P)
    states = jnp.einsum("bnqghp,bnqgx->bnghpx", xw, bc)
    states = states.reshape(bsz, nc, h, p, n)          # (B,nc,H,P,N)
    chunk_decay = jnp.exp(adt_cum[:, :, -1, :])        # (B,nc,H)

    # inter-chunk recurrence
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)
    else:
        h0 = h0.astype(f32)

    def scan_fn(carry, inp):
        st, dec = inp  # (B, H, P, N), (B, H)
        new = st + dec[:, :, None, None] * carry
        return new, carry  # output: state *entering* the chunk

    last, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B, nc, H, P, N)

    # inter-chunk output: C_i · (decay_in[i] * h_prev) — contract over N
    h_prev_g = h_prev.reshape(bsz, nc, g, hg, p, n)
    ch = jnp.einsum("bnqgx,bnghpx->bnqghp", cc, h_prev_g)
    decay_in = jnp.exp(adt_cum).reshape(bsz, nc, chunk, g, hg)
    y_inter = ch * decay_in[..., None]

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + xf * d_skip.astype(f32)[None, None, :, None]
    if pad:
        y = y[:, :s_orig]
    return y.astype(x.dtype), last


def ssd_decode_step(
    x: jax.Array,   # (B, 1, H, P)
    dt: jax.Array,  # (B, 1, H)
    a: jax.Array,   # (H,)
    b_in: jax.Array,  # (B, 1, G, N)
    c_in: jax.Array,  # (B, 1, G, N)
    d_skip: jax.Array,
    state: jax.Array,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    bsz, _, h, p = x.shape
    g = b_in.shape[-2]
    reps = h // g
    head_group = jnp.arange(h) // reps
    f32 = jnp.float32
    bh = jnp.take(b_in.astype(f32), head_group, axis=2)[:, 0]  # (B, H, N)
    ch = jnp.take(c_in.astype(f32), head_group, axis=2)[:, 0]
    dtf = dt.astype(f32)[:, 0]          # (B, H)
    dec = jnp.exp(dtf * a.astype(f32))  # (B, H)
    xf = x.astype(f32)[:, 0]            # (B, H, P)
    upd = jnp.einsum("bh,bhp,bhx->bhpx", dtf, xf, bh)
    new_state = dec[:, :, None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhx,bhpx->bhp", ch, new_state)
    y = y + xf * d_skip.astype(f32)[None, :, None]
    return y[:, None].astype(x.dtype), new_state.astype(state.dtype)


class Mamba2Block:
    """Full Mamba2 mixer: in_proj -> causal depthwise conv -> SSD -> gated
    RMSNorm -> out_proj."""

    def __init__(self, cfg: ModelConfig, seed: int = 0):
        assert cfg.ssm is not None
        self.cfg = cfg
        sc = cfg.ssm
        self.sc = sc
        d = cfg.d_model
        self.d_inner = sc.expand * d
        self.n_heads = self.d_inner // sc.head_dim
        self.conv_dim = self.d_inner + 2 * sc.n_groups * sc.d_state
        proj_out = (2 * self.d_inner + 2 * sc.n_groups * sc.d_state
                    + self.n_heads)
        sp = cfg.sparsity
        rho_up, rho_down = sp.rho_ffn if sp.enabled else (1.0, 1.0)
        pd = cfg.param_dtype
        self.in_proj = Linear(d, proj_out, rho=rho_up, sp=sp,
                              seed=seed + 21, dtype=pd,
                              logical_axes=("embed", "mlp"))
        self.out_proj = Linear(self.d_inner, d, rho=rho_down, sp=sp,
                               seed=seed + 22, dtype=pd,
                               logical_axes=("mlp", "embed"))
        self.norm = RMSNorm(self.d_inner, cfg.rms_eps, pd,
                            zero_centered=False)

    def init(self, key: jax.Array) -> dict:
        sc = self.sc
        ks = jax.random.split(key, 5)
        lo, hi = sc.a_init_range
        a_init = jnp.exp(jax.random.uniform(
            ks[2], (self.n_heads,), jnp.float32,
            np.log(lo), np.log(hi)))
        dt = jnp.exp(jax.random.uniform(
            ks[3], (self.n_heads,), jnp.float32,
            np.log(1e-3), np.log(1e-1)))
        dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
        return {
            "in_proj": self.in_proj.init(ks[0]),
            "out_proj": self.out_proj.init(ks[1]),
            "conv_w": jax.random.normal(
                ks[4], (sc.d_conv, self.conv_dim), jnp.float32)
            * np.sqrt(1.0 / sc.d_conv),
            "conv_b": jnp.zeros((self.conv_dim,), jnp.float32),
            "a_log": jnp.log(a_init),
            "dt_bias": dt_bias,
            "d_skip": jnp.ones((self.n_heads,), jnp.float32),
            "norm": self.norm.init(),
        }

    def spec(self) -> dict:
        return {
            "in_proj": self.in_proj.spec(),
            "out_proj": self.out_proj.spec(),
            "conv_w": (None, "mlp"),
            "conv_b": ("mlp",),
            "a_log": (None,),
            "dt_bias": (None,),
            "d_skip": (None,),
            "norm": self.norm.spec(),
        }

    def _split(self, proj):
        sc = self.sc
        di, gn = self.d_inner, sc.n_groups * sc.d_state
        z = proj[..., :di]
        xbc = proj[..., di:di + self.conv_dim]
        dt = proj[..., di + self.conv_dim:]
        return z, xbc, dt

    def _conv(self, params, xbc, carry: Optional[jax.Array]):
        """Causal depthwise conv along seq. carry: (B, d_conv-1, conv_dim)."""
        kw = params["conv_w"].astype(xbc.dtype)  # (K, C)
        k = kw.shape[0]
        if carry is None:
            pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
        else:
            pad = carry.astype(xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
        new_carry = xp[:, -(k - 1):, :]
        out = sum(xp[:, i:i + xbc.shape[1], :] * kw[i] for i in range(k))
        out = out + params["conv_b"].astype(xbc.dtype)
        return jax.nn.silu(out), new_carry

    def _pre_ssd(self, params, x, conv_carry):
        sc = self.sc
        proj = self.in_proj(params["in_proj"], x)
        z, xbc, dt = self._split(proj)
        xbc, new_carry = self._conv(params, xbc, conv_carry)
        di, gn = self.d_inner, sc.n_groups * sc.d_state
        xs = xbc[..., :di]
        b_in = xbc[..., di:di + gn].reshape(*xbc.shape[:2], sc.n_groups,
                                            sc.d_state)
        c_in = xbc[..., di + gn:].reshape(*xbc.shape[:2], sc.n_groups,
                                          sc.d_state)
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))
        dt = jnp.clip(dt, *sc.dt_limit)
        xh = xs.reshape(*xs.shape[:2], self.n_heads, sc.head_dim)
        a = -jnp.exp(params["a_log"].astype(jnp.float32))
        return z, xh, dt, a, b_in, c_in, new_carry

    def __call__(self, params: dict, x: jax.Array,
                 state: Optional[dict] = None
                 ) -> Tuple[jax.Array, Optional[dict]]:
        """Full-sequence form. state (optional) = {'ssd','conv'} carried in
        (for chunk-streamed prefill); returns output + final state."""
        cfg, sc = self.cfg, self.sc
        conv_carry = state["conv"] if state else None
        h0 = state["ssd"] if state else None
        z, xh, dt, a, b_in, c_in, conv_out = self._pre_ssd(
            params, x, conv_carry)
        y, h_last = ssd_chunked(xh, dt, a, b_in, c_in, params["d_skip"],
                                chunk=sc.chunk, h0=h0)
        y = y.reshape(*x.shape[:2], self.d_inner)
        y = self.norm(params["norm"], y * jax.nn.silu(z.astype(y.dtype)))
        out = self.out_proj(params["out_proj"], y)
        new_state = {"ssd": h_last, "conv": conv_out}
        return out, new_state

    def decode(self, params: dict, x: jax.Array,
               state: dict) -> Tuple[jax.Array, dict]:
        """One-token step. state = {'ssd': (B,H,P,N), 'conv': (B,K-1,C)}."""
        z, xh, dt, a, b_in, c_in, conv_out = self._pre_ssd(
            params, x, state["conv"])
        y, new_ssd = ssd_decode_step(xh, dt, a, b_in, c_in,
                                     params["d_skip"], state["ssd"])
        y = y.reshape(*x.shape[:2], self.d_inner)
        y = self.norm(params["norm"], y * jax.nn.silu(z.astype(y.dtype)))
        out = self.out_proj(params["out_proj"], y)
        return out, {"ssd": new_ssd, "conv": conv_out}

    def init_state(self, batch: int, dtype=jnp.float32) -> dict:
        sc = self.sc
        return {
            "ssd": jnp.zeros((batch, self.n_heads, sc.head_dim, sc.d_state),
                             dtype),
            "conv": jnp.zeros((batch, sc.d_conv - 1, self.conv_dim), dtype),
        }
