"""Model assembly: scanned layer stacks, LM / encoder-decoder wrappers,
KV/SSM caches, and the chunked cross-entropy loss.

The stack splits layers into (prologue, scanned pattern units, epilogue):

* ``prologue``  — unscanned leading layers (deepseek-moe's dense layer 0);
* ``scan``      — ``n_groups`` repetitions of the architecture's repeating
                  unit (gemma3: LLLLLG, gemma2: LG, zamba2: 6 mamba + one
                  shared-attention application), parameters stacked on a
                  leading group axis and applied under ``lax.scan``;
* ``epilogue``  — unscanned remainder (34 = 5x6 + 4 for gemma3).

Pattern-sharing note: scanned groups share each unit-slot's pre-defined
sparsity pattern (the pattern is compile-time static, so it cannot vary
along the scan axis). Prologue/epilogue/unit-slots each get distinct seeds.
This mirrors the FPGA reusing one address generator per pipeline stage.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, dtype_of, shard
from .layers import Embedding, Linear, RMSNorm, activation
from .transformer import MambaLayer, SharedAttnBlock, TransformerBlock


def _detect_unit(kinds: Tuple[str, ...]) -> int:
    n = len(kinds)
    for u in range(1, n + 1):
        groups = n // u
        if groups == 0:
            continue
        ok = all(kinds[i] == kinds[i % u] for i in range(groups * u))
        if ok and (groups > 1 or u == n):
            return u
    return n


def _make_block(cfg: ModelConfig, kind: str, seed: int, cross: bool,
                layer_idx: int):
    if kind == "mamba":
        return MambaLayer(cfg, seed=seed)
    return TransformerBlock(cfg, kind, seed=seed, cross=cross,
                            layer_idx=layer_idx)


def _stack_trees(trees: List[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


class Stack:
    """A stack of blocks executed as prologue + scan(groups) + epilogue."""

    def __init__(self, cfg: ModelConfig, kinds: Tuple[str, ...],
                 cross: bool = False, seed: int = 0, causal: bool = True):
        self.cfg = cfg
        self.causal = causal
        self.cross = cross
        n = len(kinds)
        self.n_layers = n
        pro_n = 1 if (cfg.moe is not None and cfg.moe.first_layer_dense
                      and not cross) else 0
        self.prologue = [
            _make_block(cfg, kinds[i], seed + 1000 * i, cross, i)
            for i in range(pro_n)]
        rest = kinds[pro_n:]
        self.hybrid = cfg.hybrid is not None and "mamba" in kinds
        if self.hybrid:
            unit = cfg.hybrid.period
        else:
            unit = _detect_unit(rest) if rest else 1
        self.unit_len = unit
        self.n_groups = len(rest) // unit if unit else 0
        scanned = self.n_groups * unit
        self.unit_blocks = [
            _make_block(cfg, rest[u], seed + 10 * u + 1, cross, pro_n + u)
            for u in range(unit)] if self.n_groups else []
        self.epilogue = [
            _make_block(cfg, rest[scanned + i],
                        seed + 2000 + 10 * i, cross, pro_n + scanned + i)
            for i in range(len(rest) - scanned)]
        self.shared = SharedAttnBlock(cfg, seed=seed + 501) \
            if self.hybrid else None

    # -- params --------------------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        keys = iter(jax.random.split(key, 4096))
        p: dict = {}
        p["prologue"] = [b.init(next(keys)) for b in self.prologue]
        if self.n_groups:
            per_slot = []
            for u, blk in enumerate(self.unit_blocks):
                per_group = [blk.init(next(keys))
                             for _ in range(self.n_groups)]
                per_slot.append(_stack_trees(per_group))
            p["scan"] = per_slot
        else:
            p["scan"] = []
        p["epilogue"] = [b.init(next(keys)) for b in self.epilogue]
        if self.shared is not None:
            p["shared"] = self.shared.init(next(keys))
        return p

    def spec(self) -> dict:
        s: dict = {}
        s["prologue"] = [b.spec() for b in self.prologue]
        if self.n_groups:
            # scanned params get a leading 'layers' (stacked) axis
            s["scan"] = [
                jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                             blk.spec(),
                             is_leaf=lambda x: isinstance(x, tuple))
                for blk in self.unit_blocks]
        else:
            s["scan"] = []
        s["epilogue"] = [b.spec() for b in self.epilogue]
        if self.shared is not None:
            s["shared"] = self.shared.spec()
        return s

    # -- helpers ---------------------------------------------------------------

    def _apply_block(self, blk, p, x, positions, enc_out, emb, collect):
        if isinstance(blk, MambaLayer):
            x, state, aux = blk(p, x, positions)
            kv = state if collect else None
        else:
            x, kv_raw, aux = blk(p, x, positions, enc_out=enc_out,
                                 causal=self.causal)
            kv = {"self": kv_raw} if collect else None
        return x, kv, aux

    # -- forward ----------------------------------------------------------------

    def __call__(self, params: dict, x: jax.Array, positions: jax.Array,
                 *, enc_out: Optional[jax.Array] = None,
                 emb: Optional[jax.Array] = None,
                 collect_cache: bool = False
                 ) -> Tuple[jax.Array, dict, dict]:
        cfg = self.cfg
        aux_tot: dict = {}
        cache: dict = {"prologue": [], "epilogue": [], "scan": None,
                       "shared": None}

        def add_aux(aux):
            for k, v in aux.items():
                aux_tot[k] = aux_tot.get(k, 0.0) + v

        for blk, p in zip(self.prologue, params["prologue"]):
            x, kv, aux = self._apply_block(blk, p, x, positions, enc_out,
                                           emb, collect_cache)
            add_aux(aux)
            cache["prologue"].append(kv)

        if self.n_groups:
            shared_p = params.get("shared")

            def body(carry, p_unit):
                xc, aux_c = carry
                kvs = []
                aux_g: dict = {}
                for u, blk in enumerate(self.unit_blocks):
                    xc, kv, aux = self._apply_block(
                        blk, p_unit[u], xc, positions, enc_out, emb,
                        collect_cache)
                    kvs.append(kv)
                    for k, v in aux.items():
                        aux_g[k] = aux_g.get(k, 0.0) + v
                kv_sh = None
                if self.shared is not None:
                    xc, kv_sh_raw = self.shared(shared_p, xc, emb, positions)
                    kv_sh = kv_sh_raw if collect_cache else None
                aux_c = {k: aux_c.get(k, 0.0) + aux_g.get(k, 0.0)
                         for k in set(aux_c) | set(aux_g)}
                ys = (kvs, kv_sh) if collect_cache else None
                return (xc, aux_c), ys

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            aux0 = {}
            if any(getattr(b, "is_moe", False) for b in self.unit_blocks):
                aux0 = {"moe_lb": 0.0, "moe_z": 0.0}
            (x, aux_s), ys = jax.lax.scan(body, (x, aux0),
                                          tuple(params["scan"]))
            add_aux(aux_s)
            if collect_cache:
                cache["scan"], cache["shared"] = ys

        for blk, p in zip(self.epilogue, params["epilogue"]):
            x, kv, aux = self._apply_block(blk, p, x, positions, enc_out,
                                           emb, collect_cache)
            add_aux(aux)
            cache["epilogue"].append(kv)

        return x, cache, aux_tot

    # -- decode -------------------------------------------------------------------

    def decode(self, params: dict, x: jax.Array, pos: jax.Array,
               cache: dict, emb: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, dict]:
        new_cache: dict = {"prologue": [], "epilogue": [],
                           "scan": None, "shared": None}
        for blk, p, c in zip(self.prologue, params["prologue"],
                             cache["prologue"]):
            x, nc = blk.decode(p, x, pos, c)
            new_cache["prologue"].append(nc)

        if self.n_groups:
            shared_p = params.get("shared")

            def body(xc, xs):
                p_unit, c_unit, c_sh = xs
                ncs = []
                for u, blk in enumerate(self.unit_blocks):
                    xc, nc = blk.decode(p_unit[u], xc, pos, c_unit[u])
                    ncs.append(nc)
                nc_sh = None
                if self.shared is not None:
                    xc, nc_sh = self.shared.decode(shared_p, xc, emb, pos,
                                                   c_sh)
                return xc, (ncs, nc_sh)

            x, (ncs, nc_sh) = jax.lax.scan(
                body, x, (tuple(params["scan"]), cache["scan"],
                          cache["shared"]))
            new_cache["scan"], new_cache["shared"] = ncs, nc_sh

        for blk, p, c in zip(self.epilogue, params["epilogue"],
                             cache["epilogue"]):
            x, nc = blk.decode(p, x, pos, c)
            new_cache["epilogue"].append(nc)
        return x, new_cache

    # -- paged serving step ---------------------------------------------------

    def paged_step(self, params: dict, x: jax.Array, pos: jax.Array,
                   n_new: jax.Array, cache: dict, page_table: jax.Array,
                   slot_ids: jax.Array, emb: Optional[jax.Array] = None,
                   *, backend: str = "auto", interpret: bool = False
                   ) -> Tuple[jax.Array, dict]:
        """One serving step (decode C==1 or a prefill chunk C>1) against the
        paged cache built by ``init_paged_cache``.

        Attention layers address the shared page pool through
        ``page_table`` (B, max_pages); SSM layers carry per-slot recurrent
        state through the same interface — their state rows are gathered by
        ``slot_ids`` (B,), stepped, and scattered back, so a B=1 prefill
        chunk touches only its own slot's state.
        """
        def apply(blk, p, xc, c):
            if isinstance(blk, MambaLayer):
                rows = jax.tree.map(lambda l: l[slot_ids], c)
                xc, new_rows = blk.paged_step(
                    p, xc, pos, n_new, rows, page_table,
                    backend=backend, interpret=interpret)
                nc = jax.tree.map(
                    lambda l, r: l.at[slot_ids].set(r.astype(l.dtype)),
                    c, new_rows)
                return xc, nc
            return blk.paged_step(p, xc, pos, n_new, c, page_table,
                                  backend=backend, interpret=interpret)

        new_cache: dict = {"prologue": [], "epilogue": [],
                           "scan": None, "shared": None}
        for blk, p, c in zip(self.prologue, params["prologue"],
                             cache["prologue"]):
            x, nc = apply(blk, p, x, c)
            new_cache["prologue"].append(nc)

        if self.n_groups:
            shared_p = params.get("shared")

            def body(xc, xs):
                p_unit, c_unit, c_sh = xs
                ncs = []
                for u, blk in enumerate(self.unit_blocks):
                    xc, nc = apply(blk, p_unit[u], xc, c_unit[u])
                    ncs.append(nc)
                nc_sh = None
                if self.shared is not None:
                    xc, nc_sh = self.shared.paged_step(
                        shared_p, xc, emb, pos, n_new, c_sh, page_table,
                        backend=backend, interpret=interpret)
                return xc, (ncs, nc_sh)

            x, (ncs, nc_sh) = jax.lax.scan(
                body, x, (tuple(params["scan"]), cache["scan"],
                          cache["shared"]))
            new_cache["scan"], new_cache["shared"] = ncs, nc_sh

        for blk, p, c in zip(self.epilogue, params["epilogue"],
                             cache["epilogue"]):
            x, nc = apply(blk, p, x, c)
            new_cache["epilogue"].append(nc)
        return x, new_cache

    # -- cache allocation ------------------------------------------------------------

    def _blk_cache(self, blk, batch: int, s_max: int, dtype,
                   enc_len: int = 0) -> dict:
        cfg = self.cfg
        if isinstance(blk, MambaLayer):
            return blk.mixer.init_state(batch, jnp.float32)
        kvshape = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        c = {"self": {
            "k": shard(jnp.zeros(kvshape, dtype),
                       "batch", "kv_seq", None, None),
            "v": shard(jnp.zeros(kvshape, dtype),
                       "batch", "kv_seq", None, None)}}
        if blk.cross_attn is not None:
            xshape = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
            c["cross"] = {"k": jnp.zeros(xshape, dtype),
                          "v": jnp.zeros(xshape, dtype)}
        return c

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16,
                   enc_len: int = 0) -> dict:
        cache: dict = {
            "prologue": [self._blk_cache(b, batch, s_max, dtype, enc_len)
                         for b in self.prologue],
            "epilogue": [self._blk_cache(b, batch, s_max, dtype, enc_len)
                         for b in self.epilogue],
            "scan": None, "shared": None,
        }
        if self.n_groups:
            def rep(tree):
                return jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x, (self.n_groups,) + x.shape), tree)
            cache["scan"] = [
                rep(self._blk_cache(b, batch, s_max, dtype, enc_len))
                for b in self.unit_blocks]
            if self.shared is not None:
                kvshape = (self.n_groups, batch, s_max,
                           self.cfg.n_kv_heads, self.cfg.head_dim)
                cache["shared"] = {
                    "k": shard(jnp.zeros(kvshape, dtype),
                               None, "batch", "kv_seq", None, None),
                    "v": shard(jnp.zeros(kvshape, dtype),
                               None, "batch", "kv_seq", None, None)}
        return cache

    def reset_slot_state(self, cache: dict, slot: int) -> dict:
        """Zero one slot's recurrent (SSM) state rows in a paged cache —
        called when a freed slot is re-admitted. Attention page buffers
        need no reset (stale KV is masked by sequence length), but Mamba
        state is carried unmasked as the chunk's initial state, so a new
        occupant must not inherit the previous sequence's state."""
        def zero(tree, scanned):
            # scanned mamba state leaves are (G, slots, ...) — slot is
            # axis 1; unscanned are (slots, ...)
            return jax.tree.map(
                lambda l: l.at[:, slot].set(0.0) if scanned
                else l.at[slot].set(0.0), tree)

        new = dict(cache)
        new["prologue"] = [
            zero(c, False) if isinstance(b, MambaLayer) else c
            for b, c in zip(self.prologue, cache["prologue"])]
        new["epilogue"] = [
            zero(c, False) if isinstance(b, MambaLayer) else c
            for b, c in zip(self.epilogue, cache["epilogue"])]
        if self.n_groups:
            new["scan"] = [
                zero(c, True) if isinstance(b, MambaLayer) else c
                for b, c in zip(self.unit_blocks, cache["scan"])]
        return new

    def _blk_paged_cache(self, blk, slots: int, total_pages: int,
                         page_size: int, dtype, quant_kv: bool) -> dict:
        cfg = self.cfg
        if isinstance(blk, MambaLayer):
            return blk.mixer.init_state(slots, jnp.float32)
        if blk.cross_attn is not None:
            raise NotImplementedError(
                "paged serving: cross-attention stacks not supported")
        shape = (total_pages + 1, page_size, cfg.n_kv_heads, cfg.head_dim)
        if quant_kv:
            # int8 pages + per-token f32 scales (see serving.kv_cache);
            # the attention paged_step keys the quantized path off the
            # presence of "k_scale" in its cache dict
            return {"self": {
                "k_pages": jnp.zeros(shape, jnp.int8),
                "v_pages": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:2], jnp.float32),
                "v_scale": jnp.zeros(shape[:2], jnp.float32)}}
        return {"self": {"k_pages": jnp.zeros(shape, dtype),
                         "v_pages": jnp.zeros(shape, dtype)}}

    def init_paged_cache(self, slots: int, total_pages: int,
                         page_size: int, dtype=jnp.bfloat16,
                         quant_kv: bool = False) -> dict:
        """Per-layer page pools (+1 write-discard page each) and per-slot
        SSM state, shaped to mirror ``init_cache``'s tree so the scan
        traversal is identical. ``quant_kv`` makes the per-layer pools
        int8 with per-token scale buffers riding alongside (the shared
        cross-group pool stays full-width: it is written once per step
        and G-replicated reads dominate, so its bandwidth win is
        marginal next to the per-layer pools)."""
        cache: dict = {
            "prologue": [self._blk_paged_cache(b, slots, total_pages,
                                               page_size, dtype, quant_kv)
                         for b in self.prologue],
            "epilogue": [self._blk_paged_cache(b, slots, total_pages,
                                               page_size, dtype, quant_kv)
                         for b in self.epilogue],
            "scan": None, "shared": None,
        }
        if self.n_groups:
            def rep(tree):
                return jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x, (self.n_groups,) + x.shape).copy(), tree)
            cache["scan"] = [
                rep(self._blk_paged_cache(b, slots, total_pages,
                                          page_size, dtype, quant_kv))
                for b in self.unit_blocks]
            if self.shared is not None:
                shape = (self.n_groups, total_pages + 1, page_size,
                         self.cfg.n_kv_heads, self.cfg.head_dim)
                cache["shared"] = {"k_pages": jnp.zeros(shape, dtype),
                                   "v_pages": jnp.zeros(shape, dtype)}
        return cache


# ---------------------------------------------------------------------------
# Language model
# ---------------------------------------------------------------------------


class LM:
    """Decoder-only LM (tokens or stub-frontend embeddings in)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.embed = Embedding(cfg.vocab_size, cfg.d_model, cfg.param_dtype)
        self.stack = Stack(cfg, cfg.layer_kinds)
        self.ln_f = RMSNorm(cfg.d_model, cfg.rms_eps, cfg.param_dtype)
        if cfg.input_mode == "embeddings":
            # 2-layer MLP projector (llava-style); also used for audio stubs
            self.proj_in = Linear(cfg.frontend_dim, cfg.d_model,
                                  dtype=cfg.param_dtype, bias=True,
                                  logical_axes=(None, "embed"))
            self.proj_mid = Linear(cfg.d_model, cfg.d_model,
                                   dtype=cfg.param_dtype, bias=True,
                                   logical_axes=("embed", None))
        self.head = None
        if not cfg.tie_embeddings:
            self.head = Linear(cfg.d_model, cfg.vocab_size,
                               dtype=cfg.param_dtype,
                               logical_axes=("embed", "vocab"))

    def init(self, key: jax.Array) -> dict:
        ks = jax.random.split(key, 5)
        p = {"embed": self.embed.init(ks[0]),
             "stack": self.stack.init(ks[1]),
             "ln_f": self.ln_f.init()}
        if self.cfg.input_mode == "embeddings":
            p["proj_in"] = self.proj_in.init(ks[2])
            p["proj_mid"] = self.proj_mid.init(ks[3])
        if self.head is not None:
            p["head"] = self.head.init(ks[4])
        return p

    def spec(self) -> dict:
        s = {"embed": self.embed.spec(), "stack": self.stack.spec(),
             "ln_f": self.ln_f.spec()}
        if self.cfg.input_mode == "embeddings":
            s["proj_in"] = self.proj_in.spec()
            s["proj_mid"] = self.proj_mid.spec()
        if self.head is not None:
            s["head"] = self.head.spec()
        return s

    # -- embedding in / logits out -------------------------------------------

    def embed_in(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        cdt = dtype_of(cfg)
        if cfg.input_mode == "embeddings":
            x = batch["embeds"].astype(cdt)
            x = self.proj_in(params["proj_in"], x)
            x = jax.nn.gelu(x)
            x = self.proj_mid(params["proj_mid"], x)
        else:
            x = self.embed(params["embed"], batch["tokens"], dtype=cdt)
        if cfg.scale_embed:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
        return shard(x, "batch", "seq", None)

    def logits_fn(self, params: dict, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if self.head is not None:
            logits = self.head(params["head"], h)
        else:
            logits = self.embed.attend(params["embed"], h)
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(
                logits / cfg.final_softcap)
        return logits

    # -- forward / loss ---------------------------------------------------------

    def forward(self, params: dict, batch: dict,
                collect_cache: bool = False):
        cfg = self.cfg
        x = self.embed_in(params, batch)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        emb = x if self.stack.shared is not None else None
        h, cache, aux = self.stack(params["stack"], x, positions, emb=emb,
                                   collect_cache=collect_cache)
        h = self.ln_f(params["ln_f"], h)
        return h, cache, aux

    def loss(self, params: dict, batch: dict) -> Tuple[jax.Array, dict]:
        """Next-token cross entropy, chunked over the sequence."""
        cfg = self.cfg
        h, _, aux = self.forward(params, batch)
        # gather the (seq-sharded) hidden once, in bf16, before chunking —
        # otherwise every chunk's dynamic_slice re-gathers it
        h = shard(h, "batch", None, None)
        labels = batch["labels"]
        b, s = labels.shape
        chunk = min(cfg.loss_chunk, s)
        n_chunks = s // chunk

        def chunk_loss(i):
            hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
            lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
            logits = self.logits_fn(params, hc).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None],
                                       axis=-1)[..., 0]
            valid = (lc >= 0).astype(jnp.float32)
            nll = (logz - gold) * valid
            return jnp.sum(nll), jnp.sum(valid)

        if cfg.remat:
            # without this the loss scan stashes full-vocab logits per
            # chunk for backward — gigabytes per device at 256k vocab
            chunk_loss = jax.checkpoint(
                chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)

        if n_chunks == 1:
            tot, cnt = chunk_loss(0)
        else:
            def body(carry, i):
                t, c = chunk_loss(i)
                return (carry[0] + t, carry[1] + c), None
            (tot, cnt), _ = jax.lax.scan(
                body, (jnp.zeros(()), jnp.zeros(())),
                jnp.arange(n_chunks))
        loss = tot / jnp.maximum(cnt, 1.0)
        metrics = {"loss": loss, "tokens": cnt}
        aux_scale = {"moe_lb": 0.01, "moe_z": 1.0}
        for k, v in aux.items():
            loss = loss + aux_scale.get(k, 1.0) * jnp.asarray(
                v, jnp.float32) / self.stack.n_layers
            metrics[k] = v
        return loss, metrics

    # -- serving ------------------------------------------------------------------

    def prefill(self, params: dict, batch: dict, s_max: int
                ) -> Tuple[jax.Array, dict]:
        """Run the prompt, build a cache of capacity ``s_max``; returns
        (last-token logits, cache)."""
        cfg = self.cfg
        h, kv_new, _ = self.forward(params, batch, collect_cache=True)
        b, s = h.shape[:2]
        cache = self.stack.init_cache(b, s_max, dtype_of(cfg))
        cache = _write_prefill(cache, kv_new, s)
        logits = self.logits_fn(params, h[:, -1:])
        return logits, {"layers": cache, "pos": jnp.asarray(s, jnp.int32)}

    def decode_step(self, params: dict, token: jax.Array, cache: dict
                    ) -> Tuple[jax.Array, dict]:
        """token: (B, 1) int32 (or (B,1,F) embeds). One step of decoding.

        For stub-frontend models (vlm/audio) decode always embeds *text*
        tokens through the embedding table — the frontend only feeds the
        prefix at prefill time (llava: anyres patches, seamless: frames).
        """
        cfg = self.cfg
        pos = cache["pos"]
        if token.ndim == 2:  # token ids
            cdt = dtype_of(cfg)
            x = self.embed(params["embed"], token, dtype=cdt)
            if cfg.scale_embed:
                x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
        else:
            x = self.embed_in(params, {"embeds": token})
        emb = x if self.stack.shared is not None else None
        x, new_layers = self.stack.decode(params["stack"], x, pos,
                                          cache["layers"], emb=emb)
        x = self.ln_f(params["ln_f"], x)
        logits = self.logits_fn(params, x)
        return logits, {"layers": new_layers, "pos": pos + 1}

    # -- paged serving (continuous batching engine) ---------------------------

    def paged_step(self, params: dict, tokens: jax.Array, pos: jax.Array,
                   n_new: jax.Array, cache: dict, page_table: jax.Array,
                   slot_ids: jax.Array, *, backend: str = "auto",
                   interpret: bool = False, all_logits: bool = False
                   ) -> Tuple[jax.Array, dict]:
        """One engine step: tokens (B, C) int32, per-row start positions
        ``pos`` (B,) and valid counts ``n_new`` (B,). C == 1 is a batched
        decode step; C > 1 one prefill chunk or a speculative verify
        chunk (pending token + drafts). Returns (last-valid-token logits
        (B, 1, V), updated paged cache) — or, with ``all_logits=True``
        (static), logits at EVERY chunk position (B, C, V): the verify
        path needs the greedy continuation after each draft to accept the
        longest matching prefix host-side.

        Only token-input decoder-only models serve through this path;
        frontends (embeddings) and enc-dec go through the legacy loop.
        """
        cfg = self.cfg
        if cfg.input_mode != "tokens":
            raise NotImplementedError(
                "paged serving expects token inputs (stub frontends feed "
                "the legacy prefill path)")
        cdt = dtype_of(cfg)
        x = self.embed(params["embed"], tokens, dtype=cdt)
        if cfg.scale_embed:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), cdt)
        emb = x if self.stack.shared is not None else None
        x, new_cache = self.stack.paged_step(
            params["stack"], x, pos, n_new, cache, page_table, slot_ids,
            emb=emb, backend=backend, interpret=interpret)
        x = self.ln_f(params["ln_f"], x)
        if all_logits:
            return self.logits_fn(params, x), new_cache
        idx = jnp.clip(n_new - 1, 0, x.shape[1] - 1)
        h_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self.logits_fn(params, h_last)
        return logits, new_cache


def _write_prefill(cache: dict, kv_new: dict, s: int) -> dict:
    """Write prefill-collected KV (length s) into zero-initialized caches."""
    def write(c, kv):
        if kv is None:
            return c
        if "ssd" in c:  # mamba state: prefill state replaces directly
            return kv
        out = dict(c)
        if "self" in kv and kv["self"] is not None:
            out["self"] = {
                n: jax.lax.dynamic_update_slice_in_dim(
                    c["self"][n], kv["self"][n].astype(c["self"][n].dtype),
                    0, axis=1)
                for n in ("k", "v")}
        return out

    new = dict(cache)
    new["prologue"] = [write(c, kv) for c, kv in
                       zip(cache["prologue"], kv_new["prologue"])]
    new["epilogue"] = [write(c, kv) for c, kv in
                       zip(cache["epilogue"], kv_new["epilogue"])]
    if cache["scan"] is not None and kv_new["scan"] is not None:
        new_scan = []
        for c, kv in zip(cache["scan"], kv_new["scan"]):
            if kv is None:
                new_scan.append(c)
            elif "ssd" in c:
                new_scan.append(kv)
            else:
                out = dict(c)  # keep e.g. zero-initialized 'cross' slots
                out["self"] = {
                    n: jax.lax.dynamic_update_slice_in_dim(
                        c["self"][n],
                        kv["self"][n].astype(c["self"][n].dtype),
                        0, axis=2)  # (G, B, S, H, D)
                    for n in ("k", "v")}
                new_scan.append(out)
        new["scan"] = new_scan
    if cache["shared"] is not None and kv_new["shared"] is not None:
        new["shared"] = {
            n: jax.lax.dynamic_update_slice_in_dim(
                cache["shared"][n],
                kv_new["shared"][n].astype(cache["shared"][n].dtype),
                0, axis=2)
            for n in ("k", "v")}
    return new


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless-m4t backbone)
# ---------------------------------------------------------------------------


class EncDec:
    """Enc-dec transformer; encoder consumes stub frontend embeddings,
    decoder is a causal token LM with cross-attention."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.enc_dec is not None
        self.cfg = cfg
        ed = cfg.enc_dec
        self.embed = Embedding(cfg.vocab_size, cfg.d_model, cfg.param_dtype)
        self.adapter = Linear(cfg.frontend_dim or cfg.d_model, cfg.d_model,
                              bias=True, dtype=cfg.param_dtype,
                              logical_axes=(None, "embed"))
        self.encoder = Stack(cfg, ("global",) * ed.n_encoder_layers,
                             seed=7000, causal=False)
        self.decoder = Stack(cfg, ("global",) * ed.n_decoder_layers,
                             cross=True, seed=9000)
        self.ln_enc = RMSNorm(cfg.d_model, cfg.rms_eps, cfg.param_dtype)
        self.ln_f = RMSNorm(cfg.d_model, cfg.rms_eps, cfg.param_dtype)

    def init(self, key: jax.Array) -> dict:
        ks = jax.random.split(key, 4)
        return {"embed": self.embed.init(ks[0]),
                "adapter": self.adapter.init(ks[1]),
                "encoder": self.encoder.init(ks[2]),
                "decoder": self.decoder.init(ks[3]),
                "ln_enc": self.ln_enc.init(), "ln_f": self.ln_f.init()}

    def spec(self) -> dict:
        return {"embed": self.embed.spec(), "adapter": self.adapter.spec(),
                "encoder": self.encoder.spec(),
                "decoder": self.decoder.spec(),
                "ln_enc": self.ln_enc.spec(), "ln_f": self.ln_f.spec()}

    def encode(self, params: dict, embeds: jax.Array) -> jax.Array:
        cdt = dtype_of(self.cfg)
        x = self.adapter(params["adapter"], embeds.astype(cdt))
        x = shard(x, "batch", "seq", None)
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, _, _ = self.encoder(params["encoder"], x, pos)
        return self.ln_enc(params["ln_enc"], h)

    def forward(self, params: dict, batch: dict,
                collect_cache: bool = False):
        cfg = self.cfg
        enc_out = self.encode(params, batch["embeds"])
        tokens = batch["tokens"]
        cdt = dtype_of(cfg)
        x = self.embed(params["embed"], tokens, dtype=cdt)
        x = shard(x, "batch", "seq", None)
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h, cache, aux = self.decoder(params["decoder"], x, pos,
                                     enc_out=enc_out,
                                     collect_cache=collect_cache)
        h = self.ln_f(params["ln_f"], h)
        return h, cache, aux, enc_out

    def loss(self, params: dict, batch: dict) -> Tuple[jax.Array, dict]:
        h, _, aux, _ = self.forward(params, batch)
        labels = batch["labels"]
        logits = self.embed.attend(params["embed"], h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        valid = (labels >= 0).astype(jnp.float32)
        loss = jnp.sum((logz - gold) * valid) / jnp.maximum(
            jnp.sum(valid), 1.0)
        return loss, {"loss": loss}

    def prefill(self, params: dict, batch: dict, s_max: int):
        cfg = self.cfg
        h, kv_new, _, enc_out = self.forward(params, batch,
                                             collect_cache=True)
        b, s = h.shape[:2]
        cache = self.decoder.init_cache(b, s_max, dtype_of(cfg),
                                        enc_len=enc_out.shape[1])
        cache = _write_prefill(cache, kv_new, s)
        cache = self._fill_cross(params, cache, enc_out)
        logits = self.embed.attend(params["embed"], h[:, -1:])
        return logits, {"layers": cache, "pos": jnp.asarray(s, jnp.int32)}

    def _fill_cross(self, params: dict, cache: dict,
                    enc_out: jax.Array) -> dict:
        """Precompute cross-attention KV from encoder output once."""
        b, se = enc_out.shape[:2]
        cdt = dtype_of(self.cfg)

        def cross_kv(blk, p):
            att = blk.cross_attn
            k = att.wk(p["cross"]["k"], enc_out).reshape(
                b, se, att.kv, att.dh)
            v = att.wv(p["cross"]["v"], enc_out).reshape(
                b, se, att.kv, att.dh)
            return {"k": k.astype(cdt), "v": v.astype(cdt)}

        dparams = params["decoder"]
        for i, blk in enumerate(self.decoder.prologue):
            cache["prologue"][i] = dict(cache["prologue"][i],
                                        cross=cross_kv(blk,
                                                       dparams["prologue"][i]))
        for i, blk in enumerate(self.decoder.epilogue):
            cache["epilogue"][i] = dict(cache["epilogue"][i],
                                        cross=cross_kv(blk,
                                                       dparams["epilogue"][i]))
        if self.decoder.n_groups:
            new_scan = []
            for u, blk in enumerate(self.decoder.unit_blocks):
                kv = jax.vmap(lambda pg: cross_kv(blk, pg))(
                    dparams["scan"][u])  # (G, B, se, kv, dh)
                new_scan.append(dict(cache["scan"][u], cross=kv))
            cache = dict(cache, scan=new_scan)
        return cache

    def decode_step(self, params: dict, token: jax.Array, cache: dict):
        cfg = self.cfg
        pos = cache["pos"]
        x = self.embed(params["embed"], token, dtype=dtype_of(cfg))
        x, new_layers = self.decoder.decode(params["decoder"], x, pos,
                                            cache["layers"])
        x = self.ln_f(params["ln_f"], x)
        logits = self.embed.attend(params["embed"], x)
        return logits, {"layers": new_layers, "pos": pos + 1}


def build_model(cfg: ModelConfig):
    if cfg.enc_dec is not None:
        return EncDec(cfg)
    return LM(cfg)
