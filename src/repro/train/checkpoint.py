"""Sharded, async, integrity-checked checkpointing (no orbax offline).

Layout of a checkpoint directory::

    <dir>/step_000100/
        shard-00000.npz      # this process's addressable shard data
        manifest.json        # step, keypaths, shapes, dtypes, checksums
        COMMITTED            # written last: presence = checkpoint is valid

Fault-tolerance properties:

* atomic commit — writers fill ``step_N.tmp`` then rename; readers only
  trust directories containing ``COMMITTED``. A machine dying mid-write
  never corrupts the restore path.
* multi-host — each process writes only the shards it owns (process 0
  writes the manifest); restore device_puts per-shard with the target
  sharding. (Single-process in this container, but the addressing logic is
  the multi-host one.)
* async — ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread so the step loop is not blocked; ``wait()``
  joins before the next save or exit.
* retention — ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {(_keystr(p)): v for p, v in leaves}, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.process_index = jax.process_index()
        self.process_count = jax.process_count()

    # -- discovery -----------------------------------------------------------

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and
                    os.path.exists(os.path.join(full, "COMMITTED"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------

    def _snapshot(self, tree) -> dict:
        """Device -> host copy of this process's addressable shard data."""
        flat, _ = _flatten(tree)
        out = {}
        for key, v in flat.items():
            if isinstance(v, jax.Array):
                shards = [s for s in v.addressable_shards]
                if len(shards) == 1 or v.is_fully_replicated:
                    out[key] = np.asarray(shards[0].data)
                else:
                    # store per-device shards with their index for restore
                    out[key] = np.asarray(jax.device_get(v))
            else:
                out[key] = np.asarray(v)
        return out

    def _write(self, step: int, host_data: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        shard_file = os.path.join(tmp, f"shard-{self.process_index:05d}.npz")
        np.savez(shard_file, **{k: v for k, v in host_data.items()})
        if self.process_index == 0:
            manifest = {
                "step": step,
                "extra": extra,
                "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                             "sha1": hashlib.sha1(
                                 np.ascontiguousarray(v)).hexdigest()}
                         for k, v in host_data.items()},
                "process_count": self.process_count,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree, extra: Optional[dict] = None,
             async_: bool = False):
        self.wait()
        host = self._snapshot(tree)
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}))
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore -----------------------------------------------------------------

    def restore(self, step: Optional[int], like,
                shardings=None) -> tuple[Any, dict]:
        """Returns (tree, extra). ``like`` provides structure; ``shardings``
        (same structure) triggers device_put with the target sharding."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, f"shard-{self.process_index:05d}.npz"))
        for k, meta in manifest["keys"].items():
            got = hashlib.sha1(np.ascontiguousarray(data[k])).hexdigest()
            if got != meta["sha1"]:
                raise IOError(f"checksum mismatch for {k} in step {step}")
        flat_like, treedef = _flatten(like)
        flat_sh = _flatten(shardings)[0] if shardings is not None else None
        out = []
        for key in flat_like:
            v = data[key]
            if flat_sh is not None:
                v = jax.device_put(v, flat_sh[key])
            out.append(v)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest.get("extra", {})
