"""Launcher-level fault tolerance: heartbeats, stragglers, elastic re-mesh.

JAX SPMD programs are lockstep — a dead or slow host cannot be handled
*inside* a step. Production systems therefore handle failures at the
launcher layer; this module implements that layer's logic so it is testable
without a cluster:

* ``HeartbeatMonitor``  — per-host last-seen timestamps; hosts exceeding the
  timeout are declared dead, hosts whose step lag exceeds the straggler
  threshold are flagged (so the launcher can pre-emptively checkpoint and
  exclude them at the next restart boundary).
* ``remesh_plan``       — given surviving host count, picks the largest
  power-of-two data-parallel degree that the survivors support, keeping the
  model axis intact (TP/EP degree is a property of the checkpointed layout;
  changing it requires resharding, which ``restore`` supports since target
  shardings are an input). Returns the new mesh shape + the batch scaling.
* ``RestartLoop``       — drives try/except around the step function:
  checkpoint-restore, failure counting, backoff. Used by ``launch.train``
  and exercised by tests with injected failures.

At 1000+ nodes the same logic runs in the cluster scheduler; the decisions
(when to declare death, how to shrink the mesh, what to do with stragglers)
are exactly what these functions encode.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

from ..obs import metrics as obs_metrics


@dataclasses.dataclass
class HostState:
    last_seen: float
    step: int = 0


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], timeout_s: float = 60.0,
                 straggler_steps: int = 3,
                 registry: Optional[obs_metrics.Registry] = None):
        self.timeout = timeout_s
        self.straggler_steps = straggler_steps
        now = time.monotonic()
        self.hosts: Dict[str, HostState] = {
            h: HostState(last_seen=now) for h in hosts}
        self.obs = registry if registry is not None \
            else obs_metrics.get_registry()
        self._m_age = self.obs.gauge(
            "ft_heartbeat_age_seconds",
            "seconds since each host's last heartbeat")
        self._m_dead = self.obs.gauge(
            "ft_dead_hosts", "hosts past the heartbeat timeout")
        self._m_strag = self.obs.gauge(
            "ft_stragglers", "live hosts lagging the lead step")

    def beat(self, host: str, step: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        st = self.hosts[host]
        st.last_seen = now
        st.step = step
        self._m_age.set(0.0, host=host)

    def dead(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        for h, st in self.hosts.items():
            self._m_age.set(max(now - st.last_seen, 0.0), host=h)
        d = [h for h, st in self.hosts.items()
             if now - st.last_seen > self.timeout]
        self._m_dead.set(len(d))
        return d

    def stragglers(self, now: Optional[float] = None) -> List[str]:
        """Live hosts whose step lags the lead by the threshold. Dead
        hosts are excluded from BOTH the lead computation and the
        returned list: a dead host that stopped beating behind the pack
        is not a straggler (it is handled by ``dead()``), and a dead
        host that died ahead of the pack must not drag the lead up and
        flag every healthy host."""
        alive = self.healthy(now)
        if not alive:
            return []
        lead = max(self.hosts[h].step for h in alive)
        lag = [h for h in alive
               if lead - self.hosts[h].step >= self.straggler_steps]
        self._m_strag.set(len(lag))
        return lag

    def healthy(self, now: Optional[float] = None) -> List[str]:
        d = set(self.dead(now))
        return [h for h in self.hosts if h not in d]


def remesh_plan(n_alive_hosts: int, devices_per_host: int,
                model_axis: int, pod_axis: int = 1
                ) -> Optional[dict]:
    """Largest runnable mesh on the survivors.

    The model axis is preserved (parameter layout); the data axis shrinks to
    the largest power of two that fits. Returns None if even model_axis
    devices are not available. global_batch should be scaled by
    ``plan['data'] / old_data`` or grad-accum increased to compensate.
    """
    total = n_alive_hosts * devices_per_host
    per_replica = model_axis * pod_axis
    if total < per_replica:
        return None
    data = 1
    while data * 2 * per_replica <= total:
        data *= 2
    return {"pod": pod_axis, "data": data, "model": model_axis,
            "devices_used": data * per_replica,
            "devices_idle": total - data * per_replica}


@dataclasses.dataclass
class RestartPolicy:
    max_failures: int = 10
    backoff_s: float = 0.0  # kept 0 in tests
    checkpoint_every: int = 50


class RestartLoop:
    """Checkpoint-restart driver with failure injection hooks (tests)."""

    def __init__(self, policy: RestartPolicy, save_fn: Callable[[int], None],
                 restore_fn: Callable[[], int],
                 registry: Optional[obs_metrics.Registry] = None):
        self.policy = policy
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.failures = 0
        self.restarts = 0
        self.obs = registry if registry is not None \
            else obs_metrics.get_registry()
        self._m_restarts = self.obs.counter(
            "ft_restarts_total", "checkpoint-restore restarts taken")
        self._m_failures = self.obs.counter(
            "ft_failures_total", "step failures caught by the loop")

    def run(self, step_fn: Callable[[int], None], total_steps: int) -> int:
        """Runs step_fn(step) for steps [resume..total); returns steps run."""
        executed = 0
        while True:
            start = self.restore_fn()
            try:
                for step in range(start, total_steps):
                    step_fn(step)
                    executed += 1
                    if (step + 1) % self.policy.checkpoint_every == 0:
                        self.save_fn(step + 1)
                        # a checkpoint landing IS progress: reset the
                        # failure budget so max_failures bounds
                        # consecutive no-progress crash loops, not the
                        # total transient-fault count over a job's
                        # lifetime (a month-long run would otherwise be
                        # killed by its 11th unrelated blip)
                        self.failures = 0
                self.save_fn(total_steps)
                return executed
            except RuntimeError:
                self.failures += 1
                self.restarts += 1
                self._m_failures.inc()
                self._m_restarts.inc()
                if self.failures > self.policy.max_failures:
                    raise
                if self.policy.backoff_s:
                    time.sleep(self.policy.backoff_s)
