"""repro.train — trainer, checkpointing, fault tolerance."""
from .trainer import Trainer, TrainerConfig  # noqa: F401
from .checkpoint import CheckpointManager  # noqa: F401
from .fault_tolerance import (  # noqa: F401
    HeartbeatMonitor, RestartLoop, RestartPolicy, remesh_plan,
)
