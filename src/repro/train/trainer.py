"""The training engine: jitted sharded step, grad accumulation, mixed
precision, DiLoCo-style cross-pod sync with compressed deltas, and
checkpoint-resume.

Distributed-optimization tricks implemented here (DESIGN.md §5):

* grad-accum microbatches via ``lax.scan`` — XLA overlaps microbatch k+1's
  compute with microbatch k's gradient reduce-scatter;
* fused optimizer (no separate update dispatch — the paper's FF/BP/UP
  operational parallelism, realized by the XLA scheduler);
* DiLoCo outer loop (``diloco_period``): pods run local AdamW and exchange
  int8 error-feedback-compressed parameter deltas every K steps — cutting
  inter-pod (DCN) traffic by ~4x/K vs per-step gradient all-reduce;
* donated buffers: params/opt-state update in place;
* sharded sparse junctions: the TRAIN rules map the ``"slab"`` logical
  axis to ``model``, so ``param_pspecs`` chunks every block-sparse weight
  slab (and its mirrored Adam state) on the block-row dim, and the jitted
  step — traced under ``mesh_context`` — runs those junctions through the
  model-parallel ``csd_matmul`` shard_map. UP (dw/db) is shard-local
  there, so the sharded optimizer state updates without any gradient
  collectives on the slab weights (ZeRO-style for free).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..nn.common import mesh_context
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..optim import adam
from ..optim.compression import psum_compressed_tree
from ..sharding import policy
from .checkpoint import CheckpointManager


def _batch_tokens(batch: dict) -> int:
    """Tokens a batch feeds the model (batch x seq), for throughput."""
    for k in ("labels", "tokens"):
        if k in batch:
            return int(np.prod(batch[k].shape))
    leaf = next(iter(batch.values()))
    return int(np.prod(leaf.shape[:2]))


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    opt: adam.AdamWConfig = dataclasses.field(default_factory=adam.AdamWConfig)
    grad_accum: int = 1
    diloco_period: int = 0       # 0 = synchronous data parallel
    diloco_outer_lr: float = 0.7
    diloco_outer_momentum: float = 0.9
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    checkpoint_keep: int = 3
    log_every: int = 10
    # observability: ``metrics`` routes per-step timing/loss/grad-norm
    # through the process obs registry (recording is host-side only — the
    # jitted step is identical either way). ``profile_dir`` captures a
    # jax.profiler trace of the whole fit() into that directory.
    metrics: bool = True
    profile_dir: Optional[str] = None


class Trainer:
    def __init__(self, model, cfg: TrainerConfig,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[dict] = None,
                 registry: Optional[obs_metrics.Registry] = None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        self.obs = obs_metrics.resolve(registry, enabled=cfg.metrics)
        self._m_steps = self.obs.counter(
            "train_steps_total", "optimizer steps taken")
        self._m_tokens = self.obs.counter(
            "train_tokens_total", "tokens consumed (batch * seq)")
        self._m_step_s = self.obs.histogram(
            "train_step_seconds",
            "per-step wall clock (first step includes compile)")
        self._m_loss = self.obs.gauge("train_loss", "last logged loss")
        self._m_gnorm = self.obs.gauge(
            "train_grad_norm", "last logged global gradient norm")
        self._m_tps = self.obs.gauge(
            "train_tokens_per_s",
            "throughput over the last log window")
        self._m_micro = self.obs.gauge(
            "train_microbatches", "grad-accum microbatches per step")
        self.rules = rules or (
            policy.rules_for("train", 0, mesh,
                             getattr(model, "cfg", None)) if mesh else {})
        self.ckpt = CheckpointManager(cfg.checkpoint_dir,
                                      cfg.checkpoint_keep) \
            if cfg.checkpoint_dir else None
        self._step_fn = None
        if mesh is not None:
            import jax as _jax
            pstruct = _jax.eval_shape(model.init, _jax.random.key(0))
            pspec = policy.param_pspecs(model.spec(), self.rules)
            self.param_sharding = policy.named(mesh, pspec, pstruct)
            self.opt_sharding = policy.named(
                mesh, policy.opt_pspecs(pspec),
                _jax.eval_shape(__import__("repro.optim.adam", fromlist=["init"]).init, pstruct))
        else:
            self.param_sharding = None
            self.opt_sharding = None

    # -- state ------------------------------------------------------------------

    def init_state(self, key: jax.Array) -> Tuple[Any, Any]:
        if self.mesh is not None:
            with self.mesh, mesh_context(self.mesh, self.rules):
                params = jax.jit(
                    self.model.init,
                    out_shardings=self.param_sharding)(key)
                opt = jax.jit(adam.init,
                              out_shardings=self.opt_sharding)(params)
        else:
            params = self.model.init(key)
            opt = adam.init(params)
        return params, opt

    # -- the step ----------------------------------------------------------------

    def _loss_fn(self, params, batch):
        return self.model.loss(params, batch)

    def _make_step(self, batch_example: dict):
        cfg = self.cfg
        accum = cfg.grad_accum

        def step(params, opt, batch):
            if accum > 1:
                def micro(carry, mb):
                    g_acc, l_acc = carry
                    (loss, metrics), g = jax.value_and_grad(
                        self._loss_fn, has_aux=True)(params, mb)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + loss), metrics

                mbs = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (g, loss_sum), metrics = jax.lax.scan(
                    micro, (zeros, 0.0), mbs)
                g = jax.tree.map(lambda x: x / accum, g)
                loss = loss_sum / accum
                metrics = jax.tree.map(lambda m: m[-1], metrics)
            else:
                (loss, metrics), g = jax.value_and_grad(
                    self._loss_fn, has_aux=True)(params, batch)
            params, opt, opt_metrics = adam.update(cfg.opt, g, opt, params)
            metrics = dict(metrics, **opt_metrics, loss=loss)
            return params, opt, metrics

        if self.mesh is None:
            return jax.jit(step, donate_argnums=(0, 1))
        batch_spec = policy.named(
            self.mesh, policy.batch_pspecs(batch_example, self.rules))
        return jax.jit(
            step,
            in_shardings=(self.param_sharding, self.opt_sharding,
                          batch_spec),
            out_shardings=(self.param_sharding, self.opt_sharding, None),
            donate_argnums=(0, 1))

    def step_fn(self, batch_example: dict):
        if self._step_fn is None:
            self._step_fn = self._make_step(batch_example)
        return self._step_fn

    # -- DiLoCo outer sync ----------------------------------------------------------

    def make_diloco_state(self, params):
        # explicit copies: params are donated by the step fn, and astype on
        # an already-f32 array would alias the donated buffer
        return {"anchor": jax.tree.map(
                    lambda p: jnp.array(p, jnp.float32, copy=True), params),
                "outer_m": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "err": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def diloco_sync(self, params, dstate, axis_name: Optional[str] = None):
        """Outer step: compressed cross-pod mean of (anchor - params) deltas
        + Nesterov-style outer momentum; returns (params, dstate)."""
        cfg = self.cfg

        def inner(params, anchor, outer_m, err):
            delta = jax.tree.map(
                lambda a, p: a - p.astype(jnp.float32), anchor, params)
            mean_delta, new_err = psum_compressed_tree(delta, err, axis_name)
            new_m = jax.tree.map(
                lambda m, d: cfg.diloco_outer_momentum * m + d,
                outer_m, mean_delta)
            new_anchor = jax.tree.map(
                lambda a, m: a - cfg.diloco_outer_lr * m, anchor, new_m)
            # explicit copy: params are donated by the next step; they must
            # not alias the anchor (f32->f32 astype is a no-op)
            new_params = jax.tree.map(
                lambda p, a: jnp.array(a, p.dtype, copy=True),
                params, new_anchor)
            return new_params, new_anchor, new_m, new_err

        if axis_name is None or self.mesh is None \
                or axis_name not in self.mesh.axis_names:
            p, a, m, e = inner(params, dstate["anchor"], dstate["outer_m"],
                               dstate["err"])
        else:
            mesh = self.mesh
            spec = jax.tree.map(lambda _: P(), params)
            fn = shard_map(
                inner, mesh=mesh,
                in_specs=(spec, spec, spec, spec),
                out_specs=(spec, spec, spec, spec), check_vma=False)
            p, a, m, e = fn(params, dstate["anchor"], dstate["outer_m"],
                            dstate["err"])
        return p, {"anchor": a, "outer_m": m, "err": e}

    # -- the loop -----------------------------------------------------------------

    def fit(self, data_iter: Iterator[dict], steps: int,
            key: Optional[jax.Array] = None, resume: bool = True,
            params=None, opt=None,
            on_step: Optional[Callable[[int, dict], None]] = None):
        cfg = self.cfg
        start = 0
        if params is None:
            params, opt = self.init_state(key or jax.random.key(0))
        if resume and self.ckpt is not None and self.ckpt.latest_step():
            start = self.ckpt.latest_step()
            (params, opt), _ = self.ckpt.restore(
                start, (params, opt),
                (self.param_sharding, self.opt_sharding)
                if self.mesh else None)
        dstate = self.make_diloco_state(params) \
            if cfg.diloco_period else None
        history = []
        self._m_micro.set(cfg.grad_accum)
        win_t0 = time.perf_counter()
        win_tokens = 0
        ctx = mesh_context(self.mesh, self.rules) if self.mesh else None
        if ctx:
            ctx.__enter__()
        try:
            with obs_trace.profile_trace(cfg.profile_dir):
                for step in range(start, steps):
                    batch = next(data_iter)
                    batch = {k: jnp.asarray(v) for k, v in batch.items()}
                    fn = self.step_fn(batch)
                    t0 = time.perf_counter()
                    with obs_trace.span("train/step", registry=self.obs):
                        params, opt, metrics = fn(params, opt, batch)
                    # dispatch wall-clock: under async dispatch this
                    # converges to true step time once the queue fills
                    self._m_step_s.observe(time.perf_counter() - t0)
                    n_tok = _batch_tokens(batch)
                    win_tokens += n_tok
                    self._m_steps.inc()
                    self._m_tokens.inc(n_tok)
                    if cfg.diloco_period \
                            and (step + 1) % cfg.diloco_period == 0:
                        params, dstate = self.diloco_sync(
                            params, dstate,
                            "pod" if (self.mesh and "pod" in
                                      self.mesh.axis_names) else None)
                    if (step + 1) % cfg.log_every == 0 \
                            or step == steps - 1:
                        m = {k: float(v) for k, v in metrics.items()}
                        # float() above synced the device, so the window
                        # clock now covers real compute, not just dispatch
                        now = time.perf_counter()
                        tps = win_tokens / max(now - win_t0, 1e-9)
                        win_t0, win_tokens = now, 0
                        m["tokens_per_s"] = tps
                        self._m_loss.set(m.get("loss", float("nan")))
                        if "grad_norm" in m:
                            self._m_gnorm.set(m["grad_norm"])
                        self._m_tps.set(tps)
                        history.append({"step": step + 1, **m})
                        if on_step:
                            on_step(step + 1, m)
                        else:
                            print(f"step {step + 1:>6d}  "
                                  f"loss {m.get('loss', float('nan')):.4f}  "
                                  f"tok/s {tps:,.0f}  "
                                  f"grad_norm "
                                  f"{m.get('grad_norm', float('nan')):.3f}")
                    if self.ckpt \
                            and (step + 1) % cfg.checkpoint_every == 0:
                        self.ckpt.save(step + 1, (params, opt),
                                       async_=True)
            if self.ckpt:
                self.ckpt.save(steps, (params, opt))
                self.ckpt.wait()
        finally:
            if ctx:
                ctx.__exit__(None, None, None)
        return params, opt, history
