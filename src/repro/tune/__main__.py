"""CLI — pre-warm the dispatch cache, explain decisions, self-test.

Pre-warm (measure + persist winners for everything a config dispatches)::

    PYTHONPATH=src python -m repro.tune --configs paper_mlp,qwen2-7b \
        --m 2,256 --cache tune-cache.json

The warm path traces the model's forward with ``jax.eval_shape`` (no
FLOPs, no memory — trace-time dispatch records every cache miss with its
full shape spec), then benchmarks each recorded regime with synthetic
operands of exactly those shapes. Decode regimes are warmed from the
config's attention geometry under the default ``EngineConfig`` paging.

``--explain`` dumps the cache (keys, winners, per-candidate timings,
rejections) without measuring anything. ``--selftest-inject`` presents
sparselint's race-broken kernel as a tuned Pallas candidate and exits
non-zero when the SL101–SL105 gate rejects it — proof the gate has teeth,
wired into CI exactly like ``lint --selftest-inject``.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import (clear_pending, get_cache, pending)
from . import cache as _cache
from . import certify as _certify
from . import tuner as _tuner


def _warm_mlp(m_list, args):
    """paper_mlp: the paper's 4-junction MNIST MLP (Table II row 0)."""
    import jax

    from ..configs.paper_mlp import MNIST_4J, TABLE2_MNIST, rho_from_dout
    from ..nn.mlp import MLPConfig, SparseMLP

    rho = rho_from_dout(MNIST_4J, TABLE2_MNIST[0][0])
    model = SparseMLP(MLPConfig(n_net=MNIST_4J, rho=rho,
                                mode="block_gather"))
    params = jax.eval_shape(model.init, jax.random.key(0))
    for m in m_list:
        x = jax.ShapeDtypeStruct((m, MNIST_4J[0]), "float32")
        y = jax.ShapeDtypeStruct((m,), "int32")
        jax.eval_shape(model.loss, params, x, y)


def _warm_arch(name, m_list, args):
    import jax

    from ..configs import get_config
    from ..nn import build_model

    cfg = get_config(name, smoke=True)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.key(0))
    for m in m_list:
        b, s = (1, m) if m > 1 else (1, 1)
        tokens = jax.ShapeDtypeStruct((b, s), "int32")
        batch = {"tokens": tokens, "labels": tokens}
        if cfg.input_mode == "embeddings" or cfg.enc_dec is not None:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.frontend_dim), "float32")
        jax.eval_shape(model.loss, params, batch)
    # decode regime: the serving engine's paged-attention geometry under
    # default EngineConfig paging
    heads = getattr(cfg, "n_heads", 0)
    if heads:
        from ..serving.engine import EngineConfig
        ec = EngineConfig()
        hkv = getattr(cfg, "n_kv_heads", heads) or heads
        from . import decide_decode
        decide_decode(b=ec.max_slots, h_kv=hkv, groups=heads // hkv,
                      head_dim=cfg.head_dim, page_size=ec.page_size,
                      n_pages=ec.max_pages_per_seq, pool=ec.total_pages,
                      quant=False, dtype="float32")


def _warm_pending(cache, args) -> int:
    specs = pending()
    n = 0
    for key, spec in specs.items():
        if cache.get(key) is not None:
            continue
        try:
            if spec["op"] == "paged_decode":
                ent = _tuner.bench_decode(
                    spec, cache=cache, iters=args.iters,
                    repeats=args.repeats,
                    interpret_pallas=args.interpret_pallas)
            else:
                ent = _tuner.bench_junction(
                    spec, cache=cache, iters=args.iters,
                    repeats=args.repeats,
                    interpret_pallas=args.interpret_pallas)
                if args.blocks:
                    _tuner.bench_tiles(
                        spec, [(64, 64), (128, 128), (256, 256)],
                        cache=cache, iters=args.iters,
                        repeats=args.repeats,
                        interpret_pallas=args.interpret_pallas)
        except Exception as e:  # noqa: BLE001 — warm what we can
            print(f"  {key}: SKIPPED ({type(e).__name__}: {e})")
            continue
        n += 1
        print(f"  {key}\n    -> {ent['backend']}"
              f"/{ent.get('dataflow', '-')} "
              f"({ent['speedup_vs_heuristic']}x vs heuristic, "
              f"score_by={ent.get('score_by')})")
    return n


def _explain(cache) -> dict:
    doc = {"path": cache.path, "schema": _cache.SCHEMA_VERSION,
           "load_error": cache.load_error, "n_entries": len(cache),
           "device": _cache.device_kind(), "entries": cache.entries}
    for key, ent in sorted(cache.entries.items()):
        extra = ""
        rej = [f"{lbl}:{','.join(i['rejected'])}"
               for lbl, i in ent.get("candidates", {}).items()
               if "rejected" in i]
        if rej:
            extra = f"  [rejected: {'; '.join(rej)}]"
        if "block_in" in ent and "backend" not in ent:
            print(f"{key}\n  -> tiles {ent['block_in']}x{ent['block_out']}"
                  f" ({ent.get('score_us')}us)")
        else:
            print(f"{key}\n  -> {ent.get('backend')}"
                  f"/{ent.get('dataflow', '-')}"
                  f" bm{ent.get('block_m', '-')}"
                  f" ({ent.get('score_us')}us, "
                  f"{ent.get('speedup_vs_heuristic')}x vs "
                  f"{ent.get('heuristic')}){extra}")
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="pre-warm / inspect the kernel dispatch cache")
    ap.add_argument("--configs", default="paper_mlp",
                    help="comma-separated config names (paper_mlp or any "
                         "registered arch) to pre-warm for")
    ap.add_argument("--m", default="2,256",
                    help="comma-separated M regimes (tokens) to trace")
    ap.add_argument("--cache", default=None,
                    help="cache file (default: REPRO_TUNE_CACHE or "
                         "~/.cache/repro/tune_cache.json)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--blocks", action="store_true",
                    help="also re-fit (bL, bR) tile shapes per junction "
                         "(consumed behind REPRO_TUNE_BLOCKS=1)")
    ap.add_argument("--interpret-pallas", action="store_true",
                    help="include Pallas candidates in interpret mode off "
                         "TPU (tests only — interpret timings do not "
                         "transfer to hardware)")
    ap.add_argument("--explain", action="store_true",
                    help="dump cached decisions and exit")
    ap.add_argument("--json", default=None,
                    help="also write the --explain dump to this file")
    ap.add_argument("--selftest-inject", action="store_true",
                    help="certification selftest: an injected race-broken "
                         "Pallas candidate must be REJECTED (exits "
                         "non-zero when the gate fires — has-teeth proof)")
    args = ap.parse_args(argv)

    if args.selftest_inject:
        ok, findings = _certify.certify_injected()
        if ok:
            print("selftest FAILED: injected illegal candidate was "
                  "accepted by the certification gate")
            return 0
        for f in findings:
            print(f"rejected: [{f.code}] {f.subject}: {f.message}")
        print("selftest: injected candidate rejected before benching "
              "(gate has teeth)")
        return 2

    cache = get_cache(args.cache)
    if cache.load_error:
        print(f"note: cache at {cache.path} unusable "
              f"({cache.load_error}); starting empty")

    if args.explain:
        doc = _explain(cache)
        if args.json:
            with open(args.json, "w") as fh:
                json.dump(doc, fh, indent=1, sort_keys=True)
        return 0

    clear_pending()
    for name in [c for c in args.configs.split(",") if c]:
        m_list = [int(v) for v in args.m.split(",") if v]
        print(f"tracing {name} (M regimes: {m_list}) ...")
        if name == "paper_mlp":
            _warm_mlp(m_list, args)
        else:
            _warm_arch(name, m_list, args)
    n_pend = len(pending())
    print(f"{n_pend} unseen regime(s); benchmarking candidates ...")
    n = _warm_pending(cache, args)
    print(f"warmed {n}/{n_pend} regimes -> {cache.path} "
          f"({len(cache)} entries)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(_explain(cache), fh, indent=1, sort_keys=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
