"""Persistent dispatch cache for the empirical autotuner.

One JSON file maps *tuning keys* — ``(op, M-regime, n_in, n_out, rho, E,
dtype/quant, device kind)`` strings — to measured winner configurations
(``{"backend", "dataflow", "block_m", ...timings}``). The cache is the
software analogue of the paper's per-board choice of the parallelism
degree ``z``: measured once per device, reused by every later process.

Contracts (ISSUE 10):

* versioned schema — a file written by a different ``SCHEMA_VERSION`` is
  ignored wholesale (graceful fallback to the static heuristic), never
  partially interpreted;
* atomic writes — ``save()`` writes a sibling temp file and ``os.replace``s
  it, so a concurrent reader sees either the old or the new cache, never a
  torn one;
* env-overridable path — ``REPRO_TUNE_CACHE=<path>`` relocates the file
  (default ``$XDG_CACHE_HOME/repro/tune_cache.json``);
* kill switch — ``REPRO_TUNE_DISABLE=1`` makes every lookup miss, which
  restores today's deterministic ``_resolve`` heuristic exactly;
* corruption tolerance — unreadable / truncated / non-JSON / wrong-schema
  files load as an empty cache (the error is kept on ``load_error`` for
  ``--explain``), they never raise into model code.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Optional

SCHEMA_VERSION = 1

ENV_PATH = "REPRO_TUNE_CACHE"
ENV_DISABLE = "REPRO_TUNE_DISABLE"
ENV_BLOCKS = "REPRO_TUNE_BLOCKS"

# M-regime buckets stop here: XLA's large-M lowering is shape-stable well
# before this, so one entry serves everything beyond it.
_M_BUCKET_CAP = 4096


def disabled() -> bool:
    return os.environ.get(ENV_DISABLE, "") not in ("", "0")


def blocks_enabled() -> bool:
    """Tile refit is opt-in: a tuned ``(bL, bR)`` is a *different pattern*
    (different parameters/numerics), unlike the performance-only dispatch
    entries — so it never activates implicitly."""
    return os.environ.get(ENV_BLOCKS, "") not in ("", "0")


def default_path() -> str:
    p = os.environ.get(ENV_PATH)
    if p:
        return p
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro", "tune_cache.json")


def device_kind() -> str:
    """Cache-key device id: platform plus hardware kind (decisions measured
    on one device class must not leak onto another)."""
    try:
        import jax
        d = jax.devices()[0]
        kind = str(getattr(d, "device_kind", "") or d.platform)
        return f"{d.platform}:{kind}".replace(" ", "_")
    except Exception:  # no backend initialised — key still forms
        return "unknown"


def m_bucket(m: int) -> int:
    """Power-of-two M-regime bucket (1, 2, 4, ... cap). Decode batches and
    training batches land in different regimes without a per-M explosion."""
    m = max(1, int(m))
    b = 1
    while b < m and b < _M_BUCKET_CAP:
        b <<= 1
    return b


def _rho_str(rho: float) -> str:
    return f"{float(rho):.4g}"


def junction_key(*, m: int, n_in: int, n_out: int, rho: float, E: int = 0,
                 dtype: str = "float32", quant: bool = False,
                 form: str = "plain", device: Optional[str] = None) -> str:
    """Key for one ``csd_matmul`` dispatch regime. ``form`` is the dispatch
    form (plain/batched/sharded/quant...); sharded callers pass their
    *shard-local* ``n_in``/``n_out``/``rho`` so tuning follows
    ``partition_pattern`` shapes."""
    return (f"csd_spmm|{form}|m{m_bucket(m)}|in{int(n_in)}|out{int(n_out)}"
            f"|rho{_rho_str(rho)}|E{int(E)}|{dtype}|q{int(bool(quant))}"
            f"|{device or device_kind()}")


def decode_key(*, b: int, h_kv: int, groups: int, head_dim: int,
               page_size: int, n_pages: int, pool: int,
               quant: bool = False, dtype: str = "float32",
               device: Optional[str] = None) -> str:
    """Key for one ``paged_decode_attention`` regime (B bucketed like M)."""
    return (f"paged_decode|b{m_bucket(b)}|h{int(h_kv)}|g{int(groups)}"
            f"|d{int(head_dim)}|p{int(page_size)}|np{int(n_pages)}"
            f"|pool{int(pool)}|q{int(bool(quant))}|{dtype}"
            f"|{device or device_kind()}")


def tile_key(*, n_in: int, n_out: int, rho: float, E: int = 0,
             dtype: str = "float32", device: Optional[str] = None) -> str:
    """Key for a measured ``(bL, bR)`` tile refit of one junction family
    (no M axis: ``fit_block_pattern`` runs before any batch exists)."""
    return (f"fit_blocks|in{int(n_in)}|out{int(n_out)}|rho{_rho_str(rho)}"
            f"|E{int(E)}|{dtype}|{device or device_kind()}")


class TuneCache:
    """Dict-of-entries with tolerant load and atomic save."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_path()
        self._lock = threading.Lock()
        self.entries: dict = {}
        self.load_error: Optional[str] = None

    def load(self) -> "TuneCache":
        self.entries, self.load_error = {}, None
        try:
            with open(self.path) as fh:
                doc = json.load(fh)
        except FileNotFoundError:
            return self
        except Exception as e:  # corrupt / truncated / unreadable
            self.load_error = f"{type(e).__name__}: {e}"
            return self
        if not isinstance(doc, dict):
            self.load_error = "cache root is not an object"
            return self
        if doc.get("schema") != SCHEMA_VERSION:
            self.load_error = (f"schema {doc.get('schema')!r} != "
                               f"{SCHEMA_VERSION} (ignored)")
            return self
        ent = doc.get("entries")
        if isinstance(ent, dict):
            self.entries = {k: v for k, v in ent.items()
                            if isinstance(v, dict)}
        return self

    def get(self, key: str) -> Optional[dict]:
        return self.entries.get(key)

    def put(self, key: str, value: dict, save: bool = True) -> None:
        with self._lock:
            self.entries[key] = dict(value)
        if save:
            self.save()

    def save(self) -> None:
        doc = {"schema": SCHEMA_VERSION, "entries": self.entries}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tune_cache.",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(doc, fh, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic on POSIX
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        return len(self.entries)


_CACHE: Optional[TuneCache] = None


def get_cache(path: Optional[str] = None) -> TuneCache:
    """Process-wide cache singleton. Re-resolves the path on every call so
    tests (and ``REPRO_TUNE_CACHE`` changes) take effect immediately."""
    global _CACHE
    want = path or default_path()
    if _CACHE is None or _CACHE.path != want:
        _CACHE = TuneCache(want).load()
    return _CACHE


def reset_cache() -> None:
    global _CACHE
    _CACHE = None
