"""Candidate enumeration + measurement for the autotuner.

For one dispatch regime (a cache key), the tuner builds synthetic operands
of the recorded shapes, times every *legal* candidate configuration with
the same ``obs.trace.timed_call`` core the benchmarks use (best-of-k
median with explicit warm-up — tuning and benching cannot disagree about
the clock), and persists the winner.

Candidate axes (the software form of the paper's flexible ``z``):

* backend ∈ {xla, dense, pallas} — ``dense`` is the escape hatch for
  regimes where structured sparsity loses to one cuBLAS/Eigen-style GEMM
  (ρ=0.5 on CPU); it is only legal for the plain/batched unquantized
  junction. Pallas candidates appear on TPU (or under
  ``interpret_pallas=True`` in tests) and must pass the SL101–SL105
  certification gate (``certify.py``) *before* they are benchmarked.
* dataflow ∈ {gather, scatter} for the XLA lowering — scatter gathers
  weights instead of activations, so it is M-independent and wins the
  skinny-M decode regime where gather falls off a cliff.
* block_m for Pallas grids.

Scoring: skinny-M regimes (M ≤ 32 — decode) score by forward time; larger
regimes (training/prefill) score by a full ``value_and_grad`` step so the
dx/dw sweeps weigh in. Both timings are kept in the entry for
``--explain``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..obs import metrics as _obs_metrics
from ..obs import trace as _obs_trace
from . import cache as _cache
from . import certify as _certify

# M at or below this is the decode regime: score candidates by forward
# time only (no backward runs at decode).
SKINNY_M = 32

PALLAS_BLOCK_MS = (128, 256)


def _on_tpu() -> bool:
    try:
        import jax
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@dataclasses.dataclass(frozen=True)
class Candidate:
    backend: str
    dataflow: str = "gather"
    block_m: int = 128

    @property
    def label(self) -> str:
        if self.backend == "pallas":
            return f"pallas/bm{self.block_m}"
        if self.backend == "dense":
            return "dense"
        return f"xla/{self.dataflow}"


def junction_candidates(*, quant: bool = False, sharded: bool = False,
                        interpret_pallas: bool = False) -> List[Candidate]:
    cands = [Candidate("xla", "gather"), Candidate("xla", "scatter")]
    if not quant and not sharded:
        # dense-ref escape hatch: densify the slab (static take) + one
        # GEMM. No sharded/quant form — those contracts are slab-only.
        cands.append(Candidate("dense"))
    if _on_tpu() or interpret_pallas:
        for bm in PALLAS_BLOCK_MS:
            cands.append(Candidate("pallas", "gather", bm))
    return cands


def _heuristic_candidate() -> Candidate:
    """What today's static ``_resolve("auto")`` would pick — the baseline
    every tuned decision is compared against."""
    return Candidate("pallas" if _on_tpu() else "xla", "gather", 128)


def _reg():
    return _obs_metrics.get_registry()


def _record_win(key: str, entry: dict) -> None:
    reg = _reg()
    reg.counter(
        "repro_tune_benched_total",
        "tuning runs completed, by op").inc(op=key.split("|", 1)[0])
    reg.gauge(
        "repro_tune_speedup",
        "measured winner speedup over the static heuristic, per key",
    ).set(entry.get("speedup_vs_heuristic", 1.0), key=key)


def bench_junction(spec: dict, *, cache: Optional[_cache.TuneCache] = None,
                   iters: int = 3, repeats: int = 2,
                   interpret_pallas: bool = False,
                   save: bool = True) -> dict:
    """Measure all legal candidates for one junction regime; cache and
    return the winning entry.

    ``spec`` fields: ``m, n_in, n_out, rho, E, dtype, quant, form,
    block_in, block_out`` (the exact dict ``decide_junction`` records on a
    miss). Sharded forms are benched on a plain pattern of the shard-local
    dims — same shapes, same density, pallas/xla candidates only — and the
    one decision applies uniformly across shards.
    """
    import jax
    import jax.numpy as jnp

    from ..core.block_pattern import make_block_pattern
    from ..kernels import ops

    m = int(spec["m"])
    n_in, n_out = int(spec["n_in"]), int(spec["n_out"])
    rho = float(spec["rho"])
    E = int(spec.get("E", 0))
    quant = bool(spec.get("quant", False))
    form = str(spec.get("form", "plain"))
    dtype = jnp.dtype(spec.get("dtype", "float32"))
    bi = int(spec.get("block_in", 128))
    bo = int(spec.get("block_out", 128))
    sharded = "sharded" in form

    key = _cache.junction_key(m=m, n_in=n_in, n_out=n_out, rho=rho, E=E,
                              dtype=str(dtype), quant=quant, form=form)
    bp = make_block_pattern(n_in, n_out, bp_rho_cap(rho),
                            block_in=bi, block_out=bo, seed=0)

    lead = (E,) if E > 0 else ()
    kx = jax.random.key(0)
    x = jax.random.normal(kx, lead + (m, n_in)).astype(dtype)
    w = jax.random.normal(
        jax.random.key(1),
        lead + (bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out),
    ).astype(dtype) * 0.02
    w_scale = None
    if quant:
        from ..core.quant import quantize_slab
        w, w_scale = quantize_slab(w)

    heuristic = _heuristic_candidate()
    results: dict = {}
    best = None
    score_by = "fwd" if (m <= SKINNY_M or quant) else "step"

    for cand in junction_candidates(quant=quant, sharded=sharded,
                                    interpret_pallas=interpret_pallas):
        info: dict = {}
        results[cand.label] = info
        if cand.backend == "pallas":
            ok, findings = _certify.certify_junction(
                bp, m, cand.block_m, E=E, dtype=dtype)
            if not ok:
                info["rejected"] = sorted({f.code for f in findings})
                _reg().counter(
                    "repro_tune_rejected_total",
                    "pallas candidates rejected by SL101-SL105, by code",
                ).inc(codes=",".join(info["rejected"]))
                continue
        interpret = cand.backend == "pallas" and not _on_tpu()
        kw = dict(backend=cand.backend, dataflow=cand.dataflow,
                  block_m=cand.block_m, interpret=interpret)
        try:
            fwd = jax.jit(lambda x, w: ops.csd_matmul(
                x, w, bp, w_scale=w_scale, **kw))
            info["us_fwd"] = round(_obs_trace.timed_call(
                fwd, x, w, iters=iters, warmup=1, repeats=repeats,
                name=f"tune/{key}/{cand.label}/fwd"), 2)
            if score_by == "step":
                def loss(w, x):
                    return jnp.mean(ops.csd_matmul(x, w, bp, **kw) ** 2)
                step = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
                info["us_step"] = round(_obs_trace.timed_call(
                    step, w, x, iters=iters, warmup=1, repeats=repeats,
                    name=f"tune/{key}/{cand.label}/step"), 2)
        except Exception as e:  # a candidate that cannot run never wins
            info["error"] = f"{type(e).__name__}: {e}"
            info.pop("us_fwd", None)
            continue
        score = info.get("us_step", info.get("us_fwd"))
        info["score_us"] = score
        if best is None or score < best[0]:
            best = (score, cand)

    if best is None:
        raise RuntimeError(f"no runnable candidate for {key}")
    score, cand = best
    h_info = results.get(heuristic.label, {})
    h_score = h_info.get("score_us", score)
    entry = {
        "backend": cand.backend,
        "dataflow": cand.dataflow,
        "block_m": cand.block_m,
        "block_in": bi,
        "block_out": bo,
        "score_us": score,
        "score_by": score_by,
        "heuristic": heuristic.label,
        "speedup_vs_heuristic": round(h_score / score, 3) if score else 1.0,
        "candidates": results,
    }
    if cache is not None:
        cache.put(key, entry, save=save)
    _record_win(key, entry)
    return entry


def bp_rho_cap(rho: float) -> float:
    """make_block_pattern treats rho as a fan-in fraction; clamp into its
    valid (0, 1] range (recorded densities are already in-range — this
    guards float drift like 1.0000001 from ``d_in_b / n_lb``)."""
    return max(min(rho, 1.0), 1e-6)


def bench_decode(spec: dict, *, cache: Optional[_cache.TuneCache] = None,
                 iters: int = 3, repeats: int = 2,
                 interpret_pallas: bool = False,
                 save: bool = True) -> dict:
    """Measure decode-attention backends for one paged-KV regime.

    The Pallas decode kernel has no tunable grid knobs (one page per grid
    step is structural), so the candidate axis is backend only; the
    shipped kernel itself is certified by sparselint's CI gate.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..kernels.flash_attention import paged_decode_attention

    b = int(spec["b"])
    h_kv, groups = int(spec["h_kv"]), int(spec["groups"])
    dh = int(spec["head_dim"])
    page, npages = int(spec["page_size"]), int(spec["n_pages"])
    pool = int(spec["pool"])
    quant = bool(spec.get("quant", False))
    dtype = jnp.dtype(spec.get("dtype", "float32"))

    key = _cache.decode_key(b=b, h_kv=h_kv, groups=groups, head_dim=dh,
                            page_size=page, n_pages=npages, pool=pool,
                            quant=quant, dtype=str(dtype))
    q = jax.random.normal(jax.random.key(0), (b, h_kv, groups, dh)
                          ).astype(dtype)
    kp = jax.random.normal(jax.random.key(1), (pool, page, h_kv, dh)
                           ).astype(dtype)
    vp = jax.random.normal(jax.random.key(2), (pool, page, h_kv, dh)
                           ).astype(dtype)
    k_scale = v_scale = None
    if quant:
        amax = jnp.max(jnp.abs(kp), axis=(2, 3))
        k_scale = (amax / 127.0 + 1e-8).astype(jnp.float32)
        v_scale = k_scale
        kp = jnp.clip(jnp.round(kp / k_scale[:, :, None, None]),
                      -127, 127).astype(jnp.int8)
        vp = jnp.clip(jnp.round(vp / v_scale[:, :, None, None]),
                      -127, 127).astype(jnp.int8)
    # half-full rows: pages handed out round-robin from the pool
    used = max(1, npages // 2)
    table = np.full((b, npages), -1, np.int32)
    for r in range(b):
        table[r, :used] = [(r * used + j) % pool for j in range(used)]
    lengths = np.full((b,), used * page - page // 2, np.int32)
    table, lengths = jnp.asarray(table), jnp.asarray(lengths)

    backends = ["xla"] + (["pallas"] if (_on_tpu() or interpret_pallas)
                          else [])
    results: dict = {}
    best = None
    for be in backends:
        interpret = be == "pallas" and not _on_tpu()
        fn = jax.jit(lambda q, kp, vp, t, ln, be=be, i=interpret:
                     paged_decode_attention(
                         q, kp, vp, t, ln, backend=be, interpret=i,
                         k_scale=k_scale, v_scale=v_scale))
        info: dict = {}
        results[be] = info
        try:
            info["us_fwd"] = round(_obs_trace.timed_call(
                fn, q, kp, vp, table, lengths, iters=iters, warmup=1,
                repeats=repeats, name=f"tune/{key}/{be}"), 2)
        except Exception as e:
            info["error"] = f"{type(e).__name__}: {e}"
            continue
        if best is None or info["us_fwd"] < best[0]:
            best = (info["us_fwd"], be)
    if best is None:
        raise RuntimeError(f"no runnable decode candidate for {key}")
    h = "pallas" if _on_tpu() else "xla"
    h_us = results.get(h, {}).get("us_fwd", best[0])
    entry = {
        "backend": best[1],
        "score_us": best[0],
        "score_by": "fwd",
        "heuristic": h,
        "speedup_vs_heuristic": round(h_us / best[0], 3) if best[0] else 1.0,
        "candidates": results,
    }
    if cache is not None:
        cache.put(key, entry, save=save)
    _record_win(key, entry)
    return entry


def bench_tiles(spec: dict, tiles, *,
                cache: Optional[_cache.TuneCache] = None,
                iters: int = 3, repeats: int = 2,
                interpret_pallas: bool = False,
                save: bool = True) -> dict:
    """Re-fit the junction's ``(bL, bR)`` tile shape by measurement.

    Benches the full candidate set at every legal tile (each run also
    populates that tile's dispatch entries) and records the winning tile
    under the M-free ``fit_blocks`` key that ``fit_block_pattern``
    consults behind ``REPRO_TUNE_BLOCKS=1``.
    """
    n_in, n_out = int(spec["n_in"]), int(spec["n_out"])
    rho, E = float(spec["rho"]), int(spec.get("E", 0))
    dtype = str(spec.get("dtype", "float32"))
    min_b = 32
    per_tile: dict = {}
    best = None
    seen = set()
    base = (int(spec.get("block_in", 128)), int(spec.get("block_out", 128)))
    for bi, bo in [base] + [t for t in tiles if tuple(t) != base]:
        bi, bo = int(bi), int(bo)
        if (bi, bo) in seen:
            continue
        seen.add((bi, bo))
        if n_in % bi or n_out % bo or bi < min_b or bo < min_b:
            per_tile[f"{bi}x{bo}"] = {"skipped": "illegal tile"}
            continue
        sub = dict(spec, block_in=bi, block_out=bo)
        try:
            ent = bench_junction(sub, cache=cache, iters=iters,
                                 repeats=repeats,
                                 interpret_pallas=interpret_pallas,
                                 save=save)
        except Exception as e:
            per_tile[f"{bi}x{bo}"] = {"error": f"{type(e).__name__}: {e}"}
            continue
        per_tile[f"{bi}x{bo}"] = {"score_us": ent["score_us"],
                                  "backend": ent["backend"]}
        if best is None or ent["score_us"] < best[0]:
            best = (ent["score_us"], bi, bo)
    if best is None:
        raise RuntimeError(f"no legal tile for {n_in}x{n_out}")
    entry = {"block_in": best[1], "block_out": best[2],
             "score_us": best[0], "per_tile": per_tile}
    key = _cache.tile_key(n_in=n_in, n_out=n_out, rho=rho, E=E, dtype=dtype)
    if cache is not None:
        cache.put(key, entry, save=save)
    return entry
