"""Legality gate for tuned Pallas candidates — sparselint pass 1, pre-bench.

Every Pallas candidate configuration the tuner wants to benchmark is first
captured (``analysis.capture.capture_launch`` — records the launch without
executing it) and proven against the grid pass's SL101–SL105 checks
(``analysis.grid_pass.analyze_launch``): contiguous output-tile visits (no
VMEM race), BlockSpec divisibility, epilogue-on-last-slot, VMEM budget,
index-map range. An illegal candidate is *rejected before it is ever
benchmarked or cached* — a config that happens to run fast in interpret
mode but races on real hardware must never become a cached winner.

``certify_injected()`` is the self-test hook: it presents sparselint's
deliberately race-broken kernel copy (fan-in slot hoisted outermost) as if
it were a tuned candidate; the gate must reject it. ``python -m repro.tune
--selftest-inject`` exits non-zero exactly when the rejection fires, the
same has-teeth contract as ``lint --selftest-inject``.
"""
from __future__ import annotations

from typing import List, Tuple


def certify_junction(bp, m: int, block_m: int, *, E: int = 0,
                     activation: str = "relu",
                     dtype=None) -> Tuple[bool, List]:
    """Certify one Pallas ``csd_spmm_fwd`` candidate (SL101–SL105).

    Returns ``(ok, findings)``. ``m`` is the logical row count; the entry
    point pads M to ``block_m``, so the capture sees post-pad shapes —
    exactly what the grid pass certifies against.
    """
    import jax.numpy as jnp

    from ..analysis import grid_pass
    from ..analysis.capture import capture_launch
    from ..analysis.findings import Finding
    from ..kernels import csd_spmm

    batched = E > 0
    mp = m + (-m) % block_m
    name = f"tune:csd_spmm_fwd_bm{block_m}" + ("_5d" if batched else "")
    dt = jnp.float32 if dtype is None else dtype

    def build():
        lead = (E,) if batched else ()
        x = jnp.zeros(lead + (mp, bp.n_in), dt)
        w = jnp.zeros(lead + (bp.n_rb, bp.d_in_b, bp.block_in,
                              bp.block_out), dt)
        bias = jnp.zeros(lead + (bp.n_out,), dt)
        return capture_launch(
            csd_spmm.csd_spmm_fwd, x, w, bp.block_idx, bias=bias,
            activation=activation, block_m=block_m, name=name)

    case = grid_pass.KernelCase(name, build,
                                epilogue_axis=3 if batched else 2)
    try:
        launch = case.build()
    except Exception as e:  # unlaunchable config = rejected, not fatal
        return False, [Finding(
            "SL105", name,
            f"candidate capture failed: {type(e).__name__}: {e}", {})]
    findings, _ = grid_pass.analyze_launch(launch, case)
    return (not findings), findings


def certify_injected() -> Tuple[bool, List]:
    """Present the race-broken selftest kernel as a tuned candidate.

    Returns ``(ok, findings)`` — ``ok`` must come back ``False`` (the gate
    rejected it) for the selftest to pass.
    """
    from ..analysis import grid_pass

    case = grid_pass.injected_alias_case()
    launch = case.build()
    findings, _ = grid_pass.analyze_launch(launch, case)
    return (not findings), findings
