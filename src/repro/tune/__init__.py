"""repro.tune — empirical kernel autotuner with a persistent dispatch cache.

``backend="auto"`` in ``kernels.ops.csd_matmul`` and
``kernels.flash_attention.paged_decode_attention`` consults this module at
trace time: a cache hit dispatches the *measured* winner configuration for
the call's regime, a miss (or ``REPRO_TUNE_DISABLE=1``, or a corrupt /
wrong-schema cache file) falls back to the static heuristic the repo
always had — tuning can only change which legal backend runs, never the
semantics (each backend's output is bit-identical whether it was chosen
explicitly or by the cache; the custom VJP and sharding contracts are
untouched).

Layout: ``cache.py`` (keys + versioned on-disk JSON), ``tuner.py``
(candidate enumeration + measurement), ``certify.py`` (SL101–SL105 gate
on Pallas candidates, pre-bench), ``__main__.py`` (CLI:
``python -m repro.tune`` pre-warms, ``--explain`` dumps decisions).

Misses are recorded (key -> full shape spec) so the CLI can pre-warm
exactly the regimes a traced model actually dispatches:
``jax.eval_shape`` a forward pass, then ``tuner.bench_*`` each pending
spec.
"""
from __future__ import annotations

from typing import Optional

from ..obs import metrics as _obs_metrics
from . import cache as _cache
from .cache import (SCHEMA_VERSION, TuneCache, blocks_enabled,  # noqa: F401
                    decode_key, default_path, device_kind, disabled,
                    get_cache, junction_key, m_bucket, reset_cache,
                    tile_key)

# key -> spec dict for every lookup that missed (the CLI's warm worklist)
_PENDING: dict = {}


def pending() -> dict:
    return dict(_PENDING)


def clear_pending() -> None:
    _PENDING.clear()


def _count(op: str, outcome: str) -> None:
    _obs_metrics.get_registry().counter(
        "repro_tune_lookup_total",
        "autotuner cache lookups by op/outcome (counted at trace time)",
    ).inc(op=op, outcome=outcome)


def _count_decision(op: str, entry: dict) -> None:
    _obs_metrics.get_registry().counter(
        "repro_tune_decision_total",
        "tuned dispatch decisions applied, by op/backend/dataflow",
    ).inc(op=op, backend=entry.get("backend", "?"),
          dataflow=entry.get("dataflow", "-"))


def _on_tpu() -> bool:
    try:
        import jax
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


def decide_junction(*, m: int, n_in: int, n_out: int, rho: float,
                    E: int = 0, dtype: str = "float32",
                    quant: bool = False, form: str = "plain",
                    block_in: int = 128, block_out: int = 128
                    ) -> Optional[dict]:
    """Measured dispatch decision for one ``csd_matmul`` regime, or
    ``None`` (miss / disabled / illegal entry) — the caller then falls
    back to the static heuristic. Called at trace time only."""
    if _cache.disabled():
        _count("csd_spmm", "disabled")
        return None
    key = _cache.junction_key(m=m, n_in=n_in, n_out=n_out, rho=rho, E=E,
                              dtype=dtype, quant=quant, form=form)
    ent = get_cache().get(key)
    if ent is None:
        _count("csd_spmm", "miss")
        _PENDING.setdefault(key, dict(
            op="csd_spmm", m=int(m), n_in=int(n_in), n_out=int(n_out),
            rho=float(rho), E=int(E), dtype=str(dtype), quant=bool(quant),
            form=str(form), block_in=int(block_in),
            block_out=int(block_out)))
        return None
    allowed = {"pallas", "xla"} if (quant or "sharded" in form) \
        else {"pallas", "xla", "dense"}
    be = ent.get("backend")
    if be not in allowed or (be == "pallas" and not _on_tpu()) \
            or ent.get("dataflow", "gather") not in ("gather", "scatter"):
        _count("csd_spmm", "invalid")
        return None
    _count("csd_spmm", "hit")
    _count_decision("csd_spmm", ent)
    return ent


def decide_decode(*, b: int, h_kv: int, groups: int, head_dim: int,
                  page_size: int, n_pages: int, pool: int,
                  quant: bool = False, dtype: str = "float32"
                  ) -> Optional[dict]:
    """Measured backend for one paged-decode regime, or ``None``."""
    if _cache.disabled():
        _count("paged_decode", "disabled")
        return None
    key = _cache.decode_key(b=b, h_kv=h_kv, groups=groups,
                            head_dim=head_dim, page_size=page_size,
                            n_pages=n_pages, pool=pool, quant=quant,
                            dtype=dtype)
    ent = get_cache().get(key)
    if ent is None:
        _count("paged_decode", "miss")
        _PENDING.setdefault(key, dict(
            op="paged_decode", b=int(b), h_kv=int(h_kv),
            groups=int(groups), head_dim=int(head_dim),
            page_size=int(page_size), n_pages=int(n_pages),
            pool=int(pool), quant=bool(quant), dtype=str(dtype)))
        return None
    be = ent.get("backend")
    if be not in ("pallas", "xla") or (be == "pallas" and not _on_tpu()):
        _count("paged_decode", "invalid")
        return None
    _count("paged_decode", "hit")
    _count_decision("paged_decode", ent)
    return ent


def decide_tile(*, n_in: int, n_out: int, rho: float, E: int = 0,
                dtype: str = "float32") -> Optional[dict]:
    """Measured ``(bL, bR)`` tile for one junction family. Gated on
    ``REPRO_TUNE_BLOCKS=1`` (a tuned tile is a different pattern — new
    parameters, new numerics — so it never activates implicitly)."""
    if _cache.disabled() or not _cache.blocks_enabled():
        return None
    key = _cache.tile_key(n_in=n_in, n_out=n_out, rho=rho, E=E,
                          dtype=dtype)
    ent = get_cache().get(key)
    if ent is None or "block_in" not in ent or "block_out" not in ent:
        _count("fit_blocks", "miss" if ent is None else "invalid")
        return None
    _count("fit_blocks", "hit")
    return ent
