"""Block-level pre-defined sparsity — the TPU-native adaptation.

The paper's clash-free generators (``repro.core.sparsity``) operate on
*neurons*; a TPU's natural unit of edge-parallelism is a (bL x bR) MXU tile
("z edges in parallel" -> "one 128x128 tile per MXU issue", DESIGN.md §2).
Lifting the generator from neurons to *blocks* keeps the entire pattern
family (type 1/2/3 seeds, dithering, clash-freedom) and makes every surviving
"edge" a dense tile: compute and HBM traffic scale with density while the MXU
stays fully utilized.

``BlockPattern`` carries both adjacency directions:

* ``block_idx[rb, f]``  — left block feeding fan-in slot ``f`` of right block
  ``rb`` (gather / column-parallel form);
* ``out_idx[lb, g], out_slot[lb, g]`` — the (right block, fan-in slot) pairs
  fed by left block ``lb`` (scatter / row-parallel form, used for the
  row-parallel down-projection and for dx in the backward pass).

Clash-freedom at block level means: in grid step ``t`` the ``z_b`` parallel
tile-processors read ``z_b`` *distinct* left blocks — i.e. no VMEM tile is
streamed twice in one step (the HBM-bandwidth analogue of the paper's
SRAM-port clash).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import sparsity


@dataclasses.dataclass(frozen=True)
class BlockPattern:
    """Pre-defined block-sparse pattern for an (n_in x n_out) junction."""

    n_in: int
    n_out: int
    block_in: int   # bL
    block_out: int  # bR
    block_idx: np.ndarray  # (n_rb, d_in_b) int32 — gather form
    out_idx: np.ndarray    # (n_lb, d_out_b) int32 — scatter form: right block
    out_slot: np.ndarray   # (n_lb, d_out_b) int32 — scatter form: fan-in slot
    meta: dict = dataclasses.field(default_factory=dict, compare=False)

    @property
    def n_lb(self) -> int:
        return self.n_in // self.block_in

    @property
    def n_rb(self) -> int:
        return self.n_out // self.block_out

    @property
    def d_in_b(self) -> int:
        return int(self.block_idx.shape[1])

    @property
    def d_out_b(self) -> int:
        return int(self.out_idx.shape[1])

    @property
    def density(self) -> float:
        return self.d_in_b / self.n_lb

    @property
    def n_weight_elems(self) -> int:
        return self.n_rb * self.d_in_b * self.block_in * self.block_out

    def to_block_mask(self) -> np.ndarray:
        """(n_lb, n_rb) 0/1 block adjacency."""
        m = np.zeros((self.n_lb, self.n_rb), dtype=np.float32)
        j = np.repeat(np.arange(self.n_rb), self.d_in_b)
        m[self.block_idx.reshape(-1), j] = 1.0
        return m

    def to_mask(self) -> np.ndarray:
        """Full (n_in, n_out) element mask (for oracle checks)."""
        bm = self.to_block_mask()
        return np.kron(bm, np.ones((self.block_in, self.block_out),
                                   dtype=np.float32))


def make_block_pattern(
    n_in: int,
    n_out: int,
    rho: float,
    *,
    block_in: int = 128,
    block_out: int = 128,
    method: str = "clashfree",
    seed: int = 0,
    cf_type: int = 1,
    dither: bool = False,
    z: Optional[int] = None,
) -> BlockPattern:
    """Lift the paper's pattern generator to block granularity.

    Density is quantized to multiples of ``1/gcd(n_lb, n_rb)`` exactly as in
    Appendix A, now over block counts. ``rho=1`` (or n_lb==d_in_b) degrades
    gracefully to a fully-connected junction — the paper's §III-E special
    case.
    """
    if n_in % block_in or n_out % block_out:
        raise ValueError(
            f"block sizes must divide junction dims: ({n_in},{n_out}) vs "
            f"({block_in},{block_out})")
    n_lb, n_rb = n_in // block_in, n_out // block_out
    pat = sparsity.make_pattern(
        n_lb, n_rb, rho, method=method, seed=seed, cf_type=cf_type,
        dither=dither, z=z)
    if pat.method == "random":
        raise ValueError("block mode requires fixed-degree (structured or "
                         "clash-free) patterns")
    block_idx = pat.idx  # (n_rb, d_in_b)
    ridx = sparsity.transpose_pattern(pat)  # (n_lb, d_out_b, 2)
    return BlockPattern(
        n_in=n_in, n_out=n_out, block_in=block_in, block_out=block_out,
        block_idx=block_idx.astype(np.int32),
        out_idx=ridx[:, :, 0].astype(np.int32),
        out_slot=ridx[:, :, 1].astype(np.int32),
        meta=dict(pat.meta, method=pat.method, seed=seed),
    )


def shrink_to_divisor(dim: int, block: int) -> int:
    """Largest power-of-two shrink of ``block`` (capped at ``dim``) that
    divides ``dim`` — the one block-size adaptation rule, shared by every
    junction-instantiating layer (``fit_block_pattern``, ``SparseMLP``)."""
    b = min(block, dim)
    while dim % b:
        b //= 2
    return b


def fit_block_pattern(n_in: int, n_out: int, rho: float, sp,
                      seed: int = 0) -> Optional[BlockPattern]:
    """Adapt a ``SparsityConfig``'s block sizes to one junction, or return
    ``None`` if the junction should stay dense.

    ``sp`` is duck-typed (any object with the SparsityConfig fields) so the
    core layer needs no import from ``nn``. Policy — shared by every layer
    that instantiates junctions (``nn.layers.Linear``, ``nn.ffn.MoE``):

    * disabled sparsity or ``rho >= 1`` -> dense (``None``);
    * block sizes shrink by powers of two until they divide the junction
      dims;
    * hardware-divisibility guard (the block analogue of the paper's
      Appendix-B "z must divide N" constraint): junctions whose dims only
      admit micro blocks (< 32 wide, e.g. mamba's packed in_proj of width
      3352) waste the MXU and blow up the XLA dataflow — they stay dense.
    """
    if sp is None or not sp.enabled or rho >= 1.0:
        return None
    bi = shrink_to_divisor(n_in, sp.block_in)
    bo = shrink_to_divisor(n_out, sp.block_out)
    min_b = min(32, sp.block_in, sp.block_out)
    if bi < min_b or bo < min_b:
        return None
    return make_block_pattern(
        n_in, n_out, rho, block_in=bi, block_out=bo, method=sp.method,
        seed=sp.seed + seed, cf_type=sp.cf_type, dither=sp.dither)
