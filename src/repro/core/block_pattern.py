"""Block-level pre-defined sparsity — the TPU-native adaptation.

The paper's clash-free generators (``repro.core.sparsity``) operate on
*neurons*; a TPU's natural unit of edge-parallelism is a (bL x bR) MXU tile
("z edges in parallel" -> "one 128x128 tile per MXU issue", DESIGN.md §2).
Lifting the generator from neurons to *blocks* keeps the entire pattern
family (type 1/2/3 seeds, dithering, clash-freedom) and makes every surviving
"edge" a dense tile: compute and HBM traffic scale with density while the MXU
stays fully utilized.

``BlockPattern`` carries both adjacency directions:

* ``block_idx[rb, f]``  — left block feeding fan-in slot ``f`` of right block
  ``rb`` (gather / column-parallel form);
* ``out_idx[lb, g], out_slot[lb, g]`` — the (right block, fan-in slot) pairs
  fed by left block ``lb`` (scatter / row-parallel form, used for the
  row-parallel down-projection and for dx in the backward pass).

Clash-freedom at block level means: in grid step ``t`` the ``z_b`` parallel
tile-processors read ``z_b`` *distinct* left blocks — i.e. no VMEM tile is
streamed twice in one step (the HBM-bandwidth analogue of the paper's
SRAM-port clash).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional

import numpy as np

from . import sparsity


def _debug_on(debug: Optional[bool]) -> bool:
    """Resolve a three-state debug flag: explicit argument wins, else the
    ``REPRO_PATTERN_DEBUG`` env var enables checking globally."""
    if debug is not None:
        return debug
    return bool(os.environ.get("REPRO_PATTERN_DEBUG"))


def _check_or_raise(check, obj, subject: str) -> None:
    findings = check(obj, subject)
    if findings:
        lines = "\n".join(f"  {f.code} {f.subject}: {f.message}"
                          for f in findings)
        raise ValueError(
            f"pattern invariant violation ({len(findings)} finding(s)):\n"
            f"{lines}")


@dataclasses.dataclass(frozen=True)
class BlockPattern:
    """Pre-defined block-sparse pattern for an (n_in x n_out) junction."""

    n_in: int
    n_out: int
    block_in: int   # bL
    block_out: int  # bR
    block_idx: np.ndarray  # (n_rb, d_in_b) int32 — gather form
    out_idx: np.ndarray    # (n_lb, d_out_b) int32 — scatter form: right block
    out_slot: np.ndarray   # (n_lb, d_out_b) int32 — scatter form: fan-in slot
    # 0/1 validity of scatter-form entries, or None when every entry is
    # real. Shard-local patterns (``partition_pattern``) have non-uniform
    # out-degree and pad their scatter form to a fixed width; every
    # scatter-form consumer (``kernels.ops``/``csd_spmm`` BP and scatter
    # dataflow) honors this mask, so a shard pattern is a full citizen of
    # the public ``csd_matmul`` API.
    out_valid: Optional[np.ndarray] = None
    meta: dict = dataclasses.field(default_factory=dict, compare=False)

    @property
    def n_lb(self) -> int:
        return self.n_in // self.block_in

    @property
    def n_rb(self) -> int:
        return self.n_out // self.block_out

    @property
    def d_in_b(self) -> int:
        return int(self.block_idx.shape[1])

    @property
    def d_out_b(self) -> int:
        return int(self.out_idx.shape[1])

    @property
    def density(self) -> float:
        return self.d_in_b / self.n_lb

    @property
    def n_weight_elems(self) -> int:
        return self.n_rb * self.d_in_b * self.block_in * self.block_out

    def to_block_mask(self) -> np.ndarray:
        """(n_lb, n_rb) 0/1 block adjacency."""
        m = np.zeros((self.n_lb, self.n_rb), dtype=np.float32)
        j = np.repeat(np.arange(self.n_rb), self.d_in_b)
        m[self.block_idx.reshape(-1), j] = 1.0
        return m

    def to_mask(self) -> np.ndarray:
        """Full (n_in, n_out) element mask (for oracle checks)."""
        bm = self.to_block_mask()
        return np.kron(bm, np.ones((self.block_in, self.block_out),
                                   dtype=np.float32))


def make_block_pattern(
    n_in: int,
    n_out: int,
    rho: float,
    *,
    block_in: int = 128,
    block_out: int = 128,
    method: str = "clashfree",
    seed: int = 0,
    cf_type: int = 1,
    dither: bool = False,
    z: Optional[int] = None,
) -> BlockPattern:
    """Lift the paper's pattern generator to block granularity.

    Density is quantized to multiples of ``1/gcd(n_lb, n_rb)`` exactly as in
    Appendix A, now over block counts. ``rho=1`` (or n_lb==d_in_b) degrades
    gracefully to a fully-connected junction — the paper's §III-E special
    case.
    """
    if n_in % block_in or n_out % block_out:
        raise ValueError(
            f"block sizes must divide junction dims: ({n_in},{n_out}) vs "
            f"({block_in},{block_out})")
    n_lb, n_rb = n_in // block_in, n_out // block_out
    pat = sparsity.make_pattern(
        n_lb, n_rb, rho, method=method, seed=seed, cf_type=cf_type,
        dither=dither, z=z)
    if pat.method == "random":
        raise ValueError("block mode requires fixed-degree (structured or "
                         "clash-free) patterns")
    block_idx = pat.idx  # (n_rb, d_in_b)
    ridx = sparsity.transpose_pattern(pat)  # (n_lb, d_out_b, 2)
    return BlockPattern(
        n_in=n_in, n_out=n_out, block_in=block_in, block_out=block_out,
        block_idx=block_idx.astype(np.int32),
        out_idx=ridx[:, :, 0].astype(np.int32),
        out_slot=ridx[:, :, 1].astype(np.int32),
        meta=dict(pat.meta, method=pat.method, seed=seed),
    )


# ---------------------------------------------------------------------------
# Pattern partitioning — the jax_pallas analogue of the paper's flexible-z
# hardware sizing. The FPGA processes a junction z block-rows at a time; a
# mesh with a tensor axis of size k processes k disjoint block-row ranges
# *simultaneously*, one range per device. Clash-freedom is a per-block-row
# property, so any row-disjoint split preserves it shard-locally.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PartitionedPattern:
    """A ``BlockPattern`` split into ``n_shards`` shard-local patterns over
    disjoint output block-row ranges.

    * ``shards[s]``      — shard-local ``BlockPattern`` (gather form indexes
      the FULL left-block range: activations are feature-complete on every
      device; only the *output* rows are partitioned);
    * ``row_assign[rb]`` — owning shard of original block-row ``rb``;
    * ``perm``           — original row ids in shard-major concatenation
      order (shard 0's rows, then shard 1's, ...);
    * ``inv_perm``       — inverse of ``perm``: ``y_logical_block[i] =
      y_shard_major_block[inv_perm[i]]`` reassembles shard-major outputs
      into the junction's logical feature order;
    * ``out_idx/out_slot/out_valid`` (stacked, ``(n_shards, n_lb, d_loc)``)
      — each shard's scatter form over *local* row ids, padded to the max
      local out-degree; padding entries point at (0, 0) with ``out_valid ==
      0`` so the BP kernels can zero their contribution.

    Uniform-degree patterns (everything ``make_block_pattern`` produces)
    are split into *contiguous* equal ranges: every row carries the same
    slot count, so any equal split is slot-balanced, and contiguity makes
    the shard-major layout coincide with the logical layout (``perm`` is
    the identity) — the global weight slab can then be row-sharded by a
    plain ``NamedSharding`` with zero data movement. The permutation
    plumbing (``perm``/``inv_perm``, honored by the slab helpers and
    ``reassemble_outputs``) carries a general assignment for future
    variable-degree patterns.
    """

    parent: BlockPattern
    n_shards: int
    shards: tuple  # tuple[BlockPattern]
    row_assign: np.ndarray   # (n_rb,) int32
    perm: np.ndarray         # (n_rb,) int32, shard-major order
    inv_perm: np.ndarray     # (n_rb,) int32
    idx: np.ndarray          # (n_shards, n_rb_loc, d_in_b) int32 stacked
    out_idx: np.ndarray      # (n_shards, n_lb, d_loc) int32 stacked
    out_slot: np.ndarray     # (n_shards, n_lb, d_loc) int32 stacked
    out_valid: np.ndarray    # (n_shards, n_lb, d_loc) int32 stacked 0/1

    @property
    def n_rb_local(self) -> int:
        return self.idx.shape[1]

    @property
    def contiguous(self) -> bool:
        return bool((self.perm == np.arange(len(self.perm))).all())


def _local_scatter(block_idx_local: np.ndarray, n_lb: int, d_loc: int):
    """Scatter form of one shard's (n_rb_loc, d_in_b) gather pattern over
    *local* row ids, padded to ``d_loc`` entries per left block."""
    n_rb_loc, d_in_b = block_idx_local.shape
    oidx = np.zeros((n_lb, d_loc), np.int32)
    oslot = np.zeros((n_lb, d_loc), np.int32)
    ovalid = np.zeros((n_lb, d_loc), np.int32)
    fill = np.zeros(n_lb, np.int64)
    for r in range(n_rb_loc):
        for f in range(d_in_b):
            lb = int(block_idx_local[r, f])
            oidx[lb, fill[lb]] = r
            oslot[lb, fill[lb]] = f
            ovalid[lb, fill[lb]] = 1
            fill[lb] += 1
    return oidx, oslot, ovalid


def partition_pattern(pattern: BlockPattern, axis_size: int,
                      debug: Optional[bool] = None) -> PartitionedPattern:
    """Split ``pattern`` into ``axis_size`` shard-local patterns over
    disjoint output block-row ranges, load-balanced by slot count.

    Requires ``n_rb % axis_size == 0`` (every shard must run the same SPMD
    program, so local shapes must match). Raises ``ValueError`` otherwise —
    callers use :func:`can_partition` to gate the sharded path.

    ``debug=True`` (or ``REPRO_PATTERN_DEBUG=1``) runs the sparselint
    SL3xx invariant checks on the result and raises on any finding.
    """
    n_rb = pattern.n_rb
    if axis_size < 1:
        raise ValueError(f"axis_size must be >= 1, got {axis_size}")
    if n_rb % axis_size:
        raise ValueError(
            f"pattern with n_rb={n_rb} block-rows cannot split over "
            f"axis_size={axis_size} shards (SPMD needs equal local shapes)")
    q = n_rb // axis_size
    # every BlockPattern row carries exactly d_in_b slots (fixed-degree is
    # structural: block_idx is a dense (n_rb, d_in_b) array), so contiguous
    # equal ranges are already slot-balanced AND keep perm == identity —
    # the global slab's NamedSharding row chunks are exactly the per-device
    # slabs. A future variable-degree pattern would need a balanced
    # assignment here; perm/inv_perm and the slab helpers already carry a
    # general permutation for that day.
    row_assign = np.repeat(np.arange(axis_size), q).astype(np.int32)
    shard_rows = [np.flatnonzero(row_assign == s) for s in range(axis_size)]
    perm = np.concatenate(shard_rows).astype(np.int32)
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(n_rb, dtype=np.int32)

    idx_stk = np.stack([pattern.block_idx[rows] for rows in shard_rows])
    d_loc = 0
    for s in range(axis_size):
        counts = np.bincount(idx_stk[s].reshape(-1), minlength=pattern.n_lb)
        d_loc = max(d_loc, int(counts.max()))
    oidx_l, oslot_l, ovalid_l, shards = [], [], [], []
    for s in range(axis_size):
        oi, os, ov = _local_scatter(idx_stk[s], pattern.n_lb, d_loc)
        oidx_l.append(oi)
        oslot_l.append(os)
        ovalid_l.append(ov)
        shards.append(BlockPattern(
            n_in=pattern.n_in, n_out=q * pattern.block_out,
            block_in=pattern.block_in, block_out=pattern.block_out,
            block_idx=idx_stk[s].astype(np.int32),
            out_idx=oi, out_slot=os, out_valid=ov,
            meta=dict(pattern.meta, shard=s, of=axis_size,
                      rows=shard_rows[s].tolist()),
        ))
    part = PartitionedPattern(
        parent=pattern, n_shards=axis_size, shards=tuple(shards),
        row_assign=row_assign, perm=perm, inv_perm=inv_perm,
        idx=idx_stk.astype(np.int32),
        out_idx=np.stack(oidx_l), out_slot=np.stack(oslot_l),
        out_valid=np.stack(ovalid_l))
    if _debug_on(debug):
        from ..analysis.pattern_pass import check_partition
        _check_or_raise(check_partition, part, "partition_pattern")
    return part


def can_partition(pattern: Optional[BlockPattern], axis_size: int) -> bool:
    """True when the sharded junction path applies: a real pattern, more
    than one shard, and equal per-shard block-row counts."""
    return (pattern is not None and axis_size > 1
            and pattern.n_rb % axis_size == 0
            and pattern.n_rb >= axis_size)


def _xp(a):
    """numpy for numpy inputs, jax.numpy for jax arrays (host helpers —
    not meant to run inside jit, but jit-safe for the jax branch)."""
    if isinstance(a, np.ndarray):
        return np
    import jax.numpy as jnp
    return jnp


def split_slab(w, part: PartitionedPattern):
    """Split a weight slab into per-shard slabs along the block-row dim.

    4-D ``(n_rb, d_in_b, bL, bR)`` -> ``(n_shards, n_rb_loc, d_in_b, bL,
    bR)``; 5-D expert slabs ``(E, n_rb, ...)`` -> ``(n_shards, E,
    n_rb_loc, ...)``. The 2-D/3-D per-block *scale* arrays of a quantized
    slab (``core.quant``: ``(n_rb, d_in_b)`` / ``(E, n_rb, d_in_b)``)
    split the same way — they are slabs without the trailing block dims.
    Works on numpy or jax arrays (pure take/reshape).
    """
    xp = _xp(w)
    rb_axis = 0 if w.ndim in (2, 4) else 1
    if w.shape[rb_axis] != len(part.perm):
        raise ValueError(f"slab block-row dim {w.shape[rb_axis]} != "
                         f"pattern n_rb {len(part.perm)}")
    wp = xp.take(w, part.perm, axis=rb_axis)
    q = part.n_rb_local
    if rb_axis == 0:
        return wp.reshape((part.n_shards, q) + w.shape[1:])
    # (E, n_rb, ...): shard-major leading dim so shards stay
    # addressable as ws[s]
    wp = wp.reshape((w.shape[0], part.n_shards, q) + w.shape[2:])
    return xp.moveaxis(wp, 1, 0)


def merge_slab(ws, part: PartitionedPattern):
    """Inverse of :func:`split_slab`: per-shard slabs back to the logical
    block-row order."""
    xp = _xp(ws)
    if ws.ndim in (3, 5):  # (k, n_rb_loc, ...) — 4-D slab or 2-D scales
        flat = ws.reshape((-1,) + ws.shape[2:])
        return xp.take(flat, part.inv_perm, axis=0)
    # (k, E, n_rb_loc, ...) — 5-D slab or 3-D scales
    sw = xp.moveaxis(ws, 0, 1)
    flat = sw.reshape((sw.shape[0], -1) + sw.shape[3:])
    return xp.take(flat, part.inv_perm, axis=1)


def reassemble_outputs(y, part: PartitionedPattern):
    """Reorder a shard-major feature axis back to logical feature order.

    ``y``: (..., n_out) with output blocks concatenated shard-major.
    No-op (returns ``y``) for contiguous partitions.
    """
    if part.contiguous:
        return y
    xp = _xp(y)
    br = part.parent.block_out
    yb = y.reshape(y.shape[:-1] + (len(part.perm), br))
    yb = xp.take(yb, part.inv_perm, axis=-2)
    return yb.reshape(y.shape)


def shrink_to_divisor(dim: int, block: int) -> int:
    """Largest power-of-two shrink of ``block`` (capped at ``dim``) that
    divides ``dim`` — the one block-size adaptation rule, shared by every
    junction-instantiating layer (``fit_block_pattern``, ``SparseMLP``)."""
    b = min(block, dim)
    while dim % b:
        b //= 2
    return b


def fit_block_pattern(n_in: int, n_out: int, rho: float, sp,
                      seed: int = 0,
                      debug: Optional[bool] = None,
                      weight_dtype=None
                      ) -> Optional[BlockPattern]:
    """Adapt a ``SparsityConfig``'s block sizes to one junction, or return
    ``None`` if the junction should stay dense.

    ``sp`` is duck-typed (any object with the SparsityConfig fields) so the
    core layer needs no import from ``nn``. Policy — shared by every layer
    that instantiates junctions (``nn.layers.Linear``, ``nn.ffn.MoE``):

    * disabled sparsity or ``rho >= 1`` -> dense (``None``);
    * block sizes shrink by powers of two until they divide the junction
      dims;
    * hardware-divisibility guard (the block analogue of the paper's
      Appendix-B "z must divide N" constraint): junctions whose dims only
      admit micro blocks (< 32 wide, e.g. mamba's packed in_proj of width
      3352) waste the MXU and blow up the XLA dataflow — they stay dense.
    """
    if sp is None or not sp.enabled or rho >= 1.0:
        return None
    bi = shrink_to_divisor(n_in, sp.block_in)
    bo = shrink_to_divisor(n_out, sp.block_out)
    min_b = min(32, sp.block_in, sp.block_out)
    if bi < min_b or bo < min_b:
        return None
    # measured tile refit (PR 10, opt-in via REPRO_TUNE_BLOCKS=1): the
    # autotuner's per-junction (bL, bR) winner replaces the config tiles.
    # Opt-in because a different tile is a different pattern — different
    # parameter shapes and numerics, unlike the performance-only dispatch
    # cache. Illegal/shrunken-away tuned tiles fall back to the heuristic.
    if os.environ.get("REPRO_TUNE_BLOCKS", "") not in ("", "0"):
        from .. import tune
        t = tune.decide_tile(
            n_in=n_in, n_out=n_out, rho=rho,
            dtype=str(np.dtype(weight_dtype or np.float32)))
        if t is not None:
            tbi = shrink_to_divisor(n_in, int(t["block_in"]))
            tbo = shrink_to_divisor(n_out, int(t["block_out"]))
            if tbi >= min_b and tbo >= min_b:
                bi, bo = tbi, tbo
    bp = make_block_pattern(
        n_in, n_out, rho, block_in=bi, block_out=bo, method=sp.method,
        seed=sp.seed + seed, cf_type=sp.cf_type, dither=sp.dither)
    # ``debug=True`` (or REPRO_PATTERN_DEBUG=1): certify the generated
    # pattern with the sparselint SL3xx checks before it reaches a kernel
    if _debug_on(debug):
        from ..analysis.pattern_pass import check_pattern
        _check_or_raise(check_pattern, bp,
                        f"fit_block_pattern({n_in}x{n_out}, rho={rho})")
    # export the junction's static complexity accounting (sparse/dense
    # MACs, storage, rho, speedup) as live gauges — every junction the
    # model instantiates becomes observable at fit time. ``weight_dtype``
    # is the slab's actual storage dtype (bf16 slabs are 2 B/elem, not
    # 4); a quantized inference path (``sp.quant``) additionally exports
    # the rho x bits/32 compression gauges.
    from ..obs import flops as _obs_flops
    wb = np.dtype(weight_dtype).itemsize if weight_dtype is not None else 4
    qc = getattr(sp, "quant", None)
    _obs_flops.register(bp, weight_bytes_per_elem=wb,
                        quant_bits=getattr(qc, "bits", None)
                        if qc is not None and getattr(qc, "weights", False)
                        else None)
    return bp
