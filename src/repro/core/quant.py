"""Per-block symmetric int8 quantization for block-sparse junction slabs.

The paper's hardware runs reduced-precision fixed-point arithmetic; its
FPGA companion (arXiv:1806.01087) and "Sparsely-Connected Neural
Networks" (arXiv:1611.01427) both show low-bitwidth weights compose
*multiplicatively* with pre-defined sparsity: storage drops by
``rho x bits/32``. This module is the software half of that claim for
the serving path — weights are quantized **once at engine load**, never
during training (training stays full-width; see ``serving.engine``).

Granularity is one scale per surviving (bL x bR) weight block — the unit
the CSD-SpMM kernels stream — so the scale tile rides the same
``(n_rb, d_in_b)``-indexed layout as the gather pattern:

* 4-D slab ``(n_rb, d_in_b, bL, bR)``      -> scales ``(n_rb, d_in_b)``
* 5-D slab ``(E, n_rb, d_in_b, bL, bR)``   -> scales ``(E, n_rb, d_in_b)``
* scanned stacks prepend a layer dim to both.

Because the scales carry the slab's leading dims, they split/merge under
``core.block_pattern.split_slab``/``merge_slab`` (generalized to the
2-D/3-D scale shapes) and shard under the same ``"slab"``/``"expert"``
policy rules — the sharded junction path works unchanged.

Quantization is symmetric (zero-preserving, range [-127, 127]) per
block: ``scale = max|w_block| / 127``; elementwise error is bounded by
``scale / 2``. Dequantization happens *in-kernel/in-register* (the int8
slab is what enters HBM traffic — certified by sparselint SL206).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Inference-path quantization knobs (see README "Quantized junctions").

    ``weights`` — quantize every block-sparse junction slab to int8 with
    per-block scales; ``kv`` — quantize the paged KV cache pages to int8
    with per-token scales written at append time; ``bits`` — weight/KV
    bitwidth (only 8 is implemented; the field exists so the storage
    gauges and README formula stay honest about the knob).
    """

    weights: bool = True
    kv: bool = True
    bits: int = 8

    def __post_init__(self):
        if self.bits != 8:
            raise ValueError(f"only int8 quantization is implemented "
                             f"(bits={self.bits})")


def quantize_slab(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8 quantization of a weight slab.

    Works for any leading dims — the amax reduces over the trailing
    (bL, bR) block dims only. Returns ``(q int8, scales f32)`` with
    ``scales.shape == w.shape[:-2]``.
    """
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=(-2, -1))
    scales = jnp.maximum(amax, 1e-12) / _QMAX
    q = jnp.clip(jnp.round(wf / scales[..., None, None]), -_QMAX, _QMAX)
    return q.astype(jnp.int8), scales


def dequantize_slab(q: jax.Array, scales: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_slab` (the test oracle — the kernels
    never materialize this full-width slab; that is SL206's contract)."""
    return (q.astype(jnp.float32) * scales[..., None, None]).astype(dtype)


# ---------------------------------------------------------------------------
# Param-tree quantization: walk params and model.spec() in parallel, turn
# every sparse junction slab into (int8 slab, f32 "<name>_scale" sibling)
# and extend the spec so the sharding policy places the scales next to
# their slab chunks.
# ---------------------------------------------------------------------------


def _is_spec_leaf(s: Any) -> bool:
    return isinstance(s, tuple) and all(
        a is None or isinstance(a, str) for a in s)


def _is_slab_spec(axes: Tuple[Optional[str], ...], ndim: int) -> bool:
    """A param leaf is a junction slab iff its logical axes name the
    block-row dim ``"slab"`` with 4 trailing slab dims, or the
    expert-major dim ``"expert"`` with 5 (the batched slab). Scanned
    stacks prepend ``"layers"`` and still match; dense expert weights
    (``("expert", "embed", ...)``, 3-D) do not."""
    if not isinstance(axes, tuple) or len(axes) != ndim:
        return False
    if "slab" in axes:
        return ndim - axes.index("slab") == 4
    if "expert" in axes:
        return ndim - axes.index("expert") == 5
    return False


def _walk(p: Any, s: Any, fn):
    """Parallel recursion over a params tree and its spec tree; ``fn(leaf,
    axes)`` returns ``None`` (keep as-is) or a ``(q, scales)`` pair."""
    if isinstance(p, dict):
        qp: dict = {}
        qs: dict = {}
        for k, v in p.items():
            sv = s[k]
            if _is_spec_leaf(sv):
                res = fn(v, sv)
                if res is not None:
                    qp[k], qp[k + "_scale"] = res
                    qs[k], qs[k + "_scale"] = sv, sv[:-2]
                else:
                    qp[k], qs[k] = v, sv
            else:
                qp[k], qs[k] = _walk(v, sv, fn)
        return qp, qs
    if isinstance(p, (list, tuple)):
        pairs = [_walk(a, b, fn) for a, b in zip(p, s)]
        t = type(p)
        return t(x[0] for x in pairs), t(x[1] for x in pairs)
    return p, s


def quantize_tree(params: Any, spec: Any) -> Tuple[Any, Any]:
    """Quantize every sparse junction slab in a param tree.

    Returns ``(new_params, new_spec)``: each slab leaf ``k`` becomes int8
    with an f32 sibling ``k + "_scale"`` (spec = the slab's leading axes),
    so the junction call sites (``nn.layers.Linear``, ``SparseLinear``,
    ``MoE``) pick up the quantized path by key presence and the sharding
    policy resolves the scale placement from the extended spec.
    """
    def fn(leaf, axes):
        if _is_slab_spec(axes, getattr(leaf, "ndim", 0)):
            return quantize_slab(leaf)
        return None

    return _walk(params, spec, fn)


def quantize_spec(spec: Any, params: Any) -> Any:
    """Spec-only half of :func:`quantize_tree` — usable with abstract
    params (``ShapeDtypeStruct`` trees): only ``ndim`` is read."""
    def fn(leaf, axes):
        if _is_slab_spec(axes, getattr(leaf, "ndim", 0)):
            return leaf, None  # placeholders; only the spec side is kept
        return None

    return _walk(params, spec, fn)[1]
