"""Pre-defined sparse linear layers (the paper's junction, as a JAX module).

Three execution modes, one statistical family:

* ``mask``   — dense weight * fixed 0/1 mask. Bit-exact reproduction of the
               paper's training dynamics (the gradient of a masked weight is
               the masked gradient, eq. (4b) restricted to existing edges).
               Runs at dense speed; used by the paper-repro benchmarks and as
               the oracle for everything else.
* ``gather`` — weights stored compactly ``(n_out, d_in)`` with the index
               pattern ``idx[j, f]``; compute and storage scale with density.
               This is the literal per-edge formulation of eq. (2a).
* ``block``  — TPU-native block-circulant form (``BlockPattern``): weights
               ``(n_rb, d_in_b, bL, bR)``. Both block modes execute through
               the ONE accelerated junction primitive,
               ``kernels.ops.csd_matmul`` (``backend="auto"``: Pallas
               kernels on TPU, slot-wise XLA elsewhere), with bias and the
               layer activation fused into the kernel epilogue. The mode
               only selects the XLA ``dataflow``:
               - ``block_gather`` (column-parallel): each right block pulls
                 its ``d_in_b`` left blocks — output sharding friendly;
               - ``block_scatter`` (row-parallel): each left block pushes
                 partial sums into the right blocks it feeds — input
                 sharding friendly; GSPMD turns the segment-sum into the
                 Megatron-style all-reduce.
               The old materializing einsum forms live on as oracles in
               ``kernels.ref`` (``block_gather_ref``/``block_scatter_ref``).

All modes share initialization: He/fan-in scaling with the *actual* in-degree
(d_in, not n_in), matching the paper's use of He init on sparse junctions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import sparsity
from .block_pattern import BlockPattern, make_block_pattern

Mode = Literal["mask", "gather", "block_gather", "block_scatter", "dense"]


@dataclasses.dataclass(frozen=True)
class SparseLinearSpec:
    """Static configuration of one sparse junction."""

    n_in: int
    n_out: int
    rho: float = 1.0
    mode: Mode = "block_gather"
    method: str = "clashfree"   # pattern family (clashfree|structured|random)
    block_in: int = 128
    block_out: int = 128
    cf_type: int = 1
    dither: bool = False
    seed: int = 0
    use_bias: bool = True
    dtype: str = "float32"

    def pattern(self) -> sparsity.JunctionPattern:
        return sparsity.make_pattern(
            self.n_in, self.n_out, self.rho, method=self.method,
            seed=self.seed, cf_type=self.cf_type, dither=self.dither)

    def block_pattern(self) -> BlockPattern:
        return make_block_pattern(
            self.n_in, self.n_out, self.rho, block_in=self.block_in,
            block_out=self.block_out, method=self.method, seed=self.seed,
            cf_type=self.cf_type, dither=self.dither)


class SparseLinear:
    """Functional module: ``layer = SparseLinear(spec); p = layer.init(key);
    y = layer(p, x)``. The pattern is a compile-time constant (numpy),
    never a traced value — exactly the paper's 'pre-defined' property.
    """

    def __init__(self, spec: SparseLinearSpec):
        self.spec = spec
        self.dtype = jnp.dtype(spec.dtype)
        if spec.mode == "dense" or (spec.rho >= 1.0 and spec.mode != "gather"):
            self._mode = "dense"
            self.pattern = None
        elif spec.mode in ("mask", "gather"):
            self._mode = spec.mode
            self.pattern = spec.pattern()
            if spec.mode == "gather" and self.pattern.method == "random":
                raise ValueError("gather mode requires fixed degrees")
        elif spec.mode in ("block_gather", "block_scatter"):
            self._mode = spec.mode
            self.pattern = spec.block_pattern()
        else:
            raise ValueError(f"unknown mode {spec.mode}")

    # -- initialization ----------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        s = self.spec
        kw, _ = jax.random.split(key)
        params = {}
        if self._mode == "dense":
            fan_in = s.n_in
            w = jax.random.normal(kw, (s.n_in, s.n_out), self.dtype)
            params["w"] = w * np.sqrt(2.0 / fan_in)
        elif self._mode == "mask":
            pat = self.pattern
            fan_in = max(1, pat.n_edges // s.n_out)
            w = jax.random.normal(kw, (s.n_in, s.n_out), self.dtype)
            params["w"] = w * np.sqrt(2.0 / fan_in)
        elif self._mode == "gather":
            d_in = self.pattern.d_in
            w = jax.random.normal(kw, (s.n_out, d_in), self.dtype)
            params["w"] = w * np.sqrt(2.0 / d_in)
        else:  # block modes
            bp: BlockPattern = self.pattern
            fan_in = bp.d_in_b * bp.block_in
            w = jax.random.normal(
                kw, (bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out),
                self.dtype)
            params["w"] = w * np.sqrt(2.0 / fan_in)
        if s.use_bias:
            params["b"] = jnp.zeros((s.n_out,), self.dtype)
        return params

    # -- forward -----------------------------------------------------------

    def __call__(self, params: dict, x: jax.Array,
                 activation: Optional[str] = None) -> jax.Array:
        """Apply the junction: ``activation(x @ W_sparse + b)``.

        ``activation`` (``None | "relu" | "gelu"``) lets callers fuse the
        following nonlinearity into the junction — for the block modes it
        rides the ``csd_matmul`` kernel epilogue and never round-trips HBM;
        the other modes apply it inline.
        """
        s = self.spec
        w = params["w"]
        b = params["b"] if s.use_bias else None
        if self._mode in ("block_gather", "block_scatter"):
            # the single accelerated junction path (tentpole): bias +
            # activation fused into the kernel epilogue. Under a mesh
            # whose rules resolve the "slab" axis the junction runs
            # model-parallel. Layering note: the mesh/rules context lives
            # in nn.common (core sits below nn), so the import is lazy —
            # at call time only, and only to read runtime state.
            from ..nn.common import junction_shard_kwargs, logical_to_spec
            kw = junction_shard_kwargs(self.pattern)
            if kw:
                # keep the batch dim's data sharding through the shard_map
                # entry (same wiring as nn.layers.Linear)
                kw["lead_spec"] = tuple(logical_to_spec(
                    *(("batch",) + (None,) * (x.ndim - 2))))
            if "w_scale" in params:
                # int8 slab from quantize_slab/quantize_tree: pass it
                # uncast with its per-block scales (inference only)
                kw["w_scale"] = params["w_scale"]
            return kops.csd_matmul(
                x, w, self.pattern, bias=b, activation=activation,
                backend="auto",
                dataflow="scatter" if self._mode == "block_scatter"
                else "gather", **kw)
        if self._mode == "dense":
            y = x @ w
        elif self._mode == "mask":
            mask = jnp.asarray(sparsity.to_mask(self.pattern), w.dtype)
            y = x @ (w * mask)
        else:  # gather
            y = gather_apply(x, w, self.pattern.idx)
        if b is not None:
            y = y + b.astype(y.dtype)
        return kops.apply_activation(y, activation)

    # -- bookkeeping -------------------------------------------------------

    @property
    def n_weights(self) -> int:
        """Stored weight count — the paper's |W_i| (Table I)."""
        if self._mode == "dense":
            return self.spec.n_in * self.spec.n_out
        if self._mode == "mask":
            return self.pattern.n_edges  # logical; physical storage is dense
        if self._mode == "gather":
            return int(self.pattern.idx.size)
        return self.pattern.n_weight_elems


# ---------------------------------------------------------------------------
# Pure functions (jit/pjit friendly; patterns enter as static numpy constants)
# ---------------------------------------------------------------------------


def gather_apply(x: jax.Array, w: jax.Array, idx: np.ndarray) -> jax.Array:
    """Eq. (2a): h[..., j] = sum_f w[j, f] * x[..., idx[j, f]]."""
    idx = jnp.asarray(idx)  # (n_out, d_in)
    xg = jnp.take(x, idx.reshape(-1), axis=-1)  # (..., n_out*d_in)
    xg = xg.reshape(x.shape[:-1] + idx.shape)
    return jnp.einsum("...jf,jf->...j", xg, w)


def masked_dense_apply(x: jax.Array, w: jax.Array,
                       mask: np.ndarray) -> jax.Array:
    """Oracle: dense matmul against the masked weight."""
    return x @ (w * jnp.asarray(mask, w.dtype))


# ---------------------------------------------------------------------------
# Layout conversions (for cross-mode equivalence tests and checkpoints)
# ---------------------------------------------------------------------------


def gather_weights_to_dense(w: jax.Array, idx: np.ndarray,
                            n_in: int) -> jax.Array:
    """(n_out, d_in) compact weights -> (n_in, n_out) dense-with-zeros."""
    n_out, d_in = idx.shape
    dense = jnp.zeros((n_in, n_out), w.dtype)
    j = jnp.repeat(jnp.arange(n_out), d_in)
    return dense.at[jnp.asarray(idx.reshape(-1)), j].add(w.reshape(-1))


def block_weights_to_dense(w: jax.Array, bp: BlockPattern) -> jax.Array:
    """(n_rb, d_in_b, bL, bR) -> (n_in, n_out) dense-with-zeros."""
    dense = jnp.zeros((bp.n_in, bp.n_out), w.dtype)
    for rb in range(bp.n_rb):
        for f in range(bp.d_in_b):
            lb = int(bp.block_idx[rb, f])
            dense = dense.at[lb * bp.block_in:(lb + 1) * bp.block_in,
                             rb * bp.block_out:(rb + 1) * bp.block_out
                             ].set(w[rb, f])
    return dense


def dense_weights_to_gather(w_dense: jax.Array, idx: np.ndarray) -> jax.Array:
    """(n_in, n_out) -> (n_out, d_in) compact, reading pattern positions."""
    n_out, d_in = idx.shape
    j = jnp.repeat(jnp.arange(n_out), d_in)
    return w_dense[jnp.asarray(idx.reshape(-1)), j].reshape(n_out, d_in)
