"""repro.core — the paper's contribution: pre-defined sparsity.

Public API:

* pattern generation/validation: ``make_pattern``, ``clashfree_schedule``,
  ``schedule_is_clash_free``, ``possible_densities``, ...
* block-level lifting for TPU: ``make_block_pattern``, ``BlockPattern``
* the junction module: ``SparseLinear``, ``SparseLinearSpec``
* hardware storage model: ``storage_cost``, ``junction_cycles``
* inference-path int8 slabs: ``QuantConfig``, ``quantize_slab``,
  ``quantize_tree`` (per-block scales riding the slab layout)
"""
from .sparsity import (  # noqa: F401
    JunctionSpec,
    JunctionPattern,
    possible_densities,
    quantize_density,
    degrees_for_density,
    make_pattern,
    random_pattern,
    structured_pattern,
    clashfree_pattern,
    clashfree_schedule,
    schedule_is_clash_free,
    pattern_from_schedule,
    in_degrees,
    out_degrees,
    disconnected_left,
    disconnected_right,
    to_mask,
    transpose_pattern,
    count_access_patterns,
)
from .block_pattern import (  # noqa: F401
    BlockPattern, PartitionedPattern, can_partition, fit_block_pattern,
    make_block_pattern, merge_slab, partition_pattern, reassemble_outputs,
    split_slab,
)
from .quant import (  # noqa: F401
    QuantConfig, dequantize_slab, quantize_slab, quantize_spec,
    quantize_tree,
)
from .sparse_linear import (  # noqa: F401
    SparseLinear,
    SparseLinearSpec,
    gather_apply,
    masked_dense_apply,
    gather_weights_to_dense,
    block_weights_to_dense,
    dense_weights_to_gather,
)
from .storage import StorageBreakdown, storage_cost, junction_cycles, balanced_z  # noqa: F401
