"""Hardware storage-cost model (paper §III-A, Table I).

Counts words of on-accelerator storage for the junction-pipelined
architecture: activation queues, derivative queues, delta pairs, biases and
the single weight bank per junction. Reproduced exactly from Table I's
expressions; ``benchmarks/table1_storage.py`` evaluates them for the paper's
(800, 100, 10) example and for arbitrary configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class StorageBreakdown:
    a: int        # activation queues      sum_{i=0}^{L-1} (2(L-i)+1) N_i
    a_dot: int    # derivative queues      sum_{i=1}^{L-1} (2(L-i)+1) N_i
    delta: int    # delta pairs            2 sum_{i=1}^{L} N_i
    b: int        # biases                 sum_{i=1}^{L} N_i
    w: int        # weights                sum_{i=1}^{L} N_i d_in_i

    @property
    def total(self) -> int:
        return self.a + self.a_dot + self.delta + self.b + self.w


def storage_cost(n_net: Sequence[int],
                 d_in: Sequence[int] | None = None) -> StorageBreakdown:
    """Words of storage for neuronal config ``n_net`` and per-junction
    in-degrees ``d_in`` (defaults to fully connected)."""
    n = list(n_net)
    L = len(n) - 1
    if d_in is None:
        d_in = [n[i - 1] for i in range(1, L + 1)]
    d_in = list(d_in)
    if len(d_in) != L:
        raise ValueError("need one d_in per junction")
    a = sum((2 * (L - i) + 1) * n[i] for i in range(0, L))
    a_dot = sum((2 * (L - i) + 1) * n[i] for i in range(1, L))
    delta = 2 * sum(n[1:])
    b = sum(n[1:])
    w = sum(n[i] * d_in[i - 1] for i in range(1, L + 1))
    return StorageBreakdown(a=a, a_dot=a_dot, delta=delta, b=b, w=w)


def junction_cycles(n_edges: int, z: int, flush: int = 0) -> int:
    """C_i = |W_i| / z_i  (+ optional pipeline-flush cycles, footnote 2)."""
    if n_edges % z:
        raise ValueError(f"z={z} must divide |W|={n_edges}")
    return n_edges // z + flush


def balanced_z(edge_counts: Sequence[int], z_total_budget: int) -> list[int]:
    """Pick z_i proportional to |W_i| so all junction cycles match
    (§III-A: C_i = C for all i), subject to an overall logic budget."""
    total = sum(edge_counts)
    zs = [max(1, round(z_total_budget * e / total)) for e in edge_counts]
    return zs
