"""Pallas TPU kernels for the perf-critical hot spots.

* ``csd_spmm``        — the paper's contribution: clash-free structured
                        pre-defined sparse matmul (fwd / dx / dw).
* ``flash_attention`` — serving/prefill attention hot path.
* ``ops``             — differentiable jit'd wrappers with backend dispatch.
* ``ref``             — pure-jnp oracles (the correctness contract).
"""
from .ops import csd_matmul  # noqa: F401
from .flash_attention import flash_attention, paged_decode_attention  # noqa: F401
from . import ref  # noqa: F401
