"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the ground truth the kernels are swept against in
``tests/test_kernels.py``. No Pallas, no custom control flow — plain jnp.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# -- csd_spmm ---------------------------------------------------------------


def csd_spmm_fwd_ref(x: jax.Array, w: jax.Array,
                     block_idx: np.ndarray) -> jax.Array:
    """y[m, rb*bR] = sum_f x_blocks[block_idx[rb,f]] @ w[rb,f]."""
    n_rb, d_in_b, bl, br = w.shape
    m = x.shape[0]
    xb = x.reshape(m, -1, bl)
    g = jnp.take(xb, jnp.asarray(block_idx.reshape(-1)), axis=1)
    g = g.reshape(m, n_rb, d_in_b, bl)
    y = jnp.einsum("mrfl,rflo->mro", g.astype(jnp.float32),
                   w.astype(jnp.float32))
    return y.reshape(m, n_rb * br).astype(x.dtype)


def block_gather_ref(x: jax.Array, w: jax.Array, block_idx: np.ndarray,
                     bl: int, br: int) -> jax.Array:
    """Column-parallel block-sparse matmul oracle (materializing einsum).

    Formerly ``core.sparse_linear.block_gather_apply`` — demoted here when
    the layer stack unified on ``ops.csd_matmul``; kept as the gather-form
    ground truth for the equivalence tests.
    """
    n_rb, d_in_b = block_idx.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (-1, bl))  # (..., n_lb, bL)
    g = jnp.take(xb, jnp.asarray(block_idx.reshape(-1)), axis=-2)
    g = g.reshape(lead + (n_rb, d_in_b, bl))
    y = jnp.einsum("...rfl,rflo->...ro", g, w)
    return y.reshape(lead + (n_rb * br,))


def block_scatter_ref(x: jax.Array, w: jax.Array, out_idx: np.ndarray,
                      out_slot: np.ndarray, bl: int, br: int) -> jax.Array:
    """Row-parallel block-sparse matmul oracle (segment-sum form).

    Formerly ``core.sparse_linear.block_scatter_apply``; algebraically
    identical to ``block_gather_ref`` over the transposed adjacency.
    """
    n_lb, d_out_b = out_idx.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (n_lb, bl))
    # wt[lb, g] = w[out_idx[lb,g], out_slot[lb,g]]  (n_lb, d_out_b, bL, bR)
    wt = w[jnp.asarray(out_idx), jnp.asarray(out_slot)]
    p = jnp.einsum("...li,lgio->...lgo", xb, wt)
    seg = jnp.asarray(out_idx.reshape(-1))  # (n_lb*d_out_b,)
    n_rb = int(out_idx.max()) + 1
    pf = p.reshape(lead + (n_lb * d_out_b, br))
    y = jax.ops.segment_sum(
        jnp.moveaxis(pf, -2, 0), seg, num_segments=n_rb)
    y = jnp.moveaxis(y, 0, -2)
    return y.reshape(lead + (n_rb * br,))


def csd_spmm_fwd_batched_ref(x: jax.Array, w: jax.Array,
                             block_idx: np.ndarray) -> jax.Array:
    """Expert-batched forward oracle: x (E, M, n_in),
    w (E, n_rb, d_in_b, bL, bR), one pattern shared by all experts."""
    return jax.vmap(lambda xe, we: csd_spmm_fwd_ref(xe, we, block_idx))(x, w)


def csd_spmm_dx_batched_ref(dy: jax.Array, w: jax.Array, out_idx: np.ndarray,
                            out_slot: np.ndarray) -> jax.Array:
    return jax.vmap(
        lambda de, we: csd_spmm_dx_ref(de, we, out_idx, out_slot))(dy, w)


def csd_spmm_dw_batched_ref(x: jax.Array, dy: jax.Array,
                            block_idx: np.ndarray, block_in: int,
                            block_out: int) -> jax.Array:
    return jax.vmap(
        lambda xe, de: csd_spmm_dw_ref(xe, de, block_idx, block_in,
                                       block_out))(x, dy)


def moe_expert_ffn_ref(xe: jax.Array, up: jax.Array, gate: jax.Array,
                       down: jax.Array, act) -> jax.Array:
    """Dense stacked expert FFN oracle: xe (E, C, d), up/gate (E, d, d_e),
    down (E, d_e, d).

    Formerly ``nn.ffn.MoE._expert_ffn`` — demoted here when the expert
    junctions unified on the batched ``ops.csd_matmul`` path; kept as the
    ground truth for the MoE cross-mode equivalence tests.
    """
    cdt = xe.dtype
    h = jnp.einsum("ecd,edf->ecf", xe, up.astype(cdt))
    g = jnp.einsum("ecd,edf->ecf", xe, gate.astype(cdt))
    h = act(g) * h
    return jnp.einsum("ecf,efd->ecd", h, down.astype(cdt))


def csd_spmm_dx_ref(dy: jax.Array, w: jax.Array, out_idx: np.ndarray,
                    out_slot: np.ndarray) -> jax.Array:
    n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = out_idx.shape
    m = dy.shape[0]
    dyb = dy.reshape(m, n_rb, br)
    dyg = jnp.take(dyb, jnp.asarray(out_idx.reshape(-1)), axis=1)
    dyg = dyg.reshape(m, n_lb, d_out_b, br)
    wt = w[jnp.asarray(out_idx), jnp.asarray(out_slot)]  # (n_lb, d_out_b, bL, bR)
    dx = jnp.einsum("mlgo,lgio->mli", dyg.astype(jnp.float32),
                    wt.astype(jnp.float32))
    return dx.reshape(m, n_lb * bl).astype(dy.dtype)


def csd_spmm_dw_ref(x: jax.Array, dy: jax.Array, block_idx: np.ndarray,
                    block_in: int, block_out: int) -> jax.Array:
    n_rb, d_in_b = block_idx.shape
    m = x.shape[0]
    xb = x.reshape(m, -1, block_in)
    dyb = dy.reshape(m, n_rb, block_out)
    g = jnp.take(xb, jnp.asarray(block_idx.reshape(-1)), axis=1)
    g = g.reshape(m, n_rb, d_in_b, block_in)
    dw = jnp.einsum("mrfi,mro->rfio", g.astype(jnp.float32),
                    dyb.astype(jnp.float32))
    return dw.astype(x.dtype)


# -- flash attention --------------------------------------------------------


def mha_ref(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    *,
    causal: bool = True,
    window: int | None = None,      # sliding-window size (None = full)
    logit_softcap: float | None = None,
    scale: float | None = None,
    q_offset: int = 0,              # absolute position of q[0] (decode)
) -> jax.Array:
    """Reference GQA attention with optional sliding window and softcap."""
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    groups = hq // hkv
    scale = scale if scale is not None else dh ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, groups, axis=2)
    vf = jnp.repeat(vf, groups, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
