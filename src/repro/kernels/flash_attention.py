"""Flash attention (Pallas/TPU) — forward kernel for the serving hot path.

Supports the features the assigned architectures need: causal masking, GQA
(kv-head grouping via the index map), sliding-window attention (gemma2/3
local layers), logit soft-capping (gemma2), and a ``q_offset`` for decode
(query positions offset against an existing KV cache).

Online-softmax over KV blocks (the standard flash recurrence): running
row-max ``m``, normalizer ``l`` and f32 accumulator live in VMEM scratch
(TPU-shaped: trailing dim 128). Out-of-window KV blocks are masked; on real
hardware the compiler hoists fully-masked blocks' loads are still issued —
the XLA chunked implementation in ``repro.nn.attention`` (used for
GSPMD-partitioned training and the dry-run) skips them structurally instead.

Validated in interpret mode against ``ref.mha_ref`` over shape/dtype sweeps
(``tests/test_kernels.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  q_offset: int, kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0) \
        + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, :1]                        # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    # fully-masked rows: zero out (m_new stays -inf; exp(-inf - -inf)=nan)
    p = jnp.where(m_new > _NEG_INF / 2, p, 0.0)
    corr = jnp.where(m_prev > _NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = corr * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)

    v = v_ref[0, 0].astype(jnp.float32)          # (block_k, d)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention forward. Layout (B, S, H, Dh); returns like ``q``."""
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    groups = hq // hkv
    scale = dh ** -0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError("sequence lengths must divide block sizes")

    # (B, S, H, D) -> (B, H, S, D) for blocking over seq
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    grid = (b, hq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=logit_softcap, block_q=block_q, block_k=block_k,
        q_offset=q_offset, kv_blocks=skv // block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bb, h, qi, ki: (bb, h // groups, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bb, h, qi, ki: (bb, h // groups, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)


# ---------------------------------------------------------------------------
# Paged decode attention (serving): one query token over a paged KV cache
# ---------------------------------------------------------------------------


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                         scale: float, window: Optional[int],
                         softcap: Optional[float], page_size: int,
                         n_pages: int, quant: bool = False):
    """``quant`` selects int8 KV pages: two extra per-token scale refs
    ((1, page_size) tiles of the scale buffers, selected by the same
    page-table index map) dequantize K/V in register — the pages stream
    from HBM at 1 byte/element."""
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        (o_ref, acc_ref, m_ref, l_ref), ks_ref, vs_ref = rest, None, None
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale    # (G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (page, Dh)
    if quant:
        k = k * ks_ref[0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    length = len_ref[b]                            # valid keys: kpos < length
    kpos = p * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    mask = kpos < length
    if window is not None:
        mask &= kpos > (length - 1) - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    pexp = jnp.exp(s - m_new)
    pexp = jnp.where(m_new > _NEG_INF / 2, pexp, 0.0)
    corr = jnp.where(m_prev > _NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = corr * l_ref[:, :1] + jnp.sum(pexp, axis=1, keepdims=True)

    v = v_ref[0, :, 0].astype(jnp.float32)         # (page, Dh)
    if quant:
        v = v * vs_ref[0][:, None]
    pv = jax.lax.dot_general(pexp, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(p == n_pages - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def _paged_decode_pallas(q, k_pages, v_pages, page_table, lengths, *,
                         window, softcap, scale, interpret,
                         k_scale=None, v_scale=None):
    b, hkv, g, dh = q.shape
    page_size = k_pages.shape[1]
    n_pages = page_table.shape[1]
    quant = k_scale is not None
    # (P, page, Hkv, Dh) blocked as (1 page-row, page, 1 head, Dh); the
    # physical page id comes from the scalar-prefetched table — this is
    # the kernel-side form of the free-list indirection
    kv_spec = pl.BlockSpec(
        (1, page_size, 1, dh),
        lambda bb, h, p, pt, ln: (jnp.maximum(pt[bb, p], 0), 0, h, 0))
    in_specs = [
        pl.BlockSpec((1, 1, g, dh),
                     lambda bb, h, p, pt, ln: (bb, h, 0, 0)),
        kv_spec,
        kv_spec,
    ]
    operands = [page_table, lengths, q, k_pages, v_pages]
    if quant:
        # per-token scale tile of the (P+1, page) buffers, same page id
        sc_spec = pl.BlockSpec(
            (1, page_size),
            lambda bb, h, p, pt, ln: (jnp.maximum(pt[bb, p], 0), 0))
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bb, h, p, pt, ln: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, _LANES), jnp.float32),
            pltpu.VMEM((g, _LANES), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=window, softcap=softcap,
        page_size=page_size, n_pages=n_pages, quant=quant)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
    )(*operands)


def _paged_decode_xla(q, k_pages, v_pages, page_table, lengths, *,
                      window, softcap, scale, k_scale=None, v_scale=None):
    """Gather-based fallback: materialize each sequence's logical KV view
    from its page table, then run the standard masked decode einsum.
    With ``k_scale``/``v_scale`` (int8 pages) the gathered view is
    dequantized per token before the einsum."""
    b, hkv, g, dh = q.shape
    page_size = k_pages.shape[1]
    idx = jnp.clip(page_table, 0, k_pages.shape[0] - 1)
    k = k_pages[idx].reshape(b, -1, hkv, dh)     # (B, S, Hkv, Dh)
    v = v_pages[idx].reshape(b, -1, hkv, dh)
    if k_scale is not None:
        ks = k_scale[idx].reshape(b, -1)
        vs = v_scale[idx].reshape(b, -1)
        k = k.astype(jnp.float32) * ks[:, :, None, None]
        v = v.astype(jnp.float32) * vs[:, :, None, None]
    logits = jnp.einsum("bhgd,bkhd->bhgk",
                        q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None] < lengths[:, None]         # (B, S)
    if window is not None:
        mask &= kpos[None] > (lengths[:, None] - 1) - window
    logits = jnp.where(mask[:, None, None], logits, _NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(m > _NEG_INF / 2, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,           # (B, Hkv, G, Dh) — one grouped query token
    k_pages: jax.Array,     # (P, page_size, Hkv, Dh) physical page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, n_pages) int32, -1 = unmapped
    lengths: jax.Array,     # (B,) int32 — valid keys per row (kpos < len)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    backend: str = "auto",
    interpret: bool = False,
    k_scale: Optional[jax.Array] = None,  # (P, page_size) f32
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token attention over a paged KV cache; returns like ``q``.

    ``backend="auto"`` follows the repo convention: the Pallas kernel on
    TPU (page table scalar-prefetched, one page per grid step, online
    softmax across pages), the gather-based XLA lowering elsewhere.
    Unmapped table entries are safe: their logical positions are >= the
    sequence length, so they are masked before the softmax.

    ``k_scale``/``v_scale`` select int8 KV pages (per-token scales from
    ``serving.kv_cache.write_kv_quant``): pages stream at 1 byte/element
    and are dequantized in register / post-gather.
    """
    from .ops import _resolve
    scale = q.shape[-1] ** -0.5 if scale is None else scale
    lengths = lengths.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be given together")
    be = None
    if backend == "auto":
        # measured-auto (PR 10): trace-time consult of the tune cache for
        # this decode regime; a miss keeps the static heuristic. This is
        # the engine's decode-kernel selection — EngineConfig.backend
        # flows here through model.paged_step.
        from .. import tune
        ent = tune.decide_decode(
            b=q.shape[0], h_kv=q.shape[1], groups=q.shape[2],
            head_dim=q.shape[3], page_size=k_pages.shape[1],
            n_pages=page_table.shape[1], pool=k_pages.shape[0],
            quant=k_scale is not None, dtype=str(q.dtype))
        if ent is not None:
            be = str(ent["backend"])
    if be is None:
        be = _resolve(backend)
    if be == "pallas":
        return _paged_decode_pallas(
            q, k_pages, v_pages, page_table, lengths, window=window,
            softcap=softcap, scale=scale, interpret=interpret,
            k_scale=k_scale, v_scale=v_scale)
    return _paged_decode_xla(
        q, k_pages, v_pages, page_table, lengths, window=window,
        softcap=softcap, scale=scale, k_scale=k_scale, v_scale=v_scale)
