"""CSD-SpMM — Clash-free Structured pre-Defined Sparse Matrix Multiply.

Pallas/TPU kernels for the block-circulant pre-defined sparse junction
(DESIGN.md §2). This is the compute hot-spot the paper accelerates: eq. (2a)
forward, eq. (3b) backward-data, eq. (4b) backward-weights — lifted from
per-edge FPGA processing to per-tile MXU processing.

Mapping of the paper's architecture onto the TPU grid:

* the ``z`` parallel edge processors  -> one (block_m x bR) output tile per
  grid step; every MXU issue covers bL*bR "edges";
* the ``z`` banked activation SRAMs   -> VMEM tiles of ``x`` selected by the
  *scalar-prefetched* pattern ``block_idx`` (the interleaved-order access of
  Fig. 2(b): the index map plays the role of the address generator built
  from the seed vector ``phi``);
* clash-freedom                       -> each grid step streams exactly one
  left block from HBM; a left block is never double-streamed within a step,
  and consecutive ``f`` steps revisit the same *output* tile so the partial
  sum stays resident in VMEM (the "natural order" write of Fig. 2(b));
* the sigmoid/ReLU unit next to the edge processors -> the fused epilogue:
  bias-add + activation are applied on the last fan-in slot while the
  accumulator tile is still in VMEM, so the pre-activation never
  round-trips HBM (see ``csd_spmm_fwd(bias=..., activation=...)``).

Weight layout: ``w[n_rb, d_in_b, bL, bR]`` — right-block major, exactly the
paper's edge numbering (§III-B: "edges are numbered sequentially ... on the
right side of the junction").

Batched (expert-major) junctions: every kernel also accepts a stacked
weight slab ``w[E, n_rb, d_in_b, bL, bR]`` with activations
``x[E, M, n_in]`` — the layout of MoE expert FFNs, where ``E`` experts
share one junction *pattern* but own private weights. The expert index
becomes the *leading* (outermost, slowest-varying) grid dimension, so one
``BlockPattern`` is scalar-prefetched once and serves every expert — the
paper's "not tied to a specific number of neurons" architecture replicated
per expert with zero extra pattern memory. Inner grid order (row tile,
right block, fan-in slot) is unchanged, so the per-expert schedule, VMEM
residency, and clash-freedom argument are identical to the unbatched case.

All kernels are validated against ``ref.py`` in interpret mode (CPU) by
``tests/test_kernels.py``; on real TPUs the same code path compiles to
Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Forward: y[m, rb] = sum_f x[m, block_idx[rb, f]] @ w[rb, f]
#
# Fused epilogue: on the LAST fan-in slot of each output tile the partial
# sum is still resident in VMEM, so bias-add and the activation are applied
# there — the pre-activation never round-trips HBM. This mirrors the FPGA
# architecture (Dey et al. §III): the sigmoid/ReLU unit sits next to the
# edge processors, directly on the accumulated activation memory.
# ---------------------------------------------------------------------------

# activations the fused epilogue supports. "gelu" is the tanh approximation
# — the same function the model stack's activation registry binds to the
# name (jax.nn.gelu default), keeping fused and unfused paths bit-comparable.
ACTIVATIONS = ("relu", "gelu")


def apply_activation(z: jax.Array, activation: Optional[str]) -> jax.Array:
    """The one definition of every fusable activation — used inside the
    kernel epilogue, by the XLA fallback, and by layers applying the same
    nonlinearity out-of-kernel, so the variants can never drift."""
    if activation is None:
        return z
    if activation == "relu":
        return jnp.maximum(z, 0)
    if activation == "gelu":
        return jax.nn.gelu(z, approximate=True)
    raise ValueError(f"unsupported fused activation {activation!r}")


def mask_cotangent(dy: jax.Array, aux: jax.Array,
                   activation: Optional[str]) -> jax.Array:
    """Fused-epilogue backward: fold the activation derivative into the
    cotangent. ``aux`` is the saved output ``y`` for relu (its sign IS the
    mask) and the saved pre-activation ``z`` for gelu. Pure jnp, so the
    same definition runs inside the Pallas BP/UP kernel bodies (the fused
    backward epilogue — the cotangent never round-trips HBM unmasked) and
    on host-side tiles in tests."""
    if activation is None:
        return dy
    if activation == "relu":
        return dy * (aux > 0).astype(dy.dtype)
    if activation == "gelu":
        # analytic derivative of the tanh approximation — matches what
        # jax.vjp derives for jax.nn.gelu(approximate=True) to rounding
        z = aux.astype(jnp.float32)
        c = np.float32(np.sqrt(2.0 / np.pi))
        a = np.float32(0.044715)
        t = jnp.tanh(c * (z + a * z * z * z))
        g = 0.5 * (1.0 + t) \
            + 0.5 * z * (1.0 - t * t) * c * (1.0 + 3.0 * a * z * z)
        return (dy.astype(jnp.float32) * g).astype(dy.dtype)
    raise ValueError(f"unsupported fused activation {activation!r}")


def _fwd_kernel(idx_ref, *refs, d_in_b: int, activation: Optional[str],
                has_bias: bool, save_preact: bool):
    """refs: x, w, [bias], y, [preact] (inputs then outputs)."""
    if has_bias:
        x_ref, w_ref, b_ref = refs[:3]
        out_refs = refs[3:]
    else:
        x_ref, w_ref = refs[:2]
        b_ref = None
        out_refs = refs[2:]
    y_ref = out_refs[0]
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]  # (block_m, bL)
    w = w_ref[0, 0]  # (bL, bR)
    y_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=y_ref.dtype)

    if has_bias or activation is not None or save_preact:
        @pl.when(f == d_in_b - 1)
        def _epilogue():
            z = y_ref[...]
            if has_bias:
                z = z + b_ref[...].astype(z.dtype)  # (1, bR) broadcasts
            if save_preact:
                out_refs[1][...] = z
            y_ref[...] = apply_activation(z, activation)


def _fwd_kernel_batched(idx_ref, *refs, d_in_b: int,
                        activation: Optional[str], has_bias: bool,
                        save_preact: bool):
    """Expert-major forward: same schedule as ``_fwd_kernel`` shifted one
    grid dim right; refs carry a leading expert-singleton block dim."""
    if has_bias:
        x_ref, w_ref, b_ref = refs[:3]
        out_refs = refs[3:]
    else:
        x_ref, w_ref = refs[:2]
        b_ref = None
        out_refs = refs[2:]
    y_ref = out_refs[0]
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[0]  # (block_m, bL)
    w = w_ref[0, 0, 0]  # (bL, bR)
    y_ref[0] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=y_ref.dtype)

    if has_bias or activation is not None or save_preact:
        @pl.when(f == d_in_b - 1)
        def _epilogue():
            z = y_ref[0]
            if has_bias:
                z = z + b_ref[0].astype(z.dtype)  # (1, bR) broadcasts
            if save_preact:
                out_refs[1][0] = z
            y_ref[0] = apply_activation(z, activation)


# ---------------------------------------------------------------------------
# Quantized forward (inference only): the slab enters the kernel as int8 and
# is widened *in register* right before the MXU issue; the per-block f32
# scale rides the scalar-prefetch channel (SMEM, next to the pattern — the
# FPGA analogy: the fixed-point weight memory plus a tiny per-block exponent
# ROM). The f32 accumulator is scaled per fan-in slot, so bias/activation in
# the last-slot epilogue see fully dequantized values. HBM traffic for the
# weights is 1 byte/element — certified by sparselint SL206: no
# convert_element_type of the *whole* slab may appear outside the kernel.
# ---------------------------------------------------------------------------


def _fwd_kernel_quant(idx_ref, scale_ref, *refs, d_in_b: int,
                      activation: Optional[str], has_bias: bool):
    """refs: x, w(int8), [bias], y. Same schedule as ``_fwd_kernel``."""
    if has_bias:
        x_ref, w_ref, b_ref, y_ref = refs
    else:
        (x_ref, w_ref, y_ref), b_ref = refs, None
    r = pl.program_id(1)
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]  # (block_m, bL)
    w = w_ref[0, 0].astype(x.dtype)  # int8 -> compute dtype, in register
    s = scale_ref[r, f]  # per-block f32 scale from SMEM
    y_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=y_ref.dtype) * s

    if has_bias or activation is not None:
        @pl.when(f == d_in_b - 1)
        def _epilogue():
            z = y_ref[...]
            if has_bias:
                z = z + b_ref[...].astype(z.dtype)
            y_ref[...] = apply_activation(z, activation)


def _fwd_kernel_quant_batched(idx_ref, scale_ref, *refs, d_in_b: int,
                              activation: Optional[str], has_bias: bool):
    """Expert-major quantized forward; scales are (E, n_rb, d_in_b)."""
    if has_bias:
        x_ref, w_ref, b_ref, y_ref = refs
    else:
        (x_ref, w_ref, y_ref), b_ref = refs, None
    e = pl.program_id(0)
    r = pl.program_id(2)
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[0]  # (block_m, bL)
    w = w_ref[0, 0, 0].astype(x.dtype)  # (bL, bR) int8 -> compute dtype
    s = scale_ref[e, r, f]
    y_ref[0] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=y_ref.dtype) * s

    if has_bias or activation is not None:
        @pl.when(f == d_in_b - 1)
        def _epilogue():
            z = y_ref[0]
            if has_bias:
                z = z + b_ref[0].astype(z.dtype)
            y_ref[0] = apply_activation(z, activation)


def _csd_spmm_fwd_quant(x, w, w_scale, block_idx, *, bias, activation,
                        block_m, interpret):
    """Unbatched quantized forward: w int8 (n_rb, d_in_b, bL, bR) with
    scales (n_rb, d_in_b) f32; grid identical to the full-width path."""
    m, n_in = x.shape
    n_rb, d_in_b, bl, br = w.shape
    if n_in % bl:
        raise ValueError("n_in not divisible by block_in")
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")

    has_bias = bias is not None
    grid = (m // block_m, n_rb, d_in_b)
    kernel = functools.partial(_fwd_kernel_quant, d_in_b=d_in_b,
                               activation=activation, has_bias=has_bias)
    in_specs = [
        pl.BlockSpec((block_m, bl),
                     lambda i, r, f, idx, sc: (i, idx[r, f])),
        pl.BlockSpec((1, 1, bl, br),
                     lambda i, r, f, idx, sc: (r, f, 0, 0)),
    ]
    operands = [jnp.asarray(block_idx, jnp.int32),
                jnp.asarray(w_scale, jnp.float32), x, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, br),
                                     lambda i, r, f, idx, sc: (r, 0)))
        operands.append(bias.reshape(n_rb, br))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((block_m, br),
                                   lambda i, r, f, idx, sc: (i, r)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n_rb * br), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out.astype(x.dtype)


def _csd_spmm_fwd_quant_batched(x, w, w_scale, block_idx, *, bias,
                                activation, block_m, interpret):
    """Expert-batched quantized forward: w int8 (E, n_rb, d_in_b, bL, bR)
    with scales (E, n_rb, d_in_b) f32."""
    e, m, n_in = x.shape
    _, n_rb, d_in_b, bl, br = w.shape
    if n_in % bl:
        raise ValueError("n_in not divisible by block_in")
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")

    has_bias = bias is not None
    grid = (e, m // block_m, n_rb, d_in_b)
    kernel = functools.partial(_fwd_kernel_quant_batched, d_in_b=d_in_b,
                               activation=activation, has_bias=has_bias)
    in_specs = [
        pl.BlockSpec((1, block_m, bl),
                     lambda e, i, r, f, idx, sc: (e, i, idx[r, f])),
        pl.BlockSpec((1, 1, 1, bl, br),
                     lambda e, i, r, f, idx, sc: (e, r, f, 0, 0)),
    ]
    operands = [jnp.asarray(block_idx, jnp.int32),
                jnp.asarray(w_scale, jnp.float32), x, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, 1, br),
                                     lambda e, i, r, f, idx, sc: (e, r, 0)))
        operands.append(bias.reshape(e, n_rb, br))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, block_m, br),
                                   lambda e, i, r, f, idx, sc: (e, i, r)),
        ),
        out_shape=jax.ShapeDtypeStruct((e, m, n_rb * br), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out.astype(x.dtype)


def _csd_spmm_fwd_batched(x, w, block_idx, *, bias, activation, save_preact,
                          block_m, interpret):
    """Expert-batched forward: x (E, M, n_in), w (E, n_rb, d_in_b, bL, bR),
    one shared pattern prefetched once; grid (E, M/bm, n_rb, d_in_b)."""
    e, m, n_in = x.shape
    _, n_rb, d_in_b, bl, br = w.shape
    if n_in % bl:
        raise ValueError("n_in not divisible by block_in")
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")
    acc_dtype = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float32) else x.dtype

    has_bias = bias is not None
    grid = (e, m // block_m, n_rb, d_in_b)
    kernel = functools.partial(_fwd_kernel_batched, d_in_b=d_in_b,
                               activation=activation, has_bias=has_bias,
                               save_preact=save_preact)
    in_specs = [
        pl.BlockSpec((1, block_m, bl),
                     lambda e, i, r, f, idx: (e, i, idx[r, f])),
        pl.BlockSpec((1, 1, 1, bl, br),
                     lambda e, i, r, f, idx: (e, r, f, 0, 0)),
    ]
    operands = [jnp.asarray(block_idx, jnp.int32), x, w]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, 1, br),
                                     lambda e, i, r, f, idx: (e, r, 0)))
        operands.append(bias.reshape(e, n_rb, br))
    out_spec = pl.BlockSpec((1, block_m, br),
                            lambda e, i, r, f, idx: (e, i, r))
    out_shape = jax.ShapeDtypeStruct((e, m, n_rb * br), acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=(out_spec, out_spec) if save_preact else out_spec,
        ),
        out_shape=(out_shape, out_shape) if save_preact else out_shape,
        interpret=interpret,
    )(*operands)
    if save_preact:
        y, z = out
        return y.astype(x.dtype), z.astype(x.dtype)
    return out.astype(x.dtype)


def csd_spmm_fwd(
    x: jax.Array,
    w: jax.Array,
    block_idx: np.ndarray,
    *,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    save_preact: bool = False,
    block_m: int = 128,
    interpret: bool = False,
    w_scale: Optional[jax.Array] = None,
):
    """Forward block-sparse matmul with optional fused bias/activation.

    x: (M, n_in) with n_in = n_lb*bL; w: (n_rb, d_in_b, bL, bR);
    block_idx: (n_rb, d_in_b) int32; bias: (n_rb*bR,) or None ->
    y: (M, n_rb*bR) = activation(x @ W_sparse + bias).

    Batched (expert-major) form: w (E, n_rb, d_in_b, bL, bR) with
    x (E, M, n_in) and bias (E, n_rb*bR) -> y (E, M, n_rb*bR); the expert
    index is the leading grid dimension and the pattern is shared.

    ``save_preact=True`` additionally returns the pre-activation
    ``z = x @ W_sparse + bias`` (needed by the backward pass of non-masking
    activations like gelu); the return value is then ``(y, z)``.

    ``w_scale`` selects the int8-quantized forward (inference only, no
    VJP): ``w`` must be int8 with per-block scales ``(n_rb, d_in_b)``
    (resp. ``(E, n_rb, d_in_b)``) from ``core.quant.quantize_slab``;
    dequantization is folded into the accumulate before the epilogue.
    """
    if activation is not None and activation not in ACTIVATIONS:
        raise ValueError(f"unsupported fused activation {activation!r}")
    if w_scale is not None:
        if save_preact:
            raise ValueError(
                "save_preact is unsupported on the quantized path "
                "(inference-only; training stays full-width)")
        if w.dtype != jnp.int8:
            raise ValueError(f"w_scale given but w.dtype={w.dtype}, "
                             f"expected int8")
        if w.ndim == 5:
            return _csd_spmm_fwd_quant_batched(
                x, w, w_scale, block_idx, bias=bias, activation=activation,
                block_m=block_m, interpret=interpret)
        return _csd_spmm_fwd_quant(
            x, w, w_scale, block_idx, bias=bias, activation=activation,
            block_m=block_m, interpret=interpret)
    if w.ndim == 5:
        return _csd_spmm_fwd_batched(
            x, w, block_idx, bias=bias, activation=activation,
            save_preact=save_preact, block_m=block_m, interpret=interpret)
    m, n_in = x.shape
    n_rb, d_in_b, bl, br = w.shape
    if n_in % bl:
        raise ValueError("n_in not divisible by block_in")
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")
    acc_dtype = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float32) else x.dtype

    has_bias = bias is not None
    grid = (m // block_m, n_rb, d_in_b)
    kernel = functools.partial(_fwd_kernel, d_in_b=d_in_b,
                               activation=activation, has_bias=has_bias,
                               save_preact=save_preact)
    in_specs = [
        # x tile: row-block i, left-block chosen by the pattern.
        pl.BlockSpec((block_m, bl),
                     lambda i, r, f, idx: (i, idx[r, f])),
        # w tile: one (bL, bR) block per (r, f).
        pl.BlockSpec((1, 1, bl, br),
                     lambda i, r, f, idx: (r, f, 0, 0)),
    ]
    operands = [jnp.asarray(block_idx, jnp.int32), x, w]
    if has_bias:
        # bias as (n_rb, bR): one right-block slice per output tile.
        in_specs.append(pl.BlockSpec((1, br),
                                     lambda i, r, f, idx: (r, 0)))
        operands.append(bias.reshape(n_rb, br))
    out_spec = pl.BlockSpec((block_m, br), lambda i, r, f, idx: (i, r))
    out_shape = jax.ShapeDtypeStruct((m, n_rb * br), acc_dtype)
    if save_preact:
        out_specs = (out_spec, out_spec)
        out_shapes = (out_shape, out_shape)
    else:
        out_specs = out_spec
        out_shapes = out_shape
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(*operands)
    if save_preact:
        y, z = out
        return y.astype(x.dtype), z.astype(x.dtype)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Backward-data: dx[m, lb] = sum_g dy[m, out_idx[lb, g]] @ w[out_idx, out_slot].T
# (eq. (3b): the transpose pattern is itself structured — degrees swap)
#
# Fused backward epilogue: when ``activation`` is given, the cotangent is
# masked (``mask_cotangent``) tile-by-tile INSIDE the kernel from the saved
# ``aux`` (y for relu, pre-activation for gelu) — the unmasked dy is read
# straight from HBM and never materialized masked.
#
# ``out_valid`` (same shape as out_idx, 0/1) marks padded scatter entries:
# shard-local transpose patterns have non-uniform out-degree and are padded
# to a fixed d_loc; padded entries contribute zero.
# ---------------------------------------------------------------------------


def _dx_kernel(*refs, batched: bool, has_valid: bool,
               activation: Optional[str]):
    ns = 3 if has_valid else 2
    scalar_refs, rest = refs[:ns], refs[ns:]
    ovalid_ref = scalar_refs[2] if has_valid else None
    if activation is not None:
        dy_ref, aux_ref, w_ref, dx_ref = rest
    else:
        (dy_ref, w_ref, dx_ref), aux_ref = rest, None
    base = 1 if batched else 0
    l = pl.program_id(base + 1)
    g = pl.program_id(base + 2)

    @pl.when(g == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    def tile(ref):
        return ref[0] if batched else ref[...]

    dy = tile(dy_ref)  # (block_m, bR)
    if activation is not None:
        dy = mask_cotangent(dy, tile(aux_ref), activation)
    w = w_ref[0, 0, 0] if batched else w_ref[0, 0]  # (bL, bR)
    contrib = jax.lax.dot_general(
        dy, w, (((1,), (1,)), ((), ())),
        preferred_element_type=dx_ref.dtype)
    if has_valid:
        contrib = contrib * ovalid_ref[l, g].astype(contrib.dtype)
    if batched:
        dx_ref[0] += contrib
    else:
        dx_ref[...] += contrib


def csd_spmm_dx(
    dy: jax.Array,
    w: jax.Array,
    out_idx,
    out_slot,
    *,
    out_valid=None,
    aux: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dx: (M, n_in). dy: (M, n_rb*bR); the scatter pattern arrays come from
    ``BlockPattern.out_idx/out_slot`` (reverse adjacency) and may be traced
    jnp arrays (the sharded path selects them per-device). Batched form:
    dy (E, M, n_rb*bR), w (E, n_rb, d_in_b, bL, bR) -> dx (E, M, n_in).

    ``aux``/``activation`` select the fused backward epilogue (cotangent
    masked in-kernel); ``out_valid`` zeroes padded scatter entries."""
    batched = w.ndim == 5
    if batched:
        e, m, _ = dy.shape
        _, n_rb, d_in_b, bl, br = w.shape
    else:
        m, _ = dy.shape
        n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = out_idx.shape
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")
    acc_dtype = jnp.float32 if dy.dtype in (jnp.bfloat16, jnp.float32) else dy.dtype

    has_valid = out_valid is not None
    ns = 3 if has_valid else 2

    def imap(fn):
        # index maps receive (grid..., *scalar_refs); ``*s`` absorbs the
        # optional ovalid ref so one lambda serves both arities
        if batched:
            return (lambda e_, i, l, g, oidx, oslot, *s: fn(
                (e_,), i, l, g, oidx, oslot))
        return (lambda i, l, g, oidx, oslot, *s: fn(
            (), i, l, g, oidx, oslot))

    dy_map = imap(lambda e_, i, l, g, oidx, oslot: e_ + (i, oidx[l, g]))
    w_map = imap(lambda e_, i, l, g, oidx, oslot:
                 e_ + (oidx[l, g], oslot[l, g], 0, 0))
    dx_map = imap(lambda e_, i, l, g, oidx, oslot: e_ + (i, l))

    one = (1,) if batched else ()
    dy_spec = pl.BlockSpec(one + (block_m, br), dy_map)
    in_specs = [dy_spec]
    operands = [jnp.asarray(out_idx, jnp.int32),
                jnp.asarray(out_slot, jnp.int32)]
    if has_valid:
        operands.append(jnp.asarray(out_valid, jnp.int32))
    operands.append(dy)
    if activation is not None:
        if aux is None:
            raise ValueError("fused backward epilogue needs aux")
        in_specs.append(dy_spec)
        operands.append(aux)
    in_specs.append(pl.BlockSpec(one + (1, 1, bl, br), w_map))
    operands.append(w)

    grid = ((e,) if batched else ()) + (m // block_m, n_lb, d_out_b)
    out_shape = jax.ShapeDtypeStruct(
        ((e,) if batched else ()) + (m, n_lb * bl), acc_dtype)
    kernel = functools.partial(_dx_kernel, batched=batched,
                               has_valid=has_valid, activation=activation)
    dx = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=ns,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec(one + (block_m, bl), dx_map),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return dx.astype(dy.dtype)


# ---------------------------------------------------------------------------
# Backward-weights: dw[rb, f] = x[:, block_idx[rb, f]].T @ dy[:, rb]
# (eq. (4b) per tile, accumulated over the batch)
#
# Fused backward epilogue as in the dx kernel; with ``want_db`` the bias
# cotangent db[rb] = sum_m masked_dy[m, rb] rides along as a second output
# (accumulated on the first fan-in slot only, so each dy tile is counted
# once).
# ---------------------------------------------------------------------------


def _dw_kernel(*refs, batched: bool, activation: Optional[str],
               want_db: bool):
    if activation is not None:
        idx_ref, x_ref, dy_ref, aux_ref = refs[:4]
        out_refs = refs[4:]
    else:
        idx_ref, x_ref, dy_ref = refs[:3]
        aux_ref = None
        out_refs = refs[3:]
    dw_ref = out_refs[0]
    db_ref = out_refs[1] if want_db else None
    base = 1 if batched else 0
    f = pl.program_id(base + 1)
    i = pl.program_id(base + 2)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    def tile(ref):
        return ref[0] if batched else ref[...]

    x = tile(x_ref)    # (block_m, bL)
    dy = tile(dy_ref)  # (block_m, bR)
    if activation is not None:
        dy = mask_cotangent(dy, tile(aux_ref), activation)
    acc = jax.lax.dot_general(
        x, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=dw_ref.dtype)
    if batched:
        dw_ref[0, 0, 0] += acc
    else:
        dw_ref[0, 0] += acc

    if want_db:
        @pl.when((f == 0) & (i == 0))
        def _init_db():
            db_ref[...] = jnp.zeros_like(db_ref)

        @pl.when(f == 0)
        def _acc_db():
            db_ref[...] += jnp.sum(
                dy.astype(db_ref.dtype), axis=0, keepdims=True
            ).reshape(db_ref.shape)


def csd_spmm_dw(
    x: jax.Array,
    dy: jax.Array,
    block_idx,
    *,
    block_in: int,
    block_out: int,
    aux: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    want_db: bool = False,
    block_m: int = 128,
    interpret: bool = False,
):
    """dw: (n_rb, d_in_b, bL, bR), batch-accumulated (innermost grid dim).
    Batched (expert-major) form: x (E, M, n_in), dy (E, M, n_out) ->
    dw (E, n_rb, d_in_b, bL, bR); per-expert accumulation over M only —
    any 3-D input IS interpreted as expert-batched (fwd/dx dispatch on the
    unambiguous w.ndim; dw has no w, so the rank of x decides).

    ``aux``/``activation`` select the fused backward epilogue; with
    ``want_db`` returns ``(dw, db)`` where db (f32, (n_out,) or (E,
    n_out)) is the masked bias cotangent."""
    if x.ndim != dy.ndim or x.ndim not in (2, 3):
        raise ValueError(
            f"x/dy must both be 2-D (unbatched) or 3-D (expert-batched), "
            f"got {x.shape} / {dy.shape}")
    batched = x.ndim == 3
    if batched:
        e, m, n_in = x.shape
    else:
        m, n_in = x.shape
    n_rb, d_in_b = block_idx.shape
    bl, br = block_in, block_out
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")

    one = (1,) if batched else ()

    def imap(fn):
        if batched:
            return lambda e_, r, f, i, idx: fn((e_,), r, f, i, idx)
        return lambda r, f, i, idx: fn((), r, f, i, idx)

    x_map = imap(lambda e_, r, f, i, idx: e_ + (i, idx[r, f]))
    dy_map = imap(lambda e_, r, f, i, idx: e_ + (i, r))
    dw_map = imap(lambda e_, r, f, i, idx: e_ + (r, f, 0, 0))
    db_map = imap(lambda e_, r, f, i, idx: e_ + (r, 0))

    in_specs = [pl.BlockSpec(one + (block_m, bl), x_map),
                pl.BlockSpec(one + (block_m, br), dy_map)]
    operands = [jnp.asarray(block_idx, jnp.int32), x, dy]
    if activation is not None:
        if aux is None:
            raise ValueError("fused backward epilogue needs aux")
        in_specs.append(pl.BlockSpec(one + (block_m, br), dy_map))
        operands.append(aux)

    grid = ((e,) if batched else ()) + (n_rb, d_in_b, m // block_m)
    dw_spec = pl.BlockSpec(one + (1, 1, bl, br), dw_map)
    dw_shape = jax.ShapeDtypeStruct(
        ((e,) if batched else ()) + (n_rb, d_in_b, bl, br), jnp.float32)
    if want_db:
        out_specs = (dw_spec, pl.BlockSpec(one + (1, br), db_map))
        out_shapes = (dw_shape, jax.ShapeDtypeStruct(
            ((e,) if batched else ()) + (n_rb, br), jnp.float32))
    else:
        out_specs = dw_spec
        out_shapes = dw_shape
    kernel = functools.partial(_dw_kernel, batched=batched,
                               activation=activation, want_db=want_db)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(*operands)
    if want_db:
        dw, db = out
        return dw.astype(x.dtype), db.reshape(
            ((e,) if batched else ()) + (n_rb * br,))
    return out.astype(x.dtype)
