"""CSD-SpMM — Clash-free Structured pre-Defined Sparse Matrix Multiply.

Pallas/TPU kernels for the block-circulant pre-defined sparse junction
(DESIGN.md §2). This is the compute hot-spot the paper accelerates: eq. (2a)
forward, eq. (3b) backward-data, eq. (4b) backward-weights — lifted from
per-edge FPGA processing to per-tile MXU processing.

Mapping of the paper's architecture onto the TPU grid:

* the ``z`` parallel edge processors  -> one (block_m x bR) output tile per
  grid step; every MXU issue covers bL*bR "edges";
* the ``z`` banked activation SRAMs   -> VMEM tiles of ``x`` selected by the
  *scalar-prefetched* pattern ``block_idx`` (the interleaved-order access of
  Fig. 2(b): the index map plays the role of the address generator built
  from the seed vector ``phi``);
* clash-freedom                       -> each grid step streams exactly one
  left block from HBM; a left block is never double-streamed within a step,
  and consecutive ``f`` steps revisit the same *output* tile so the partial
  sum stays resident in VMEM (the "natural order" write of Fig. 2(b)).

Weight layout: ``w[n_rb, d_in_b, bL, bR]`` — right-block major, exactly the
paper's edge numbering (§III-B: "edges are numbered sequentially ... on the
right side of the junction").

All kernels are validated against ``ref.py`` in interpret mode (CPU) by
``tests/test_kernels.py``; on real TPUs the same code path compiles to
Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Forward: y[m, rb] = sum_f x[m, block_idx[rb, f]] @ w[rb, f]
# ---------------------------------------------------------------------------


def _fwd_kernel(idx_ref, x_ref, w_ref, y_ref, *, d_in_b: int):
    f = pl.program_id(2)

    @pl.when(f == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]  # (block_m, bL)
    w = w_ref[0, 0]  # (bL, bR)
    y_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=y_ref.dtype)


def csd_spmm_fwd(
    x: jax.Array,
    w: jax.Array,
    block_idx: np.ndarray,
    *,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Forward block-sparse matmul.

    x: (M, n_in) with n_in = n_lb*bL; w: (n_rb, d_in_b, bL, bR);
    block_idx: (n_rb, d_in_b) int32 -> y: (M, n_rb*bR).
    """
    m, n_in = x.shape
    n_rb, d_in_b, bl, br = w.shape
    if n_in % bl:
        raise ValueError("n_in not divisible by block_in")
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")
    acc_dtype = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float32) else x.dtype

    grid = (m // block_m, n_rb, d_in_b)
    kernel = functools.partial(_fwd_kernel, d_in_b=d_in_b)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # x tile: row-block i, left-block chosen by the pattern.
                pl.BlockSpec((block_m, bl),
                             lambda i, r, f, idx: (i, idx[r, f])),
                # w tile: one (bL, bR) block per (r, f).
                pl.BlockSpec((1, 1, bl, br),
                             lambda i, r, f, idx: (r, f, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, br),
                                   lambda i, r, f, idx: (i, r)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n_rb * br), acc_dtype),
        interpret=interpret,
    )(jnp.asarray(block_idx, jnp.int32), x, w)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Backward-data: dx[m, lb] = sum_g dy[m, out_idx[lb, g]] @ w[out_idx, out_slot].T
# (eq. (3b): the transpose pattern is itself structured — degrees swap)
# ---------------------------------------------------------------------------


def _dx_kernel(oidx_ref, oslot_ref, dy_ref, w_ref, dx_ref):
    g = pl.program_id(2)

    @pl.when(g == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    dy = dy_ref[...]  # (block_m, bR)
    w = w_ref[0, 0]  # (bL, bR)
    dx_ref[...] += jax.lax.dot_general(
        dy, w, (((1,), (1,)), ((), ())),
        preferred_element_type=dx_ref.dtype)


def csd_spmm_dx(
    dy: jax.Array,
    w: jax.Array,
    out_idx: np.ndarray,
    out_slot: np.ndarray,
    *,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dx: (M, n_in). dy: (M, n_rb*bR); the scatter pattern arrays come from
    ``BlockPattern.out_idx/out_slot`` (reverse adjacency)."""
    m, _ = dy.shape
    n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = out_idx.shape
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")
    acc_dtype = jnp.float32 if dy.dtype in (jnp.bfloat16, jnp.float32) else dy.dtype

    grid = (m // block_m, n_lb, d_out_b)
    dx = pl.pallas_call(
        _dx_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, br),
                             lambda i, l, g, oidx, oslot: (i, oidx[l, g])),
                pl.BlockSpec((1, 1, bl, br),
                             lambda i, l, g, oidx, oslot:
                             (oidx[l, g], oslot[l, g], 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, bl),
                                   lambda i, l, g, oidx, oslot: (i, l)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n_lb * bl), acc_dtype),
        interpret=interpret,
    )(jnp.asarray(out_idx, jnp.int32), jnp.asarray(out_slot, jnp.int32),
      dy, w)
    return dx.astype(dy.dtype)


# ---------------------------------------------------------------------------
# Backward-weights: dw[rb, f] = x[:, block_idx[rb, f]].T @ dy[:, rb]
# (eq. (4b) per tile, accumulated over the batch)
# ---------------------------------------------------------------------------


def _dw_kernel(idx_ref, x_ref, dy_ref, dw_ref):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    x = x_ref[...]  # (block_m, bL)
    dy = dy_ref[...]  # (block_m, bR)
    dw_ref[0, 0] += jax.lax.dot_general(
        x, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=dw_ref.dtype)


def csd_spmm_dw(
    x: jax.Array,
    dy: jax.Array,
    block_idx: np.ndarray,
    *,
    block_in: int,
    block_out: int,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """dw: (n_rb, d_in_b, bL, bR), batch-accumulated (innermost grid dim)."""
    m, n_in = x.shape
    n_rb, d_in_b = block_idx.shape
    bl, br = block_in, block_out
    if m % block_m:
        raise ValueError(f"M={m} not divisible by block_m={block_m}")

    grid = (n_rb, d_in_b, m // block_m)
    dw = pl.pallas_call(
        _dw_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, bl),
                             lambda r, f, i, idx: (i, idx[r, f])),
                pl.BlockSpec((block_m, br),
                             lambda r, f, i, idx: (i, r)),
            ],
            out_specs=pl.BlockSpec((1, 1, bl, br),
                                   lambda r, f, i, idx: (r, f, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_rb, d_in_b, bl, br), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_idx, jnp.int32), x, dy)
    return dw.astype(x.dtype)
