"""Jit'd public ops wrapping the Pallas kernels, with XLA fallbacks.

``csd_matmul`` is THE differentiable block-sparse junction primitive — the
single execution path every layer in the model stack routes through
(``core.sparse_linear.SparseLinear`` block modes, ``nn.layers.Linear``,
``nn.mlp.SparseMLP``, the FFN junctions). It computes

    y = activation(x @ W_sparse + bias)

with the bias/activation epilogue *fused* into the junction: the Pallas
kernel applies it on the last fan-in slot while the accumulator tile is
still in VMEM (the activation never round-trips HBM), and the XLA
fallback applies it on the slot-wise accumulator so XLA fuses it into the
final slot's consumer. Backend selection:

* ``backend="pallas"``    — pl.pallas_call kernels (TPU; ``interpret=True``
                            executes the same kernel bodies on CPU and is
                            what the test suite sweeps);
* ``backend="xla"``       — slot-wise gather/scatter einsum forms
                            (GSPMD-friendly; what the multi-pod dry-run
                            lowers, letting the SPMD partitioner place
                            collectives);
* ``backend="dense"``     — the dense-ref escape hatch: densify the slab
                            (static zero-filled block gather) and run ONE
                            dense GEMM. Algebraically identical, grads
                            flow only to pattern blocks; the winning move
                            in regimes where structured sparsity loses to
                            a single dense matmul (e.g. rho=0.5 on CPU);
* ``backend="auto"``      — *measured*-auto: consult the ``repro.tune``
                            dispatch cache at trace time (key: op,
                            M-regime, junction dims, rho, E, dtype/quant,
                            device kind) and run the benchmarked winner;
                            on a cache miss (or ``REPRO_TUNE_DISABLE=1``)
                            fall back to the static heuristic — pallas on
                            TPU, xla elsewhere.

``dataflow`` picks the XLA lowering of the forward: ``"gather"`` is
column-parallel (each right block pulls its fan-in — output-sharding
friendly), ``"scatter"`` is row-parallel (each left block pushes partial
sums — input-sharding friendly, GSPMD turns the segment-sum into the
Megatron-style all-reduce). Both are algebraically identical; the Pallas
kernel serves both.

The custom VJP wires the paper's three operations exactly as the hardware
does (Fig. 3): FF = ``csd_spmm_fwd``, BP = ``csd_spmm_dx`` over the
*transpose* pattern, UP = ``csd_spmm_dw``; all three share one weight
layout, the paper's single weight memory bank. The fused epilogue's
gradient is handled by masking the incoming cotangent (relu: sign of the
saved output; gelu: derivative at the saved pre-activation) before it
enters BP/UP.

Batched (expert-major) junctions — the MoE layout
-------------------------------------------------
Passing ``w`` with a leading expert dimension, ``(E, n_rb, d_in_b, bL,
bR)``, selects the batched junction path: ``x`` is ``(E, ..., n_in)``
(one activation slab per expert), ``bias`` is ``(E, n_out)``, and the
result is ``(E, ..., n_out)``. All ``E`` experts share ONE compile-time
``BlockPattern``:

* Pallas — the expert index is the leading (outermost) grid dimension of
  the same FF/BP/UP kernels; the pattern is scalar-prefetched once and
  re-read per expert, so pattern memory does not scale with ``E``;
* XLA fallback — the slot-wise gather/scatter sweeps are ``jax.vmap``-ed
  over the expert dim, keeping the one-output-intermediate peak per
  expert. The fallback is selected exactly as in the unbatched case:
  ``backend="auto"`` resolves to Pallas on TPU and XLA everywhere else
  (and is what GSPMD partitions inside the MoE ``shard_map``).

The batched custom VJP routes expert junctions through the same three
operations, so a stack of expert FFNs trains exactly like the paper's
single junction — this is what ``nn.ffn.MoE`` runs when
``SparsityConfig.moe_sparsity`` is enabled.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_pattern import BlockPattern
from ..obs import metrics as _obs_metrics
from . import csd_spmm, ref
from .csd_spmm import apply_activation  # noqa: F401 — re-export: layers
#   applying the nonlinearity out-of-kernel use the same one definition


def _count_dispatch(backend: str, form: str) -> None:
    """Per-backend junction dispatch counter. ``csd_matmul`` is called at
    trace time (host-side Python inside ``jax.jit``), so this counts
    junction *instantiations per compiled executable*, not per-step
    executions — which is the useful number: it says which backend/form
    every compiled program routed each junction through, without putting
    any op (or host sync) into the traced program itself."""
    _obs_metrics.get_registry().counter(
        "repro_junction_dispatch_total",
        "csd_matmul dispatches by backend/form (counted at trace time)",
    ).inc(backend=backend, form=form)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # no backend yet
        return False


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


# Static pattern arrays are hashed by id for custom_vjp staticness; wrap them
# in a hashable carrier.
class _Pat:
    """Hashable wrapper for the static pattern (numpy arrays)."""

    def __init__(self, bp: BlockPattern):
        self.block_idx = np.asarray(bp.block_idx, np.int32)
        self.out_idx = np.asarray(bp.out_idx, np.int32)
        self.out_slot = np.asarray(bp.out_slot, np.int32)
        # scatter-form padding mask of shard-local patterns (None = all
        # entries real); every scatter-form consumer below honors it
        self.out_valid = None if getattr(bp, "out_valid", None) is None \
            else np.asarray(bp.out_valid, np.int32)
        self.block_in = bp.block_in
        self.block_out = bp.block_out
        self._key = (self.block_idx.tobytes(), self.out_idx.tobytes(),
                     None if self.out_valid is None
                     else self.out_valid.tobytes(),
                     bp.block_in, bp.block_out)

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, _Pat) and self._key == other._key


# ---------------------------------------------------------------------------
# Slot-wise XLA implementations. The naive gather-einsum oracle (ref.py)
# materializes the activations expanded per (right-block, fan-in slot) —
# an O(n_rb * d_in_b * bL / n_in) blowup (200x+ for narrow output blocks).
# Processing one fan-in slot at a time keeps the peak at one output-sized
# intermediate: this is the XLA analogue of the kernel's grid loop over f,
# and exactly the paper's "one sweep at a time" schedule (§III-B).
# ---------------------------------------------------------------------------


def _acc_dtype(dtype, n_slots):
    """Cross-slot accumulator dtype: each dot already accumulates in f32
    internally; for few slots a bf16 running sum halves the dominant
    accumulator HBM traffic at negligible numeric cost."""
    if dtype == jnp.bfloat16:
        return dtype if n_slots <= 8 else jnp.float32
    return dtype


def _slot_sweep(slot, acc0, xs):
    """Accumulate ``slot`` over the fan slots (leading dim of every array
    in ``xs``): unrolled for small fan so XLA fuses the short chain,
    ``lax.scan`` otherwise. Shared by every slot-wise XLA form so the
    unroll threshold / accumulator policy cannot diverge between them."""
    n_slots = xs[0].shape[0]
    if n_slots <= 4:
        for i in range(n_slots):
            acc0, _ = slot(acc0, tuple(x[i] for x in xs))
        return acc0
    y, _ = jax.lax.scan(slot, acc0, xs)
    return y


def _xla_fwd(x, w, block_idx):
    """x: (..., n_in) — leading dims preserved so GSPMD keeps their
    (batch, seq) sharding through the take/einsum chain (flattening them
    merges sharded axes and the partitioner gives up -> full replication).
    ``block_idx`` (n_rb, d_in_b) may be numpy or a traced jnp array (the
    sharded path selects the shard-local pattern by ``axis_index``)."""
    n_rb, d_in_b, bl, br = w.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (-1, bl))
    idx = jnp.asarray(block_idx).T  # (d_in_b, n_rb)

    def slot(acc, inp):
        idx_f, w_f = inp
        lhs = jnp.take(xb, idx_f, axis=-2)  # (..., n_rb, bL)
        y_f = jnp.einsum("...ri,rio->...ro", lhs, w_f.astype(lhs.dtype))
        return acc + y_f.astype(acc.dtype), None

    acc0 = jnp.zeros(lead + (n_rb, br), _acc_dtype(x.dtype, d_in_b))
    y = _slot_sweep(slot, acc0, (idx, jnp.moveaxis(w, 1, 0)))
    return y.reshape(lead + (n_rb * br,)).astype(x.dtype)


def _xla_fwd_scatter(x, w, out_idx, out_slot, out_valid=None):
    """Row-parallel slot-wise forward: each left block pushes its partial
    product into the right blocks it feeds (segment-sum over the reverse
    adjacency). Same O(one output intermediate) peak as ``_xla_fwd``; the
    different dataflow gives GSPMD the input-sharded lowering.
    ``out_valid`` zeroes padded entries of shard-local scatter forms."""
    n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = out_idx.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (n_lb, bl))
    oidx = jnp.asarray(out_idx).T    # (d_out_b, n_lb)
    oslot = jnp.asarray(out_slot).T
    xs = (oidx, oslot)
    if out_valid is not None:
        xs = xs + (jnp.asarray(out_valid).T,)

    def slot(acc, inp):
        oi, os = inp[0], inp[1]
        w_g = w[oi, os].astype(xb.dtype)            # (n_lb, bL, bR)
        if out_valid is not None:
            w_g = w_g * inp[2][:, None, None].astype(w_g.dtype)
        p = jnp.einsum("...li,lio->...lo", xb, w_g)
        contrib = jax.ops.segment_sum(
            jnp.moveaxis(p.astype(acc.dtype), -2, 0), oi,
            num_segments=n_rb)
        return acc + jnp.moveaxis(contrib, 0, -2), None

    acc0 = jnp.zeros(lead + (n_rb, br), _acc_dtype(x.dtype, d_out_b))
    y = _slot_sweep(slot, acc0, xs)
    return y.reshape(lead + (n_rb * br,)).astype(x.dtype)


def _xla_fwd_quant(x, w, scales, block_idx):
    """Quantized gather-form forward (inference only): ``w`` int8 with
    per-block scales ``(n_rb, d_in_b)``. Each slot's int8 block is widened
    per-slot (a rank-3 (n_rb, bL, bR) convert — never the whole 4-D slab,
    which is SL206's contract) and the f32 scale is applied to the slot's
    partial sum before accumulation."""
    n_rb, d_in_b, bl, br = w.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (-1, bl))
    idx = jnp.asarray(block_idx).T  # (d_in_b, n_rb)

    def slot(acc, inp):
        idx_f, w_f, s_f = inp  # w_f (n_rb, bL, bR) int8; s_f (n_rb,) f32
        lhs = jnp.take(xb, idx_f, axis=-2)  # (..., n_rb, bL)
        y_f = jnp.einsum("...ri,rio->...ro", lhs, w_f.astype(lhs.dtype))
        return acc + y_f.astype(acc.dtype) * s_f[:, None], None

    acc0 = jnp.zeros(lead + (n_rb, br), jnp.float32)
    y = _slot_sweep(slot, acc0,
                    (idx, jnp.moveaxis(w, 1, 0),
                     jnp.moveaxis(jnp.asarray(scales, jnp.float32), 1, 0)))
    return y.reshape(lead + (n_rb * br,)).astype(x.dtype)


def _xla_fwd_scatter_quant(x, w, scales, out_idx, out_slot, out_valid=None):
    """Quantized row-parallel forward: per-slot rank-3 int8 gathers with
    the gathered f32 scale folded into the partial sum (masking the scale,
    not the slab, zeroes padded shard-local entries)."""
    n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = out_idx.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (n_lb, bl))
    sc = jnp.asarray(scales, jnp.float32)
    oidx = jnp.asarray(out_idx).T    # (d_out_b, n_lb)
    oslot = jnp.asarray(out_slot).T
    xs = (oidx, oslot)
    if out_valid is not None:
        xs = xs + (jnp.asarray(out_valid).T,)

    def slot(acc, inp):
        oi, os = inp[0], inp[1]
        w_g = w[oi, os].astype(xb.dtype)  # (n_lb, bL, bR) rank-3 convert
        s_g = sc[oi, os]                  # (n_lb,) f32
        if out_valid is not None:
            s_g = s_g * inp[2].astype(s_g.dtype)
        p = jnp.einsum("...li,lio->...lo", xb, w_g)
        p = p.astype(acc.dtype) * s_g[:, None]
        contrib = jax.ops.segment_sum(
            jnp.moveaxis(p, -2, 0), oi, num_segments=n_rb)
        return acc + jnp.moveaxis(contrib, 0, -2), None

    acc0 = jnp.zeros(lead + (n_rb, br), jnp.float32)
    y = _slot_sweep(slot, acc0, xs)
    return y.reshape(lead + (n_rb * br,)).astype(x.dtype)


def _xla_dx(dy, w, out_idx, out_slot, out_valid=None):
    """``out_valid`` (n_lb, d_out_b) 0/1 marks padded entries of a
    shard-local (non-uniform out-degree) scatter pattern; padded entries
    contribute zero."""
    n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = out_idx.shape
    lead = dy.shape[:-1]
    dyb = dy.reshape(lead + (n_rb, br))
    oidx = jnp.asarray(out_idx).T    # (d_out_b, n_lb)
    oslot = jnp.asarray(out_slot).T
    xs = (oidx, oslot)
    if out_valid is not None:
        xs = xs + (jnp.asarray(out_valid).T,)

    def slot(acc, inp):
        oi, os = inp[0], inp[1]
        lhs = jnp.take(dyb, oi, axis=-2)            # (..., n_lb, bR)
        w_g = w[oi, os].astype(lhs.dtype)           # (n_lb, bL, bR)
        if out_valid is not None:
            w_g = w_g * inp[2][:, None, None].astype(w_g.dtype)
        d = jnp.einsum("...lo,lio->...li", lhs, w_g)
        return acc + d.astype(acc.dtype), None

    acc0 = jnp.zeros(lead + (n_lb, bl), _acc_dtype(dy.dtype, d_out_b))
    dx = _slot_sweep(slot, acc0, xs)
    return dx.reshape(lead + (n_lb * bl,)).astype(dy.dtype)


def _xla_dw(x, dy, block_idx, bl, br):
    n_rb, d_in_b = block_idx.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (-1, bl))
    dyb = dy.reshape(lead + (n_rb, br))
    idx = jnp.asarray(block_idx).T

    def slot(_, idx_f):
        lhs = jnp.take(xb, idx_f, axis=-2)  # (..., n_rb, bL)
        return None, jnp.einsum("...ri,...ro->rio",
                                lhs, dyb.astype(lhs.dtype))

    if d_in_b <= 4:
        dws = [slot(None, idx[f])[1] for f in range(d_in_b)]
        dw = jnp.stack(dws, axis=1)
    else:
        _, dws = jax.lax.scan(slot, None, idx)
        dw = jnp.moveaxis(dws, 0, 1)
    return dw.astype(x.dtype)


# ---------------------------------------------------------------------------
# Batched (expert-major) XLA fallbacks: the slot sweeps vmapped over the
# leading expert dim of x and w. The pattern is closed over (shared by all
# experts), so only the weight slab and activations are mapped — the
# per-expert peak memory is identical to the unbatched sweep.
# ---------------------------------------------------------------------------


def _xla_fwd_batched(x, w, pat, dataflow):
    if dataflow == "scatter":
        return jax.vmap(lambda xe, we: _xla_fwd_scatter(
            xe, we, pat.out_idx, pat.out_slot, pat.out_valid))(x, w)
    return jax.vmap(lambda xe, we: _xla_fwd(xe, we, pat.block_idx))(x, w)


def _xla_fwd_quant_batched(x, w, scales, pat, dataflow):
    if dataflow == "scatter":
        return jax.vmap(lambda xe, we, se: _xla_fwd_scatter_quant(
            xe, we, se, pat.out_idx, pat.out_slot, pat.out_valid))(
                x, w, scales)
    return jax.vmap(lambda xe, we, se: _xla_fwd_quant(
        xe, we, se, pat.block_idx))(x, w, scales)


def _xla_dx_batched(dy, w, pat):
    return jax.vmap(lambda de, we: _xla_dx(
        de, we, pat.out_idx, pat.out_slot, pat.out_valid))(dy, w)


def _xla_dw_batched(x, dy, pat):
    return jax.vmap(lambda xe, de: _xla_dw(
        xe, de, pat.block_idx, pat.block_in, pat.block_out))(x, dy)


# ---------------------------------------------------------------------------
# Dense-ref escape hatch (backend="dense"). The autotuner's measurement
# says some regimes (rho=0.5 at training M on CPU) lose to one dense GEMM
# no matter which sparse dataflow runs — the paper's complexity win is a
# FLOP count, the crossover point is a device property. Densify with a
# STATIC slot map + jnp.take (one appended zero block serves every hole),
# never a scatter: the take fuses into the GEMM's prologue (~2% overhead
# at M=512) where `.at[].set()` costs tens of ms per call.
# ---------------------------------------------------------------------------


def _dense_map(pat: _Pat) -> np.ndarray:
    """Static flat map dense block (lb, rb) -> slab slot, sentinel = the
    appended zero block. Cached on the pattern carrier (pure numpy)."""
    cached = getattr(pat, "_dense_map_arr", None)
    if cached is not None:
        return cached
    n_rb, d_in_b = pat.block_idx.shape
    n_lb = pat.out_idx.shape[0]
    sentinel = n_rb * d_in_b
    slot_of = np.full((n_lb, n_rb), sentinel, np.int32)
    rows = np.repeat(np.arange(n_rb, dtype=np.int32), d_in_b)
    slot_of[pat.block_idx.reshape(-1), rows] = np.arange(
        n_rb * d_in_b, dtype=np.int32)
    if int((slot_of != sentinel).sum()) != n_rb * d_in_b:
        raise ValueError(
            "backend='dense' requires distinct (left, right) block pairs "
            "per pattern (duplicate fan-in entry found)")
    pat._dense_map_arr = slot_of.reshape(-1)
    return pat._dense_map_arr


def _densify_slab(w, pat: _Pat):
    """(n_rb, d_in_b, bL, bR) slab -> (n_in, n_out) dense weight (zeros at
    non-pattern blocks). Batched: (E, ...) -> (E, n_in, n_out)."""
    if w.ndim == 5:
        return jax.vmap(lambda we: _densify_slab(we, pat))(w)
    n_rb, d_in_b, bl, br = w.shape
    n_lb = pat.out_idx.shape[0]
    wf = jnp.concatenate([w.reshape(n_rb * d_in_b, bl, br),
                          jnp.zeros((1, bl, br), w.dtype)])
    dense = jnp.take(wf, jnp.asarray(_dense_map(pat)), axis=0)
    dense = jnp.moveaxis(dense.reshape(n_lb, n_rb, bl, br), -2, -3)
    return dense.reshape(n_lb * bl, n_rb * br)


def _dense_grad_slab(dwd, pat: _Pat):
    """Gather the slab-layout weight gradient back out of a dense
    (n_in, n_out) gradient — grads at zero blocks are structurally zero
    and are dropped, exactly matching the sparse-path dw."""
    if dwd.ndim == 3:
        return jax.vmap(lambda g: _dense_grad_slab(g, pat))(dwd)
    n_rb, d_in_b = pat.block_idx.shape
    bl, br = pat.block_in, pat.block_out
    n_lb = pat.out_idx.shape[0]
    g = jnp.moveaxis(dwd.reshape(n_lb, bl, n_rb, br), 1, 2)
    g = g.reshape(n_lb * n_rb, bl, br)
    flat = (pat.block_idx.astype(np.int64) * n_rb
            + np.arange(n_rb, dtype=np.int64)[:, None])  # (n_rb, d_in_b)
    dw = jnp.take(g, jnp.asarray(flat.reshape(-1)), axis=0)
    return dw.reshape(n_rb, d_in_b, bl, br)


# ---------------------------------------------------------------------------
# Differentiable core. Signature: (x, w, b) differentiable; everything else
# static. ``b`` is a zero-length placeholder when has_bias is False so the
# custom_vjp arity stays fixed. Batched-ness is a shape property
# (w.ndim == 5), not an extra static flag — both layouts trace through the
# same custom_vjp.
# ---------------------------------------------------------------------------


def _fwd_impl(x, w, b, pat, has_bias, activation, backend, dataflow,
              block_m, interpret, want_preact=False):
    """Returns (y, preact): preact is the pre-activation z = xW + b when the
    caller is the VJP forward and the backward needs it (gelu), else None
    (relu recovers its mask from y; the primal never pays for the extra
    kernel output)."""
    batched = w.ndim == 5
    if backend == "pallas":
        bias = b if has_bias else None
        if activation == "gelu" and want_preact:
            return csd_spmm.csd_spmm_fwd(
                x, w, pat.block_idx, bias=bias, activation="gelu",
                save_preact=True, block_m=block_m, interpret=interpret)
        y = csd_spmm.csd_spmm_fwd(
            x, w, pat.block_idx, bias=bias, activation=activation,
            block_m=block_m, interpret=interpret)
        return y, None
    if backend == "dense":
        wd = _densify_slab(w, pat).astype(x.dtype)
        z = jnp.einsum("e...i,eio->e...o", x, wd) if batched else x @ wd
    elif batched:
        z = _xla_fwd_batched(x, w, pat, dataflow)
    elif dataflow == "scatter":
        z = _xla_fwd_scatter(x, w, pat.out_idx, pat.out_slot,
                             pat.out_valid)
    else:
        z = _xla_fwd(x, w, pat.block_idx)
    if has_bias:
        bb = b
        if batched:  # (E, n_out) broadcast over the per-expert leading dims
            bb = b.reshape((b.shape[0],) + (1,) * (z.ndim - 2) + b.shape[1:])
        z = z + bb.astype(z.dtype)
    y = csd_spmm.apply_activation(z, activation)
    return y, (z if activation == "gelu" else None)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _csd_matmul(x, w, b, pat: _Pat, has_bias: bool,
                activation: Optional[str], backend: str, dataflow: str,
                block_m: int, interpret: bool):
    y, _ = _fwd_impl(x, w, b, pat, has_bias, activation, backend, dataflow,
                     block_m, interpret)
    return y


def _fwd_vjp(x, w, b, pat, has_bias, activation, backend, dataflow,
             block_m, interpret):
    y, preact = _fwd_impl(x, w, b, pat, has_bias, activation, backend,
                          dataflow, block_m, interpret, want_preact=True)
    # relu's gradient mask is recoverable from the output itself — no extra
    # residual; gelu needs the pre-activation the kernel emitted. b rides
    # along so db can match its dtype exactly.
    aux = y if activation == "relu" else preact
    return y, (x, w, b, aux)


def _mask_dy_xla(dy, aux, activation):
    """XLA-path fused-epilogue gradient: mask/scale the cotangent before
    it enters BP (dx) and UP (dw) — eq. (3)/(4) with the activation
    derivative folded into delta. (The Pallas path masks *inside* the
    BP/UP kernels instead — the fused backward epilogue.)"""
    if activation == "relu":
        return dy * (aux > 0).astype(dy.dtype)
    if activation == "gelu":
        _, act_vjp = jax.vjp(
            lambda z: jax.nn.gelu(z, approximate=True),
            aux.astype(jnp.float32))
        return act_vjp(dy.astype(jnp.float32))[0].astype(dy.dtype)
    return dy


def _bwd_vjp(pat, has_bias, activation, backend, dataflow, block_m,
             interpret, res, dy):
    x, w, b, aux = res
    # keep backward slot traffic in the compute dtype — f32 cotangents
    # double the (already dominant) gather/accumulate HBM bytes
    dy = dy.astype(x.dtype)
    batched = w.ndim == 5
    if backend == "pallas":
        # fused backward epilogue: the raw cotangent streams into the
        # BP/UP kernels which mask it tile-by-tile from aux (and fold the
        # bias cotangent into the UP sweep) — no separate elementwise op,
        # no masked-dy round-trip through HBM
        dx = csd_spmm.csd_spmm_dx(dy, w, pat.out_idx, pat.out_slot,
                                  out_valid=pat.out_valid, aux=aux,
                                  activation=activation,
                                  block_m=block_m, interpret=interpret)
        if has_bias:
            dw, db = csd_spmm.csd_spmm_dw(
                x, dy, pat.block_idx, block_in=pat.block_in,
                block_out=pat.block_out, aux=aux, activation=activation,
                want_db=True, block_m=block_m, interpret=interpret)
            db = db.astype(b.dtype)
        else:
            dw = csd_spmm.csd_spmm_dw(
                x, dy, pat.block_idx, block_in=pat.block_in,
                block_out=pat.block_out, aux=aux, activation=activation,
                block_m=block_m, interpret=interpret)
            db = jnp.zeros((0,), b.dtype)
        return dx, dw.astype(w.dtype), db
    dy = _mask_dy_xla(dy, aux, activation)
    if has_bias:
        # batched: keep the per-expert leading dim — db is (E, n_out)
        axes = tuple(range(1 if batched else 0, dy.ndim - 1))
        db = jnp.sum(dy.astype(jnp.float32), axis=axes).astype(b.dtype)
    else:
        db = jnp.zeros((0,), b.dtype)
    if backend == "dense":
        # BP/UP against the densified weight: dx = dy @ W^T, dw = x^T dy
        # gathered back to slab layout (zero-block grads dropped — the
        # same structural-zero contract as the sparse sweeps)
        wd = _densify_slab(w, pat).astype(dy.dtype)
        if batched:
            dx = jnp.einsum("e...o,eio->e...i", dy, wd)
            xf = x.reshape(x.shape[0], -1, x.shape[-1])
            dyf = dy.reshape(dy.shape[0], -1, dy.shape[-1])
            dwd = jnp.einsum("emi,emo->eio", xf, dyf.astype(xf.dtype))
        else:
            dx = jnp.einsum("...o,io->...i", dy, wd)
            xf = x.reshape(-1, x.shape[-1])
            dyf = dy.reshape(-1, dy.shape[-1])
            dwd = xf.T @ dyf.astype(xf.dtype)
        dw = _dense_grad_slab(dwd, pat)
        return dx.astype(x.dtype), dw.astype(w.dtype), db
    if batched:
        dx = _xla_dx_batched(dy, w, pat)
        dw = _xla_dw_batched(x, dy, pat)
    else:
        dx = _xla_dx(dy, w, pat.out_idx, pat.out_slot, pat.out_valid)
        dw = _xla_dw(x, dy, pat.block_idx, pat.block_in, pat.block_out)
    return dx, dw.astype(w.dtype), db


_csd_matmul.defvjp(_fwd_vjp, _bwd_vjp)


# ---------------------------------------------------------------------------
# Sharded (model-parallel) junctions — the jax_pallas form of the paper's
# size-flexible hardware: the same junction processed k block-row ranges at
# a time, one range per mesh device. Under ``shard_map`` every device runs
# its shard-local scalar-prefetched pattern against its slab rows:
#
#   FF — shard-local forward over the local gather pattern; the output
#        feature axis comes out sharded over ``axis`` (column-parallel);
#   BP — shard-local dx over the local (padded, validity-masked) scatter
#        pattern, then ``psum`` over ``axis`` (each shard contributes the
#        cotangent flowing through its output rows);
#   UP — dw and db are SHARD-LOCAL: a device's weight rows only ever see
#        its own dy shard, so weight gradients (and therefore Adam state)
#        stay sharded over ``axis`` ZeRO-style with no extra collectives.
#
# The global slab keeps its logical (n_rb, d_in_b, bL, bR) layout sharded
# contiguously on the block-row dim — exactly what a NamedSharding row
# chunking produces, so entering the shard_map moves no weight data.
# ---------------------------------------------------------------------------


class _ShardPat:
    """Hashable static carrier of a partitioned pattern (stacked per-shard
    arrays; selected per-device by ``axis_index`` inside the shard_map)."""

    def __init__(self, part):
        self.idx = np.asarray(part.idx, np.int32)
        self.oidx = np.asarray(part.out_idx, np.int32)
        self.oslot = np.asarray(part.out_slot, np.int32)
        self.ovalid = np.asarray(part.out_valid, np.int32)
        self.block_in = part.parent.block_in
        self.block_out = part.parent.block_out
        self.n_shards = part.n_shards
        self._key = (self.idx.tobytes(), self.oidx.tobytes(),
                     self.oslot.tobytes(), self.ovalid.tobytes(),
                     self.block_in, self.block_out)

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, _ShardPat) and self._key == other._key


_PARTITION_CACHE: dict = {}


def get_partition(pattern: BlockPattern, axis_size: int):
    """Cached ``partition_pattern`` (patterns are immutable; partitioning
    is pure numpy work we only want once per (pattern, k))."""
    from ..core.block_pattern import partition_pattern
    key = (pattern.block_idx.tobytes(), pattern.block_in,
           pattern.block_out, pattern.n_in, pattern.n_out, axis_size)
    part = _PARTITION_CACHE.get(key)
    if part is None:
        part = _PARTITION_CACHE[key] = partition_pattern(pattern, axis_size)
    return part


def _shard_specs(batched, has_bias, lead, axis):
    from jax.sharding import PartitionSpec as P
    x_spec = P(*lead, None)
    if batched:
        w_spec = P(None, axis, None, None, None)
    else:
        w_spec = P(axis, None, None, None)
    if has_bias:
        b_spec = P(None, axis) if batched else P(axis)
    else:
        b_spec = P(axis)  # zero-length placeholder: 0 % k == 0
    y_spec = P(*lead, axis)
    return x_spec, w_spec, b_spec, y_spec


def _local_pattern(spat, axis):
    """Per-device slices of the stacked pattern arrays (traced by
    ``axis_index`` — the device id IS the address-generator seed here)."""
    s = jax.lax.axis_index(axis)
    return (jnp.asarray(spat.idx)[s], jnp.asarray(spat.oidx)[s],
            jnp.asarray(spat.oslot)[s], jnp.asarray(spat.ovalid)[s])


def _spmd_fwd_call(x, w, b, spat, has_bias, activation, backend, block_m,
                   interpret, mesh, axis, lead, want_aux):
    from ..compat import shard_map
    batched = w.ndim == 5
    x_spec, w_spec, b_spec, y_spec = _shard_specs(
        batched, has_bias, lead, axis)

    def local(xl, wl, bl):
        idx, _, _, _ = _local_pattern(spat, axis)
        if backend == "pallas":
            bias_l = bl if has_bias else None
            if want_aux and activation == "gelu":
                return csd_spmm.csd_spmm_fwd(
                    xl, wl, idx, bias=bias_l, activation="gelu",
                    save_preact=True, block_m=block_m, interpret=interpret)
            y = csd_spmm.csd_spmm_fwd(
                xl, wl, idx, bias=bias_l, activation=activation,
                block_m=block_m, interpret=interpret)
            return (y, y) if want_aux else y
        if batched:
            z = jax.vmap(lambda xe, we: _xla_fwd(xe, we, idx))(xl, wl)
        else:
            z = _xla_fwd(xl, wl, idx)
        if has_bias:
            bb = bl
            if batched:
                bb = bl.reshape((bl.shape[0],) + (1,) * (z.ndim - 2)
                                + bl.shape[1:])
            z = z + bb.astype(z.dtype)
        y = csd_spmm.apply_activation(z, activation)
        if want_aux:
            return y, (z if activation == "gelu" else y)
        return y

    out_specs = (y_spec, y_spec) if want_aux else y_spec
    fn = shard_map(local, mesh=mesh, in_specs=(x_spec, w_spec, b_spec),
                   out_specs=out_specs, check_vma=False)
    return fn(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9,
                                                    10, 11))
def _csd_matmul_spmd(x, w, b, spat: _ShardPat, has_bias: bool,
                     activation: Optional[str], backend: str, block_m: int,
                     interpret: bool, mesh, axis: str, lead: tuple):
    return _spmd_fwd_call(x, w, b, spat, has_bias, activation, backend,
                          block_m, interpret, mesh, axis, lead,
                          want_aux=False)


def _spmd_fwd_vjp(x, w, b, spat, has_bias, activation, backend, block_m,
                  interpret, mesh, axis, lead):
    if activation is None:
        y = _spmd_fwd_call(x, w, b, spat, has_bias, activation, backend,
                           block_m, interpret, mesh, axis, lead,
                           want_aux=False)
        aux = y  # unused by the backward; placeholder with y's sharding
    else:
        y, aux = _spmd_fwd_call(x, w, b, spat, has_bias, activation,
                                backend, block_m, interpret, mesh, axis,
                                lead, want_aux=True)
    return y, (x, w, b, aux)


def _spmd_bwd_vjp(spat, has_bias, activation, backend, block_m, interpret,
                  mesh, axis, lead, res, dy):
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    x, w, b, aux = res
    dy = dy.astype(x.dtype)
    batched = w.ndim == 5
    x_spec, w_spec, b_spec, y_spec = _shard_specs(
        batched, has_bias, lead, axis)
    bl_, br_ = spat.block_in, spat.block_out
    # mesh axes the batch (lead) dims are mapped over: dw/db sum over the
    # batch, so their shard-local partials must all-reduce over these axes
    # (dw's out-spec is unmapped over them — sparselint SL205)
    lead_axes = tuple(
        a for entry in lead if entry is not None
        for a in (entry if isinstance(entry, tuple) else (entry,)))

    def local(xl, wl, bll, auxl, dyl):
        idx, oidx, oslot, ovalid = _local_pattern(spat, axis)
        if backend == "pallas":
            dxl = csd_spmm.csd_spmm_dx(
                dyl, wl, oidx, oslot, out_valid=ovalid, aux=auxl,
                activation=activation, block_m=block_m,
                interpret=interpret)
            if has_bias:
                dwl, dbl = csd_spmm.csd_spmm_dw(
                    xl, dyl, idx, block_in=bl_, block_out=br_, aux=auxl,
                    activation=activation, want_db=True, block_m=block_m,
                    interpret=interpret)
            else:
                dwl = csd_spmm.csd_spmm_dw(
                    xl, dyl, idx, block_in=bl_, block_out=br_, aux=auxl,
                    activation=activation, block_m=block_m,
                    interpret=interpret)
                dbl = jnp.zeros((0,), jnp.float32)
        else:
            dym = _mask_dy_xla(dyl, auxl, activation)
            if batched:
                dxl = jax.vmap(lambda de, we: _xla_dx(
                    de, we, oidx, oslot, ovalid))(dym, wl)
                dwl = jax.vmap(lambda xe, de: _xla_dw(
                    xe, de, idx, bl_, br_))(xl, dym)
            else:
                dxl = _xla_dx(dym, wl, oidx, oslot, ovalid)
                dwl = _xla_dw(xl, dym, idx, bl_, br_)
            if has_bias:
                axes = tuple(range(1 if batched else 0, dym.ndim - 1))
                dbl = jnp.sum(dym.astype(jnp.float32), axis=axes)
            else:
                dbl = jnp.zeros((0,), jnp.float32)
        # BP assembles the full input cotangent: every shard's output rows
        # pull on the whole input, so the partials all-reduce over `axis`
        dx = jax.lax.psum(dxl, axis)
        if lead_axes:
            dwl = jax.lax.psum(dwl, lead_axes)
            dbl = jax.lax.psum(dbl, lead_axes)
        return dx, dwl, dbl

    dx_spec = P(*lead, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, w_spec, b_spec, y_spec, y_spec),
        out_specs=(dx_spec, w_spec, b_spec), check_vma=False)
    aux_arr = aux if activation is not None else dy
    dx, dw, db = fn(x, w, b, aux_arr, dy)
    return dx, dw.astype(w.dtype), db.astype(b.dtype)


_csd_matmul_spmd.defvjp(_spmd_fwd_vjp, _spmd_bwd_vjp)


def _csd_matmul_sharded(x, w, pattern, bias, activation, backend, block_m,
                        interpret, mesh, axis, lead_spec):
    """Entry for the sharded path: validate the partition, normalize the
    lead spec, pad M for the Pallas layout, run the SPMD custom-VJP."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}")
    k = int(mesh.shape[axis])
    # partition_pattern guarantees a contiguous split (fixed-degree is
    # structural for BlockPattern), so the global slab's NamedSharding
    # row chunks are exactly the per-device slabs this path assumes
    part = get_partition(pattern, k)
    spat = _ShardPat(part)
    batched = w.ndim == 5
    has_bias = bias is not None
    b = bias if has_bias else jnp.zeros((0,), x.dtype)
    if backend == "pallas":
        n_in = x.shape[-1]
        xf = x.reshape(((x.shape[0],) if batched else ()) + (-1, n_in))
        m = xf.shape[-2]
        pad = (-m) % block_m
        if pad:
            widths = [(0, 0)] * (xf.ndim - 2) + [(0, pad), (0, 0)]
            xf = jnp.pad(xf, widths)
        lead = (None,) * (xf.ndim - 1)
        y = _csd_matmul_spmd(xf, w, b, spat, has_bias, activation, backend,
                             block_m, interpret, mesh, axis, lead)
        if pad:
            y = y[..., :m, :]
        return y.reshape(x.shape[:-1] + (y.shape[-1],))
    if lead_spec is None:
        lead = (None,) * (x.ndim - 1)
    else:
        lead = tuple(lead_spec)
        if len(lead) != x.ndim - 1:
            raise ValueError(
                f"lead_spec {lead_spec} must cover the {x.ndim - 1} "
                f"leading dims of x {x.shape}")
    return _csd_matmul_spmd(x, w, b, spat, has_bias, activation, backend,
                            block_m, interpret, mesh, axis, lead)


# ---------------------------------------------------------------------------
# Quantized (int8-weight) forward — inference only, no VJP. The slab stays
# int8 all the way into the kernel / per-slot einsum; per-block f32 scales
# ride alongside (sharded with the same row chunking as the slab, so the
# serving engine's model-parallel path works unchanged).
# ---------------------------------------------------------------------------


def _quant_matmul(x, w, w_scale, pat, bias, activation, backend, dataflow,
                  block_m, interpret):
    batched = w.ndim == 5
    has_bias = bias is not None
    if backend == "pallas":
        n_in = x.shape[-1]
        xf = x.reshape(((x.shape[0],) if batched else ()) + (-1, n_in))
        m = xf.shape[-2]
        pad = (-m) % block_m
        if pad:
            widths = [(0, 0)] * (xf.ndim - 2) + [(0, pad), (0, 0)]
            xf = jnp.pad(xf, widths)
        y = csd_spmm.csd_spmm_fwd(
            xf, w, pat.block_idx, bias=bias, activation=activation,
            block_m=block_m, interpret=interpret, w_scale=w_scale)
        if pad:
            y = y[..., :m, :]
        return y.reshape(x.shape[:-1] + (y.shape[-1],))
    if batched:
        z = _xla_fwd_quant_batched(x, w, w_scale, pat, dataflow)
    elif dataflow == "scatter":
        z = _xla_fwd_scatter_quant(x, w, w_scale, pat.out_idx,
                                   pat.out_slot, pat.out_valid)
    else:
        z = _xla_fwd_quant(x, w, w_scale, pat.block_idx)
    if has_bias:
        bb = bias
        if batched:
            bb = bias.reshape((bias.shape[0],) + (1,) * (z.ndim - 2)
                              + bias.shape[1:])
        z = z + bb.astype(z.dtype)
    return csd_spmm.apply_activation(z, activation)


def _quant_matmul_sharded(x, w, w_scale, pattern, bias, activation, backend,
                          block_m, interpret, mesh, axis, lead_spec):
    """Sharded quantized forward: the scale array is row-chunked with the
    same contiguous split as the slab (``P(axis, None)`` for the 2-D
    scales, ``P(None, axis, None)`` batched), so each device's local
    scales line up with its local pattern rows."""
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}")
    k = int(mesh.shape[axis])
    part = get_partition(pattern, k)
    spat = _ShardPat(part)
    batched = w.ndim == 5
    has_bias = bias is not None
    b = bias if has_bias else jnp.zeros((0,), x.dtype)
    s_spec = P(None, axis, None) if batched else P(axis, None)

    def run(xf, lead):
        x_spec, w_spec, b_spec, y_spec = _shard_specs(
            batched, has_bias, lead, axis)

        def local(xl, wl, sl, bl):
            idx, _, _, _ = _local_pattern(spat, axis)
            bias_l = bl if has_bias else None
            if backend == "pallas":
                return csd_spmm.csd_spmm_fwd(
                    xl, wl, idx, bias=bias_l, activation=activation,
                    block_m=block_m, interpret=interpret, w_scale=sl)
            if batched:
                z = jax.vmap(lambda xe, we, se: _xla_fwd_quant(
                    xe, we, se, idx))(xl, wl, sl)
            else:
                z = _xla_fwd_quant(xl, wl, sl, idx)
            if has_bias:
                bb = bl
                if batched:
                    bb = bl.reshape((bl.shape[0],) + (1,) * (z.ndim - 2)
                                    + bl.shape[1:])
                z = z + bb.astype(z.dtype)
            return csd_spmm.apply_activation(z, activation)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(x_spec, w_spec, s_spec, b_spec),
                       out_specs=y_spec, check_vma=False)
        return fn(xf, w, w_scale, b)

    if backend == "pallas":
        n_in = x.shape[-1]
        xf = x.reshape(((x.shape[0],) if batched else ()) + (-1, n_in))
        m = xf.shape[-2]
        pad = (-m) % block_m
        if pad:
            widths = [(0, 0)] * (xf.ndim - 2) + [(0, pad), (0, 0)]
            xf = jnp.pad(xf, widths)
        y = run(xf, (None,) * (xf.ndim - 1))
        if pad:
            y = y[..., :m, :]
        return y.reshape(x.shape[:-1] + (y.shape[-1],))
    if lead_spec is None:
        lead = (None,) * (x.ndim - 1)
    else:
        lead = tuple(lead_spec)
        if len(lead) != x.ndim - 1:
            raise ValueError(
                f"lead_spec {lead_spec} must cover the {x.ndim - 1} "
                f"leading dims of x {x.shape}")
    return run(x, lead)


def csd_matmul(
    x: jax.Array,
    w: jax.Array,
    pattern: BlockPattern,
    *,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    backend: str = "auto",
    dataflow: str = "gather",
    block_m: int = 128,
    interpret: bool = False,
    mesh=None,
    axis: Optional[str] = None,
    lead_spec=None,
    w_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Differentiable block-sparse junction: (..., n_in) -> (..., n_out),
    computing ``activation(x @ W_sparse + bias)`` with the epilogue fused
    into the matmul (see module docstring).

    Batched (expert-major) form: ``w`` of shape ``(E, n_rb, d_in_b, bL,
    bR)`` with ``x`` ``(E, ..., n_in)`` and ``bias`` ``(E, n_out)`` runs
    all ``E`` expert junctions over one shared pattern and returns
    ``(E, ..., n_out)`` (see module docstring).

    ``backend`` is ``"auto" | "pallas" | "xla" | "dense"``. ``"auto"`` is
    *measured*: the ``repro.tune`` dispatch cache is consulted at trace
    time and the benchmarked winner for this call's regime runs (miss or
    ``REPRO_TUNE_DISABLE=1`` -> the static heuristic). ``"dense"`` is the
    escape hatch: densify the slab and run one GEMM — same math, grads
    only at pattern blocks; plain/batched unquantized junctions only.

    ``activation`` is ``None | "relu" | "gelu"`` (gelu = tanh approximation,
    matching the model stack's activation registry). Leading dims are
    flattened to M (per expert in the batched form) and padded to
    ``block_m`` for the Pallas path; the XLA path keeps leading dims intact
    so GSPMD preserves their sharding. The pattern is compile-time static.

    Sharded (model-parallel) form: pass ``mesh`` and ``axis`` (a mesh axis
    name) to partition the pattern and slab over ``mesh.shape[axis]``
    devices — each device runs its shard-local pattern under ``shard_map``
    (FF column-parallel, BP psum'd, UP shard-local; see the sharded-section
    comment). ``w``/``bias`` keep their logical layouts, row-sharded on the
    block-row / feature dim; ``lead_spec`` optionally names the mesh axes
    of ``x``'s leading dims (XLA path) so their sharding survives entry.
    Requires ``n_rb % mesh.shape[axis] == 0`` (see ``can_partition``).

    Quantized form (inference only, no VJP): pass ``w`` as int8 with
    ``w_scale`` per-block f32 scales ``(n_rb, d_in_b)`` (batched:
    ``(E, n_rb, d_in_b)``) from ``core.quant.quantize_slab`` — the slab
    stays int8 into the kernel / per-slot einsum and dequantization is
    folded into the accumulate before the fused epilogue. Composes with
    the sharded form (scales row-chunk with the slab).
    """
    if activation is not None and activation not in csd_spmm.ACTIVATIONS:
        raise ValueError(f"unsupported fused activation {activation!r}")
    if dataflow not in ("gather", "scatter"):
        raise ValueError(f"unknown dataflow {dataflow!r}")
    batched = w.ndim == 5
    if batched and (x.ndim < 2 or x.shape[0] != w.shape[0]):
        raise ValueError(
            f"batched junction: x leading dim {x.shape} must match expert "
            f"count E={w.shape[0]}")
    if backend not in ("auto", "pallas", "xla", "dense"):
        raise ValueError(f"unknown backend {backend!r}")
    sharded = mesh is not None and axis is not None
    quant = w_scale is not None
    if quant:
        form = ("quant_sharded_batched" if batched else "quant_sharded") \
            if sharded else ("quant_batched" if batched else "quant")
    elif sharded:
        form = "sharded_batched" if batched else "sharded"
    else:
        form = "batched" if batched else "plain"
    if backend == "auto":
        # measured-auto (PR 10): consult the tune cache at trace time and
        # dispatch the benchmarked winner for this regime; a miss (or
        # REPRO_TUNE_DISABLE=1) falls back to the static heuristic below.
        # Sharded forms key on the shard-local output width — the tuning
        # decision follows partition_pattern's per-device shapes.
        from .. import tune
        k = int(mesh.shape[axis]) if sharded else 1
        lead = x.shape[1:-1] if batched else x.shape[:-1]
        m = 1
        for d in lead:
            m *= int(d)
        ent = tune.decide_junction(
            m=m, n_in=pattern.n_in, n_out=pattern.n_out // k,
            rho=pattern.density, E=w.shape[0] if batched else 0,
            dtype=str(x.dtype), quant=quant, form=form,
            block_in=pattern.block_in, block_out=pattern.block_out)
        if ent is not None:
            backend = str(ent["backend"])
            dataflow = str(ent.get("dataflow", dataflow))
            block_m = int(ent.get("block_m", block_m))
        else:
            backend = _resolve(backend)
    if backend == "dense" and (quant or sharded):
        raise ValueError("backend='dense' supports only the plain/batched "
                         "unquantized junction")
    _count_dispatch(backend, form)
    if quant:
        if w.dtype != jnp.int8:
            raise ValueError(
                f"w_scale given but w.dtype={w.dtype}, expected int8")
        if sharded:
            return _quant_matmul_sharded(
                x, w, w_scale, pattern, bias, activation, backend, block_m,
                interpret, mesh, axis, lead_spec)
        return _quant_matmul(x, w, w_scale, _Pat(pattern), bias,
                             activation, backend, dataflow, block_m,
                             interpret)
    if sharded:
        return _csd_matmul_sharded(x, w, pattern, bias, activation,
                                   backend, block_m, interpret, mesh, axis,
                                   lead_spec)
    pat = _Pat(pattern)
    has_bias = bias is not None
    b = bias if has_bias else jnp.zeros((0,), x.dtype)
    if backend == "pallas":
        n_in = x.shape[-1]
        # after this reshape the M axis is -2 in both layouts (batched
        # keeps E as axis 0), so pad/slice/unflatten share one form
        xf = x.reshape(((x.shape[0],) if batched else ()) + (-1, n_in))
        m = xf.shape[-2]
        pad = (-m) % block_m
        if pad:
            widths = [(0, 0)] * (xf.ndim - 2) + [(0, pad), (0, 0)]
            xf = jnp.pad(xf, widths)
        y = _csd_matmul(xf, w, b, pat, has_bias, activation, backend,
                        dataflow, block_m, interpret)
        if pad:
            y = y[..., :m, :]
        return y.reshape(x.shape[:-1] + (y.shape[-1],))
    # xla: leading dims flow through untouched (sharding preserved)
    return _csd_matmul(x, w, b, pat, has_bias, activation, backend,
                       dataflow, block_m, interpret)
