"""Jit'd public ops wrapping the Pallas kernels, with XLA fallbacks.

``csd_matmul`` is the differentiable entry point used by the model stack.
Backend selection:

* ``backend="pallas"``    — pl.pallas_call kernels (TPU; ``interpret=True``
                            executes the same kernel bodies on CPU and is
                            what the test suite sweeps);
* ``backend="xla"``       — gather-einsum forms (GSPMD-friendly; what the
                            multi-pod dry-run lowers, letting the SPMD
                            partitioner place collectives);
* ``backend="auto"``      — pallas on TPU, xla elsewhere.

The custom VJP wires the paper's three operations exactly as the hardware
does (Fig. 3): FF = ``csd_spmm_fwd``, BP = ``csd_spmm_dx`` over the
*transpose* pattern, UP = ``csd_spmm_dw``; all three share one weight
layout, the paper's single weight memory bank.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_pattern import BlockPattern
from . import csd_spmm, ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # no backend yet
        return False


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


# Static pattern arrays are hashed by id for custom_vjp staticness; wrap them
# in a hashable carrier.
class _Pat:
    """Hashable wrapper for the static pattern (numpy arrays)."""

    def __init__(self, bp: BlockPattern):
        self.block_idx = np.asarray(bp.block_idx, np.int32)
        self.out_idx = np.asarray(bp.out_idx, np.int32)
        self.out_slot = np.asarray(bp.out_slot, np.int32)
        self.block_in = bp.block_in
        self.block_out = bp.block_out
        self._key = (self.block_idx.tobytes(), self.out_idx.tobytes(),
                     bp.block_in, bp.block_out)

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, _Pat) and self._key == other._key


# ---------------------------------------------------------------------------
# Slot-wise XLA implementations. The naive gather-einsum oracle (ref.py)
# materializes the activations expanded per (right-block, fan-in slot) —
# an O(n_rb * d_in_b * bL / n_in) blowup (200x+ for narrow output blocks).
# Processing one fan-in slot at a time keeps the peak at one output-sized
# intermediate: this is the XLA analogue of the kernel's grid loop over f,
# and exactly the paper's "one sweep at a time" schedule (§III-B).
# ---------------------------------------------------------------------------


def _xla_fwd(x, w, pat):
    """x: (..., n_in) — leading dims preserved so GSPMD keeps their
    (batch, seq) sharding through the take/einsum chain (flattening them
    merges sharded axes and the partitioner gives up -> full replication)."""
    n_rb, d_in_b, bl, br = w.shape
    lead = x.shape[:-1]
    xb = x.reshape(lead + (-1, bl))
    idx = jnp.asarray(pat.block_idx.T)  # (d_in_b, n_rb)

    def slot(acc, inp):
        idx_f, w_f = inp
        lhs = jnp.take(xb, idx_f, axis=-2)  # (..., n_rb, bL)
        y_f = jnp.einsum("...ri,rio->...ro", lhs, w_f.astype(lhs.dtype))
        return acc + y_f.astype(acc.dtype), None

    # cross-slot accumulator: each dot already accumulates in f32
    # internally; for few slots a bf16 running sum halves the dominant
    # accumulator HBM traffic at negligible numeric cost
    acc_dt = x.dtype if (x.dtype == jnp.bfloat16 and d_in_b <= 8) \
        else (jnp.float32 if x.dtype == jnp.bfloat16 else x.dtype)
    acc0 = jnp.zeros(lead + (n_rb, br), acc_dt)
    if d_in_b <= 4:
        for f in range(d_in_b):
            acc0, _ = slot(acc0, (idx[f], w[:, f]))
        y = acc0
    else:
        y, _ = jax.lax.scan(slot, acc0, (idx, jnp.moveaxis(w, 1, 0)))
    return y.reshape(lead + (n_rb * br,)).astype(x.dtype)


def _xla_dx(dy, w, pat):
    n_rb, d_in_b, bl, br = w.shape
    n_lb, d_out_b = pat.out_idx.shape
    lead = dy.shape[:-1]
    dyb = dy.reshape(lead + (n_rb, br))
    oidx = jnp.asarray(pat.out_idx.T)    # (d_out_b, n_lb)
    oslot = jnp.asarray(pat.out_slot.T)

    def slot(acc, inp):
        oi, os = inp
        lhs = jnp.take(dyb, oi, axis=-2)            # (..., n_lb, bR)
        w_g = w[oi, os].astype(lhs.dtype)           # (n_lb, bL, bR)
        d = jnp.einsum("...lo,lio->...li", lhs, w_g)
        return acc + d.astype(acc.dtype), None

    acc_dt = dy.dtype if (dy.dtype == jnp.bfloat16 and d_out_b <= 8) \
        else (jnp.float32 if dy.dtype == jnp.bfloat16 else dy.dtype)
    acc0 = jnp.zeros(lead + (n_lb, bl), acc_dt)
    if d_out_b <= 4:
        for g in range(d_out_b):
            acc0, _ = slot(acc0, (oidx[g], oslot[g]))
        dx = acc0
    else:
        dx, _ = jax.lax.scan(slot, acc0, (oidx, oslot))
    return dx.reshape(lead + (n_lb * bl,)).astype(dy.dtype)


def _xla_dw(x, dy, pat):
    n_rb, d_in_b = pat.block_idx.shape
    bl, br = pat.block_in, pat.block_out
    lead = x.shape[:-1]
    xb = x.reshape(lead + (-1, bl))
    dyb = dy.reshape(lead + (n_rb, br))
    idx = jnp.asarray(pat.block_idx.T)

    def slot(_, idx_f):
        lhs = jnp.take(xb, idx_f, axis=-2)  # (..., n_rb, bL)
        return None, jnp.einsum("...ri,...ro->rio",
                                lhs, dyb.astype(lhs.dtype))

    if d_in_b <= 4:
        dws = [slot(None, idx[f])[1] for f in range(d_in_b)]
        dw = jnp.stack(dws, axis=1)
    else:
        _, dws = jax.lax.scan(slot, None, idx)
        dw = jnp.moveaxis(dws, 0, 1)
    return dw.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _csd_matmul(x, w, pat: _Pat, backend: str, block_m: int, interpret: bool):
    return _fwd_impl(x, w, pat, backend, block_m, interpret)


def _fwd_impl(x, w, pat, backend, block_m, interpret):
    if backend == "pallas":
        return csd_spmm.csd_spmm_fwd(x, w, pat.block_idx, block_m=block_m,
                                     interpret=interpret)
    return _xla_fwd(x, w, pat)


def _fwd_vjp(x, w, pat, backend, block_m, interpret):
    y = _fwd_impl(x, w, pat, backend, block_m, interpret)
    return y, (x, w)


def _bwd_vjp(pat, backend, block_m, interpret, res, dy):
    x, w = res
    # keep backward slot traffic in the compute dtype — f32 cotangents
    # double the (already dominant) gather/accumulate HBM bytes
    dy = dy.astype(x.dtype)
    if backend == "pallas":
        dx = csd_spmm.csd_spmm_dx(dy, w, pat.out_idx, pat.out_slot,
                                  block_m=block_m, interpret=interpret)
        dw = csd_spmm.csd_spmm_dw(x, dy, pat.block_idx,
                                  block_in=pat.block_in,
                                  block_out=pat.block_out,
                                  block_m=block_m, interpret=interpret)
    else:
        dx = _xla_dx(dy, w, pat)
        dw = _xla_dw(x, dy, pat)
    return dx, dw.astype(w.dtype)


_csd_matmul.defvjp(_fwd_vjp, _bwd_vjp)


def csd_matmul(
    x: jax.Array,
    w: jax.Array,
    pattern: BlockPattern,
    *,
    backend: str = "auto",
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Differentiable block-sparse matmul: (..., n_in) -> (..., n_out).

    Leading dims are flattened to M; M is padded to ``block_m`` for the
    Pallas path. The pattern is compile-time static.
    """
    backend = _resolve(backend)
    pat = _Pat(pattern)
    if backend == "pallas":
        lead = x.shape[:-1]
        n_in = x.shape[-1]
        xf = x.reshape(-1, n_in)
        m = xf.shape[0]
        pad = (-m) % block_m
        if pad:
            xf = jnp.pad(xf, ((0, pad), (0, 0)))
        y = _csd_matmul(xf, w, pat, backend, block_m, interpret)
        if pad:
            y = y[:m]
        return y.reshape(lead + (y.shape[-1],))
    # xla: leading dims flow through untouched (sharding preserved)
    return _csd_matmul(x, w, pat, backend, block_m, interpret)
