"""Pass 1 — grid/race analysis of the shipped Pallas kernels.

Symbolically enumerates every grid point of a captured launch (see
``capture.py``) and proves, per kernel:

* **SL101 (races)** — the set of grid steps writing one output tile must be
  a *contiguous run* in the TPU's sequential grid order. Pallas keeps an
  output block resident in VMEM only across consecutive steps that map to
  the same block; a non-consecutive revisit means the tile was flushed and
  the revisit clobbers (not accumulates) — the silent-wrong-gradient class.
  This is the block-level form of the paper's clash-freedom proof: the FPGA
  flow statically checks no two parallel lanes hit one memory bank, we
  check no two non-adjacent grid steps hit one VMEM tile.
* **SL102/SL105 (shape safety)** — every BlockSpec's block shape divides
  the bound array dim (entry points pad M before launching; the check sees
  post-pad operand shapes, so an unpadded path fails loudly here), and
  every evaluated index map stays inside the array. Out-of-range pattern
  entries (a corrupt ``block_idx``) surface as SL105.
* **SL103 (epilogue)** — kernels that fuse bias/activation on the *last
  fan-in slot* declare their epilogue grid axis; the pass proves each
  output tile's final visit carries ``idx[axis] == size-1`` and that the
  tile is visited exactly ``size`` times — the "epilogue fires once, last"
  contract the fused-VJP relies on.
* **SL104 (VMEM budget)** — per-step working set: double-buffered in/out
  blocks plus scratch must fit the configured budget (default half of the
  ~16 MiB/core TPU VMEM, leaving headroom for Mosaic's own allocations).

Also emits a ``pl.CostEstimate``-style report per kernel: grid size, HBM
bytes actually streamed (consecutive same-block steps stream nothing — the
quantity the accumulation ordering optimizes), and the per-step VMEM high
water mark.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .capture import CapturedLaunch, capture_launch
from .findings import Finding

# Default per-core VMEM budget for SL104: TPU cores carry ~16 MiB of VMEM;
# Mosaic needs headroom for semaphores/metadata, so certify against half.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


@dataclasses.dataclass
class KernelCase:
    """One registry entry: how to capture a kernel and what it promises."""

    name: str
    build: Callable[[], CapturedLaunch]
    # grid axis whose last index fires the fused epilogue (None = no fused
    # epilogue contract to check)
    epilogue_axis: Optional[int] = None
    # output indices the epilogue contract applies to (default: all)
    epilogue_outputs: Optional[Tuple[int, ...]] = None


def _spec_block_shape(spec) -> Optional[Tuple[int, ...]]:
    bs = getattr(spec, "block_shape", None)
    if bs is None:
        return None
    return tuple(int(b) for b in bs)


def analyze_launch(launch: CapturedLaunch, case: KernelCase,
                   vmem_budget: int = DEFAULT_VMEM_BUDGET
                   ) -> Tuple[List[Finding], dict]:
    """Run all grid-pass checks on one captured launch."""
    findings: List[Finding] = []
    subject = case.name
    grid = launch.grid
    specs = (
        [("in", i, s, launch.in_shapes[i])
         for i, s in enumerate(launch.in_specs)]
        + [("out", i, s, launch.out_shapes[i])
           for i, s in enumerate(launch.out_specs)])

    # -- SL102: block divisibility + SL104 static VMEM accounting ---------
    vmem_bytes = 0
    for kind, i, spec, (shape, dtype) in specs:
        bs = _spec_block_shape(spec)
        if bs is None:
            continue
        if len(bs) != len(shape):
            findings.append(Finding(
                "SL102", subject,
                f"{kind}[{i}] block rank {len(bs)} != array rank "
                f"{len(shape)}", {"block": bs, "shape": shape}))
            continue
        for d, (dim, blk) in enumerate(zip(shape, bs)):
            if blk <= 0 or dim % blk:
                findings.append(Finding(
                    "SL102", subject,
                    f"{kind}[{i}] block dim {d}: {blk} does not divide "
                    f"array dim {dim} (implicit pad is not masked)",
                    {"block": bs, "shape": shape}))
        # in/out blocks are double-buffered by the Pallas pipeline
        vmem_bytes += 2 * int(np.prod(bs)) * np.dtype(dtype).itemsize
    for shape, dtype in launch.scratch_shapes:
        vmem_bytes += int(np.prod(shape)) * np.dtype(dtype).itemsize
    if vmem_bytes > vmem_budget:
        findings.append(Finding(
            "SL104", subject,
            f"per-step VMEM working set {vmem_bytes} B exceeds budget "
            f"{vmem_budget} B",
            {"vmem_bytes": vmem_bytes, "budget": vmem_budget}))

    # enumerate the grid once; evaluate every index map at every point
    steps = list(np.ndindex(*grid)) if grid else [()]
    visits: List[dict] = [dict() for _ in launch.out_specs]
    streamed = {f"{kind}{i}": 0 for kind, i, _, _ in specs}
    prev_block = {}
    bad_maps = set()
    for lin, step in enumerate(steps):
        for kind, i, spec, (shape, dtype) in specs:
            bs = _spec_block_shape(spec)
            if bs is None or (kind, i) in bad_maps:
                continue
            try:
                coords = launch.eval_index_map(spec, step)
            except Exception as e:  # index map itself is broken
                findings.append(Finding(
                    "SL105", subject,
                    f"{kind}[{i}] index map failed at grid point "
                    f"{step}: {e}", {}))
                bad_maps.add((kind, i))
                continue
            if len(coords) != len(bs):
                findings.append(Finding(
                    "SL105", subject,
                    f"{kind}[{i}] index map returned {len(coords)} coords "
                    f"for rank-{len(bs)} block", {"coords": coords}))
                bad_maps.add((kind, i))
                continue
            oob = [d for d, (c, blk, dim) in
                   enumerate(zip(coords, bs, shape))
                   if c < 0 or (c * blk + blk) > dim + (blk - dim % blk) % blk]
            if oob:
                findings.append(Finding(
                    "SL105", subject,
                    f"{kind}[{i}] block {coords} out of range for shape "
                    f"{shape} at grid point {step}",
                    {"dims": oob, "block": bs}))
                bad_maps.add((kind, i))
                continue
            key = f"{kind}{i}"
            if prev_block.get(key) != coords:
                streamed[key] += int(np.prod(bs)) * np.dtype(dtype).itemsize
                prev_block[key] = coords
            if kind == "out":
                visits[i].setdefault(coords, []).append(lin)

    # -- SL101: contiguous-visit (race) check -----------------------------
    for i, vmap in enumerate(visits):
        for coords, lins in vmap.items():
            if lins[-1] - lins[0] + 1 != len(lins):
                findings.append(Finding(
                    "SL101", subject,
                    f"out[{i}] tile {coords} written at non-consecutive "
                    f"grid steps {lins[0]}..{lins[-1]} ({len(lins)} "
                    f"visits): the tile leaves VMEM between visits and "
                    f"the revisit clobbers the partial sum",
                    {"tile": coords, "first": lins[0], "last": lins[-1],
                     "visits": len(lins)}))

    # -- SL103: epilogue-on-last-fan-in-slot ------------------------------
    if case.epilogue_axis is not None and not any(
            f.code in ("SL101", "SL105") for f in findings):
        ax = case.epilogue_axis
        n_ax = grid[ax]
        outs = case.epilogue_outputs or tuple(range(len(launch.out_specs)))
        for i in outs:
            for coords, lins in visits[i].items():
                last_step = steps[lins[-1]]
                if last_step[ax] != n_ax - 1:
                    findings.append(Finding(
                        "SL103", subject,
                        f"out[{i}] tile {coords}: final visit has "
                        f"grid[{ax}]={last_step[ax]}, epilogue (fires at "
                        f"{n_ax - 1}) would be skipped or non-final",
                        {"tile": coords, "last_step": last_step}))
                elif len(lins) != n_ax:
                    findings.append(Finding(
                        "SL103", subject,
                        f"out[{i}] tile {coords} visited {len(lins)} "
                        f"times, expected one visit per fan-in slot "
                        f"({n_ax})", {"tile": coords}))

    cost = {
        "grid": tuple(grid),
        "steps": len(steps),
        "vmem_bytes_per_step": vmem_bytes,
        "hbm_bytes_streamed": sum(streamed.values()),
        "hbm_bytes_naive": sum(
            len(steps) * int(np.prod(_spec_block_shape(s)))
            * np.dtype(dt).itemsize
            for _, _, s, (_, dt) in specs
            if _spec_block_shape(s) is not None),
    }
    return findings, cost


# ---------------------------------------------------------------------------
# Kernel case registry: every shipped Pallas kernel family, captured with
# representative shapes (the production block aspect, small counts — the
# checks are per-block-structure, so small grids prove the same invariants
# the production grids rely on).
# ---------------------------------------------------------------------------


def _demo_pattern(block_in=128, block_out=128, n_lb=4, n_rb=4, rho=0.5,
                  seed=0):
    from ..core.block_pattern import make_block_pattern
    return make_block_pattern(
        n_lb * block_in, n_rb * block_out, rho,
        block_in=block_in, block_out=block_out, seed=seed)


def _shard_pattern():
    from ..core.block_pattern import partition_pattern
    bp = _demo_pattern()
    return partition_pattern(bp, 2).shards[0]


def _fwd_case(batched: bool, activation: Optional[str], name: str,
              save_preact: bool = False) -> KernelCase:
    def build():
        import jax.numpy as jnp
        from ..kernels import csd_spmm
        bp = _demo_pattern()
        m, bm = 256, 128
        x = jnp.zeros(((2,) if batched else ()) + (m, bp.n_in), jnp.float32)
        w = jnp.zeros(
            ((2,) if batched else ())
            + (bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out), jnp.float32)
        bias = jnp.zeros(((2,) if batched else ()) + (bp.n_out,),
                         jnp.float32)
        return capture_launch(
            csd_spmm.csd_spmm_fwd, x, w, bp.block_idx, bias=bias,
            activation=activation, save_preact=save_preact, block_m=bm,
            name=name)
    return KernelCase(name, build, epilogue_axis=3 if batched else 2)


def _dx_case(batched: bool, name: str, shard_local: bool = False
             ) -> KernelCase:
    def build():
        import jax.numpy as jnp
        from ..kernels import csd_spmm
        bp = _shard_pattern() if shard_local else _demo_pattern()
        m, bm = 256, 128
        dy = jnp.zeros(((2,) if batched else ()) + (m, bp.n_out),
                       jnp.float32)
        w = jnp.zeros(
            ((2,) if batched else ())
            + (bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out), jnp.float32)
        return capture_launch(
            csd_spmm.csd_spmm_dx, dy, w, bp.out_idx, bp.out_slot,
            out_valid=bp.out_valid, aux=dy, activation="relu", block_m=bm,
            name=name)
    return KernelCase(name, build)


def _dw_case(batched: bool, name: str) -> KernelCase:
    def build():
        import jax.numpy as jnp
        from ..kernels import csd_spmm
        bp = _demo_pattern()
        m, bm = 256, 128
        x = jnp.zeros(((2,) if batched else ()) + (m, bp.n_in), jnp.float32)
        dy = jnp.zeros(((2,) if batched else ()) + (m, bp.n_out),
                       jnp.float32)
        return capture_launch(
            csd_spmm.csd_spmm_dw, x, dy, bp.block_idx,
            block_in=bp.block_in, block_out=bp.block_out, aux=dy,
            activation="relu", want_db=True, block_m=bm, name=name)
    return KernelCase(name, build)


def _flash_case() -> KernelCase:
    def build():
        import jax.numpy as jnp
        from ..kernels.flash_attention import flash_attention
        q = jnp.zeros((2, 256, 4, 64), jnp.bfloat16)
        k = jnp.zeros((2, 256, 2, 64), jnp.bfloat16)
        return capture_launch(
            flash_attention, q, k, k, causal=True, window=128,
            name="flash_attention_fwd")
    return KernelCase("flash_attention_fwd", build, epilogue_axis=3)


def _paged_decode_case() -> KernelCase:
    def build():
        import jax.numpy as jnp
        import numpy as _np
        from ..kernels.flash_attention import _paged_decode_pallas
        b, hkv, g, dh, page, npg, pool = 2, 2, 2, 64, 8, 4, 9
        q = jnp.zeros((b, hkv, g, dh), jnp.bfloat16)
        kp = jnp.zeros((pool, page, hkv, dh), jnp.bfloat16)
        table = _np.full((b, npg), -1, _np.int32)
        table[0, :3] = [1, 4, 2]
        table[1, :2] = [0, 3]
        lengths = _np.array([19, 10], _np.int32)
        return capture_launch(
            _paged_decode_pallas, q, kp, kp, jnp.asarray(table),
            jnp.asarray(lengths), window=None, softcap=None, scale=1.0,
            interpret=True, name="paged_decode_attention")
    # the online-softmax finalize fires on the last page of each row
    return KernelCase("paged_decode_attention", build, epilogue_axis=2)


def kernel_cases() -> List[KernelCase]:
    """Every shipped Pallas kernel family (ISSUE 6 pass-1 scope)."""
    return [
        _fwd_case(False, "relu", "csd_spmm_fwd_4d_relu"),
        _fwd_case(False, "gelu", "csd_spmm_fwd_4d_gelu_preact",
                  save_preact=True),
        _fwd_case(False, None, "csd_spmm_fwd_4d_plain"),
        _fwd_case(True, "relu", "csd_spmm_fwd_5d_batched"),
        _dx_case(False, "csd_spmm_dx_4d"),
        _dx_case(False, "csd_spmm_dx_4d_shardlocal", shard_local=True),
        _dx_case(True, "csd_spmm_dx_5d_batched"),
        _dw_case(False, "csd_spmm_dw_4d_db"),
        _dw_case(True, "csd_spmm_dw_5d_batched"),
        _flash_case(),
        _paged_decode_case(),
    ]


# ---------------------------------------------------------------------------
# Self-test injection: a deliberately broken copy of csd_spmm_fwd with the
# accumulation (fan-in) dimension hoisted OUTERMOST — every output tile is
# then revisited non-consecutively, the exact race SL101 certifies against.
# Used by `lint --selftest-inject` and the linter's own test suite to prove
# the pass catches the bug class, never by production code.
# ---------------------------------------------------------------------------


def _aliased_fwd_copy(x, w, block_idx, *, block_m=128):
    import functools
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl  # noqa: F811 — patched copy
    from jax.experimental.pallas import tpu as pltpu
    from ..kernels.csd_spmm import _fwd_kernel
    m, n_in = x.shape
    n_rb, d_in_b, bl, br = w.shape
    grid = (d_in_b, m // block_m, n_rb)  # BUG: fan-in slot outermost
    kernel = functools.partial(_fwd_kernel, d_in_b=d_in_b, activation=None,
                               has_bias=False, save_preact=False)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, bl),
                             lambda f, i, r, idx: (i, idx[r, f])),
                pl.BlockSpec((1, 1, bl, br),
                             lambda f, i, r, idx: (r, f, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, br),
                                   lambda f, i, r, idx: (i, r)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, n_rb * br), jnp.float32),
        interpret=True,
    )(jnp.asarray(block_idx), x, w)
    return out


def injected_alias_case() -> KernelCase:
    def build():
        import jax.numpy as jnp
        bp = _demo_pattern()
        x = jnp.zeros((256, bp.n_in), jnp.float32)
        w = jnp.zeros((bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out),
                      jnp.float32)
        return capture_launch(_aliased_fwd_copy, x, w, bp.block_idx,
                              name="csd_spmm_fwd_injected_alias")
    return KernelCase("csd_spmm_fwd_injected_alias", build, epilogue_axis=0)


def run(vmem_budget: int = DEFAULT_VMEM_BUDGET,
        cases: Optional[Sequence[KernelCase]] = None,
        inject: bool = False) -> Tuple[List[Finding], dict, List[str]]:
    """Run the grid pass over the kernel registry.

    Returns (findings, cost-by-kernel, covered subjects).
    """
    findings: List[Finding] = []
    cost = {}
    covered = []
    cs = list(cases) if cases is not None else kernel_cases()
    if inject:
        cs.append(injected_alias_case())
    for case in cs:
        try:
            launch = case.build()
        except Exception as e:
            findings.append(Finding(
                "SL105", case.name,
                f"kernel capture failed: {type(e).__name__}: {e}", {}))
            continue
        f, c = analyze_launch(launch, case, vmem_budget)
        findings.extend(f)
        cost[case.name] = c
        covered.append(case.name)
    return findings, cost, covered
