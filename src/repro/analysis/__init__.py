"""sparselint — static certifier for the jax_pallas sparse stack.

Three passes (see ``repro.analysis.lint`` for the CLI):

* ``grid_pass``    — SL1xx Pallas grid/race/VMEM analysis
* ``jaxpr_pass``   — SL2xx jitted-hot-path lint (donation, collectives)
* ``pattern_pass`` — SL3xx BlockPattern/partition invariants

Submodules import jax lazily where the CLI needs to configure the
platform first; import them explicitly (``from repro.analysis import
pattern_pass``) rather than through package attributes.
"""

from .findings import Finding, Report, Suppression, apply_suppressions

__all__ = ["Finding", "Report", "Suppression", "apply_suppressions"]
