"""Finding model shared by every sparselint pass.

Each finding carries a stable *code* (``SL1xx`` grid pass, ``SL2xx`` jaxpr
pass, ``SL3xx`` pattern pass), a *subject* (the kernel case / config /
pattern it was found in) and a human message. Codes are the unit of
suppression: a suppression entry names a code plus a subject substring and
a justification, and suppressed findings stay in the report (marked) but
do not fail the lint — the same contract as the FPGA flow the paper's
companion hardware uses, where every waived timing/bank check must carry a
sign-off note.

Code map (kept in sync with README.md "Static certification"):

=====  =====================================================================
SL101  output-tile aliasing: two non-consecutive grid steps write one tile
SL102  BlockSpec block shape does not divide the bound array dimension
SL103  fused epilogue does not fire exactly once, on the last fan-in slot
SL104  per-grid-step VMEM working set exceeds the budget
SL105  index map addresses a block outside the bound array
SL201  host-sync op (callback/infeed) inside a jitted hot path
SL202  large non-donated input buffer in a step executable
SL203  unintended wide-dtype promotion (float64/complex128) in a hot path
SL204  large closure-captured constant baked into the traced program
SL205  shard_map body lacks the collective its out-spec replication implies
SL206  whole int8 slab / KV pool upcast to full width inside a hot path
SL301  duplicate edge: one left block feeds the same right block twice
SL302  coverage hole: a left/right block with no surviving edges
SL303  scatter form (out_idx/out_slot/out_valid) disagrees with gather form
SL304  degree bound violation vs the paper's structured-sparsity constraint
SL305  per-shard slot counts unbalanced (SPMD shards would diverge in work)
SL401  tune-cache entry names an illegal configuration for its regime
SL402  tune-cache file/key unreadable (audit fails; runtime falls back)
=====  =====================================================================
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

# (code, subject substring, justification) entries mark findings as waived.
Suppression = Tuple[str, str, str]


@dataclasses.dataclass
class Finding:
    code: str           # e.g. "SL101"
    subject: str        # kernel case / config / pattern identifier
    message: str
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)
    suppressed: bool = False
    justification: Optional[str] = None

    def key(self) -> str:
        return f"{self.code}:{self.subject}"

    def to_dict(self) -> Dict[str, Any]:
        d = {"code": self.code, "subject": self.subject,
             "message": self.message, "detail": self.detail}
        if self.suppressed:
            d["suppressed"] = True
            d["justification"] = self.justification
        return d


def apply_suppressions(findings: Sequence[Finding],
                       suppressions: Sequence[Suppression]) -> List[Finding]:
    """Mark findings matched by a (code, subject-substring) entry."""
    out = []
    for f in findings:
        for code, subj, why in suppressions:
            if f.code == code and subj in f.subject:
                f = dataclasses.replace(f, suppressed=True,
                                        justification=why)
                break
        out.append(f)
    return out


@dataclasses.dataclass
class Report:
    """Full lint run result: findings plus per-kernel cost estimates."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    # kernel case name -> CostEstimate-style dict (grid, steps, flops
    # lower bound where known, bytes streamed, per-step VMEM bytes)
    cost: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)
    # pass name -> list of subjects covered (so "no findings" is
    # distinguishable from "never ran")
    covered: Dict[str, List[str]] = dataclasses.field(default_factory=dict)
    errors: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: Sequence[Finding]) -> None:
        self.findings.extend(findings)

    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings if not f.suppressed]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "findings": [f.to_dict() for f in self.findings],
            "n_findings": len(self.findings),
            "n_unsuppressed": len(self.unsuppressed()),
            "cost": self.cost,
            "covered": self.covered,
            "errors": self.errors,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, default=str)

    def to_text(self) -> str:
        lines = []
        for f in self.findings:
            tag = " [suppressed: %s]" % f.justification if f.suppressed \
                else ""
            lines.append(f"{f.code} {f.subject}: {f.message}{tag}")
            for k, v in f.detail.items():
                lines.append(f"    {k}: {v}")
        for name, cost in sorted(self.cost.items()):
            lines.append(f"cost {name}: " + ", ".join(
                f"{k}={v}" for k, v in cost.items()))
        for p, subjects in sorted(self.covered.items()):
            lines.append(f"covered[{p}]: {len(subjects)} subjects")
        for e in self.errors:
            lines.append(f"error: {e}")
        n_sup = len(self.findings) - len(self.unsuppressed())
        lines.append(
            f"sparselint: {len(self.unsuppressed())} finding(s), "
            f"{n_sup} suppressed")
        return "\n".join(lines)
