"""Pass 3 — BlockPattern / PartitionedPattern invariant checks.

These are the software form of the constraints the paper's hardware flow
certifies before synthesis: the interleaver (pattern) must be clash-free,
every neuron (block) must stay connected, and parallel lanes (shards) must
carry equal work. A pattern violating them doesn't crash — it trains to a
silently wrong or silently slower model — which is why the checks run
statically here and (behind ``debug=True``) at pattern construction time.

Checks:

* **SL301** — duplicate edge: one right block lists the same left block in
  two fan-in slots (gather form), or the scatter form emits one (right
  block, slot) cell twice. The MXU tile would be applied twice: wrong
  math, and the clash-free generator's whole point defeated.
* **SL302** — coverage hole: a left block feeding nothing or a right block
  fed by nothing (dead neurons by construction — §III's generators
  guarantee full coverage).
* **SL303** — scatter/gather disagreement: ``out_idx``/``out_slot`` (with
  ``out_valid`` honored) must be exactly the transpose of ``block_idx``.
  dx/BP consume the scatter form while FF consumes the gather form; a
  mismatch means forward and backward silently use different networks.
* **SL304** — degree/bounds: indices within range, fan-in degree uniform
  and ≤ n_lb, matching the structured-sparsity constraint (d_in fixed per
  junction) the paper's Appendix A density quantization assumes.
* **SL305** — shard imbalance: per-shard valid-slot counts must be equal
  (every SPMD shard runs the same program; unequal slot counts mean the
  padded width d_loc hides idle work on some devices and the slab
  row-split no longer matches ``NamedSharding``'s equal chunks).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding


def check_pattern(bp, subject: str) -> List[Finding]:
    """All single-pattern invariants for one ``BlockPattern``."""
    f: List[Finding] = []
    n_lb, n_rb = bp.n_lb, bp.n_rb
    idx = np.asarray(bp.block_idx)

    # SL304: shape + range sanity first — later checks assume it
    if idx.ndim != 2 or idx.shape[0] != n_rb:
        f.append(Finding("SL304", subject,
                         f"block_idx shape {idx.shape} != (n_rb={n_rb}, "
                         f"d_in_b)", {}))
        return f
    d_in_b = idx.shape[1]
    if d_in_b < 1 or d_in_b > n_lb:
        f.append(Finding("SL304", subject,
                         f"fan-in degree {d_in_b} outside [1, n_lb={n_lb}]",
                         {}))
    if idx.size and (idx.min() < 0 or idx.max() >= n_lb):
        f.append(Finding("SL304", subject,
                         f"block_idx entries outside [0, {n_lb}): "
                         f"min={idx.min()}, max={idx.max()}", {}))
        return f

    # SL301: duplicate edges in gather form
    for r in range(n_rb):
        row = idx[r]
        if len(np.unique(row)) != len(row):
            vals, counts = np.unique(row, return_counts=True)
            f.append(Finding(
                "SL301", subject,
                f"right block {r} lists left block(s) "
                f"{vals[counts > 1].tolist()} in multiple fan-in slots",
                {"row": r}))

    # SL302: coverage (every left block feeds something; right rows are
    # structurally covered since block_idx is dense, but check emptiness)
    used = np.zeros(n_lb, bool)
    used[idx.reshape(-1)] = True
    missing = np.flatnonzero(~used)
    if missing.size:
        f.append(Finding(
            "SL302", subject,
            f"{missing.size} left block(s) feed no right block "
            f"(dead input blocks): {missing[:8].tolist()}...",
            {"n_missing": int(missing.size)}))

    # SL303/SL301(scatter): scatter form must be the exact transpose
    oi = np.asarray(bp.out_idx)
    osl = np.asarray(bp.out_slot)
    ov = np.asarray(bp.out_valid) if bp.out_valid is not None else \
        np.ones_like(oi)
    if oi.shape != osl.shape or oi.shape[0] != n_lb:
        f.append(Finding("SL303", subject,
                         f"scatter form shapes {oi.shape}/{osl.shape} "
                         f"inconsistent with n_lb={n_lb}", {}))
        return f
    gather_edges = {(int(idx[r, s]), r, s)
                    for r in range(n_rb) for s in range(d_in_b)}
    scatter_edges = set()
    for lb in range(n_lb):
        for g in range(oi.shape[1]):
            if not ov[lb, g]:
                continue
            r, s = int(oi[lb, g]), int(osl[lb, g])
            if r < 0 or r >= n_rb or s < 0 or s >= d_in_b:
                f.append(Finding(
                    "SL304", subject,
                    f"scatter entry ({lb},{g}) -> (rb={r}, slot={s}) out "
                    f"of range", {}))
                continue
            e = (lb, r, s)
            if e in scatter_edges:
                f.append(Finding(
                    "SL301", subject,
                    f"scatter form emits (rb={r}, slot={s}) twice from "
                    f"left block {lb} — the tile would accumulate twice",
                    {"edge": e}))
            scatter_edges.add(e)
    if scatter_edges != gather_edges and not any(
            x.code == "SL304" for x in f):
        only_g = sorted(gather_edges - scatter_edges)[:4]
        only_s = sorted(scatter_edges - gather_edges)[:4]
        f.append(Finding(
            "SL303", subject,
            "scatter form disagrees with gather form (FF and BP would use "
            f"different networks); gather-only={only_g}, "
            f"scatter-only={only_s}",
            {"n_gather": len(gather_edges), "n_scatter": len(scatter_edges)}))
    return f


def check_partition(part, subject: str) -> List[Finding]:
    """Invariants for a ``PartitionedPattern``: every shard individually
    valid, shards disjointly cover the parent rows, and slot counts are
    balanced across shards (SL305)."""
    f: List[Finding] = []
    for s, shard in enumerate(part.shards):
        # SL302 does not apply per shard: a shard only reads the left
        # blocks its own output rows need; coverage is a union property
        f.extend(x for x in check_pattern(shard, f"{subject}/shard{s}")
                 if x.code != "SL302")
    used = np.zeros(part.parent.n_lb, bool)
    used[np.asarray(part.idx).reshape(-1)] = True
    if not used.all():
        f.append(Finding(
            "SL302", subject,
            f"{int((~used).sum())} left block(s) feed no shard at all "
            f"(union coverage hole): {np.flatnonzero(~used)[:8].tolist()}",
            {}))
    # disjoint full cover of the parent's rows
    ra = np.asarray(part.row_assign)
    counts = np.bincount(ra, minlength=part.n_shards)
    if len(set(counts.tolist())) != 1:
        f.append(Finding(
            "SL305", subject,
            f"row counts per shard unbalanced: {counts.tolist()} — SPMD "
            "shards must have equal local shapes", {}))
    perm_ok = sorted(np.asarray(part.perm).tolist()) == \
        list(range(part.parent.n_rb))
    if not perm_ok:
        f.append(Finding(
            "SL305", subject,
            "perm is not a permutation of the parent block-rows", {}))
    # valid-slot balance: total real work per shard must match, else some
    # devices idle inside the padded d_loc width every step
    ov = np.asarray(part.out_valid)
    slot_counts = ov.reshape(part.n_shards, -1).sum(axis=1)
    if len(set(slot_counts.tolist())) != 1:
        f.append(Finding(
            "SL305", subject,
            f"valid scatter-slot counts per shard unbalanced: "
            f"{slot_counts.tolist()} (padded width d_loc="
            f"{ov.shape[-1]} hides idle lanes)",
            {"slots": slot_counts.tolist()}))
    return f


# ---------------------------------------------------------------------------
# Collection: find every pattern a registered config can produce by building
# the model (pattern construction is eager and parameter-free) and walking
# the module graph for BlockPattern attributes.
# ---------------------------------------------------------------------------


def collect_patterns(config_names: Optional[Sequence[str]] = None
                     ) -> List[Tuple[str, object]]:
    """(subject, BlockPattern) for every junction every registered config
    instantiates (smoke variants: same structural flags, small dims)."""
    from ..configs import ARCHS, get_config
    from ..core.block_pattern import BlockPattern
    from ..nn.model import build_model

    out: List[Tuple[str, object]] = []
    for name in (config_names or ARCHS):
        model = build_model(get_config(name, smoke=True))
        seen = set()
        stack = [(name, model)]
        while stack:
            path, obj = stack.pop()
            if id(obj) in seen:
                continue
            seen.add(id(obj))
            if isinstance(obj, BlockPattern):
                out.append((path, obj))
                continue
            if isinstance(obj, (list, tuple)):
                stack.extend((f"{path}[{i}]", v) for i, v in enumerate(obj))
            elif isinstance(obj, dict):
                stack.extend((f"{path}.{k}", v) for k, v in obj.items()
                             if isinstance(k, str))
            elif type(obj).__module__.startswith("repro."):
                d = getattr(obj, "__dict__", None)
                if d:
                    stack.extend((f"{path}.{k}", v) for k, v in d.items()
                                 if not k.startswith("_"))
    return out


def run(config_names: Optional[Sequence[str]] = None,
        shard_sizes: Sequence[int] = (2, 4)
        ) -> Tuple[List[Finding], List[str]]:
    """Run pattern invariants over every config-producible pattern plus the
    partitions the sharding policy would build for each mesh size."""
    from ..core.block_pattern import can_partition, partition_pattern

    findings: List[Finding] = []
    covered: List[str] = []
    # dedupe structurally identical junctions (same dims/degree/seed) so a
    # 24-layer stack doesn't re-check one pattern 24 times
    by_sig = {}
    for subject, bp in collect_patterns(config_names):
        sig = (bp.n_in, bp.n_out, bp.block_in, bp.block_out, bp.d_in_b,
               np.asarray(bp.block_idx).tobytes())
        by_sig.setdefault(sig, (subject, bp))
    for subject, bp in by_sig.values():
        findings.extend(check_pattern(bp, subject))
        covered.append(subject)
        for k in shard_sizes:
            if can_partition(bp, k):
                findings.extend(
                    check_partition(partition_pattern(bp, k),
                                    f"{subject}@shards{k}"))
                covered.append(f"{subject}@shards{k}")
    return findings, covered
