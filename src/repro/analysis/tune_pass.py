"""sparselint tune pass — SL4xx: audit a persisted autotuner cache.

The tuner's pre-bench gate (``repro.tune.certify``) proves SL101–SL105
for every Pallas candidate *before* it is ever measured, so an illegal
configuration cannot be cached by this repo's tuner. This pass closes
the remaining hole: a cache file is plain JSON on disk — hand-edited,
copied from another checkout, or written by a future buggy tuner — so
CI re-audits whatever file the run will actually consult:

* every cached ``csd_spmm`` Pallas entry is re-certified through the
  grid pass (the SL101–SL105 findings re-surface here, subject = the
  cache key);
* every entry's dispatch fields must be legal for its key's form —
  no dense winner for a quant/sharded regime, no unknown dataflow
  (SL401);
* unparseable keys / an unreadable cache file are reported (SL402)
  rather than silently skipped — runtime lookups tolerate corruption by
  design (graceful heuristic fallback), the *audit* must not.

Keys are parsed from their string form (``cache.junction_key`` et al.);
entries tuned on another device class are still audited — certification
is static capture, it never executes the kernel.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .findings import Finding


def _parse_key(key: str) -> Optional[dict]:
    parts = key.split("|")
    try:
        if parts[0] == "csd_spmm" and len(parts) == 10:
            return dict(
                op="csd_spmm", form=parts[1], m=int(parts[2][1:]),
                n_in=int(parts[3][2:]), n_out=int(parts[4][3:]),
                rho=float(parts[5][3:]), E=int(parts[6][1:]),
                dtype=parts[7], quant=parts[8] == "q1", device=parts[9])
        if parts[0] == "paged_decode" and len(parts) == 11:
            return dict(op="paged_decode", device=parts[10])
        if parts[0] == "fit_blocks" and len(parts) == 7:
            return dict(op="fit_blocks", device=parts[6])
    except (ValueError, IndexError):
        return None
    return None


def _audit_junction(key: str, parsed: dict, ent: dict) -> List[Finding]:
    allowed = {"pallas", "xla"} if (parsed["quant"]
                                    or "sharded" in parsed["form"]) \
        else {"pallas", "xla", "dense"}
    be = ent.get("backend")
    df = ent.get("dataflow", "gather")
    if be not in allowed or df not in ("gather", "scatter"):
        return [Finding(
            "SL401", key,
            f"illegal tuned entry: backend={be!r} dataflow={df!r} "
            f"(allowed backends for form {parsed['form']!r}, "
            f"quant={parsed['quant']}: {sorted(allowed)})")]
    if be != "pallas":
        return []
    from ..core.block_pattern import make_block_pattern
    from ..tune import certify
    from ..tune.tuner import bp_rho_cap
    bi = int(ent.get("block_in", 128))
    bo = int(ent.get("block_out", 128))
    try:
        bp = make_block_pattern(parsed["n_in"], parsed["n_out"],
                                bp_rho_cap(parsed["rho"]), block_in=bi,
                                block_out=bo, seed=0)
        ok, fs = certify.certify_junction(bp, parsed["m"],
                                          int(ent.get("block_m", 128)),
                                          E=parsed["E"])
    except Exception as e:
        return [Finding("SL401", key,
                        f"cached pallas entry cannot be re-certified: "
                        f"{type(e).__name__}: {e}")]
    if ok:
        return []
    return [dataclasses.replace(f, subject=key,
                                detail=dict(f.detail, case=f.subject))
            for f in fs]


def run(cache_path: Optional[str] = None
        ) -> Tuple[List[Finding], List[str]]:
    """Audit the tune cache at ``cache_path`` (default: the path runtime
    lookups resolve — ``REPRO_TUNE_CACHE`` or the XDG default). Returns
    ``(findings, covered_keys)``; a missing file is an empty, clean
    audit."""
    from ..tune import cache as tcache

    findings: List[Finding] = []
    covered: List[str] = []
    c = tcache.TuneCache(cache_path or tcache.default_path()).load()
    if c.load_error is not None:
        findings.append(Finding(
            "SL402", c.path,
            f"tune cache unreadable (runtime falls back to the "
            f"heuristic; the audit does not): {c.load_error}"))
        return findings, covered
    for key, ent in sorted(c.entries.items()):
        parsed = _parse_key(key)
        if parsed is None:
            findings.append(Finding("SL402", key,
                                    "unparseable tune-cache key"))
            continue
        covered.append(key)
        if parsed["op"] == "csd_spmm":
            findings.extend(_audit_junction(key, parsed, ent))
        elif parsed["op"] == "paged_decode":
            if ent.get("backend") not in ("pallas", "xla"):
                findings.append(Finding(
                    "SL401", key,
                    f"illegal tuned entry: backend="
                    f"{ent.get('backend')!r} (decode allows pallas/xla)"))
        elif parsed["op"] == "fit_blocks":
            bi, bo = ent.get("block_in"), ent.get("block_out")
            if not (isinstance(bi, int) and isinstance(bo, int)
                    and bi >= 32 and bo >= 32):
                findings.append(Finding(
                    "SL401", key,
                    f"illegal tile entry: block_in={bi!r} "
                    f"block_out={bo!r} (need ints >= 32)"))
    return findings, covered
