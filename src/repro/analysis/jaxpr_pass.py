"""Pass 2 — jaxpr lint of the jitted hot paths.

Traces the *real* executables — the trainer's jitted step (via
``Trainer._make_step``) and the serving engine's ``raw_step`` around
``LM.paged_step`` — for every registered config (smoke variant: same
structural flags, small dims; the jaxpr's *op population* is what we lint,
and that is scale-invariant). Under a forced multi-device mesh the traces
run inside ``mesh_context``, so the sharded junction ``shard_map`` bodies
appear in the jaxpr and get the collective check.

Checks:

* **SL201** — host-sync primitives (``pure_callback``/``io_callback``/
  ``debug_callback``/infeed/outfeed) inside a step: each one stalls the
  TPU pipeline on a host round-trip every step.
* **SL202** — donation: large inputs that the lowered executable does not
  alias to an output (``tf.aliasing_output``), and the regression class
  where a step donates *nothing* (double-buffered params/optimizer state
  = 2x HBM).
* **SL203** — wide-dtype creep: any float64/complex128 value in the
  traced program (a silent 2x memory + off-MXU penalty; nothing in this
  codebase should promote past f32).
* **SL204** — large closure-captured constants baked into the traced
  program. Python-side arrays that should be arguments (a recompile +
  HBM-resident-copy hazard every time the python value changes identity).
  The pattern index arrays are *meant* to be baked in (they define the
  program, per the paper's pre-defined sparsity premise) and stay far
  under the threshold.
* **SL205** — ``shard_map`` bodies whose out-specs drop a mesh axis that
  some input is mapped over, without any collective over that axis in the
  body. With ``check_vma=False`` (which the sharded junctions need), jax
  does NOT verify this — a missing ``psum`` yields per-device partial
  sums silently passed off as the full result (the PR-4 bug class).
* **SL206** — quantization-defeating upcast: a ``convert_element_type``
  whose int8 input is a *whole* registered slab / KV page pool (exact
  shape match against the traced step's int8 inputs, plus their
  shard-local variants). Dequantizing the full tensor up front
  materializes an f32 copy in HBM and erases the 4x bandwidth win the
  int8 path exists for; healthy paths convert only per-slot / per-page
  tiles (rank-3 slices in the XLA fallback, in-register tiles in the
  Pallas kernels), which never match a full-slab shape.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .findings import Finding

HOST_SYNC_PRIMS = {"pure_callback", "io_callback", "debug_callback",
                   "infeed", "outfeed"}
WIDE_DTYPES = ("float64", "complex128")
COLLECTIVE_PRIMS = {"psum", "pmax", "pmin", "all_gather", "all_to_all",
                    "reduce_scatter", "psum_scatter", "ppermute",
                    "pbroadcast"}
DEFAULT_CONST_THRESHOLD = 1 << 20   # 1 MiB
DEFAULT_DONATE_THRESHOLD = 1 << 20  # 1 MiB


# -- jaxpr walking ----------------------------------------------------------


def _sub_jaxprs(params: Dict[str, Any]):
    """Yield every jaxpr nested in an eqn's params (scan/cond/pjit/
    shard_map/custom_vjp bodies alike), as raw ``Jaxpr`` objects."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for x in vs:
            if hasattr(x, "jaxpr"):     # ClosedJaxpr
                yield x.jaxpr
            elif hasattr(x, "eqns"):    # raw Jaxpr
                yield x


def _iter_eqns(jaxpr, *, into_shard_map=True):
    """All eqns, depth first. ``into_shard_map=False`` stops at shard_map
    boundaries (their bodies get their own dedicated check)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "shard_map" and not into_shard_map:
            continue
        for sub in _sub_jaxprs(eqn.params):
            yield from _iter_eqns(sub, into_shard_map=into_shard_map)


def _collective_axes(jaxpr) -> Set[str]:
    """Mesh axis names any collective in ``jaxpr`` (recursively) reduces
    or permutes over."""
    axes: Set[str] = set()
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            for k in ("axes", "axis_name", "axis_index_groups_axis"):
                v = eqn.params.get(k)
                if v is None:
                    continue
                for a in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(a, str):
                        axes.add(a)
    return axes


def _names_axes(names) -> Set[str]:
    """Flatten a shard_map in_names/out_names entry ({dim: (axes,)}) to the
    set of mesh axes it maps."""
    out: Set[str] = set()
    for axes in dict(names).values():
        out.update(axes if isinstance(axes, (list, tuple)) else (axes,))
    return out


def lint_closed_jaxpr(closed, subject: str,
                      const_threshold: int = DEFAULT_CONST_THRESHOLD
                      ) -> List[Finding]:
    """SL201/SL203/SL204/SL205 over one traced program."""
    f: List[Finding] = []
    jaxpr = closed.jaxpr

    # SL204: large baked-in constants
    for c in getattr(closed, "consts", ()):
        nbytes = int(np.prod(getattr(c, "shape", ()) or (1,))) * \
            np.dtype(getattr(c, "dtype", np.float32)).itemsize
        if nbytes > const_threshold:
            f.append(Finding(
                "SL204", subject,
                f"closure-captured constant {getattr(c, 'shape', '?')} "
                f"{getattr(c, 'dtype', '?')} ({nbytes} B) baked into the "
                "traced program — pass it as an argument (recompile + "
                "resident-copy hazard)", {"bytes": nbytes}))

    seen_sync = set()
    seen_wide = set()
    for eqn in _iter_eqns(jaxpr):
        name = eqn.primitive.name
        # SL201: host-sync ops
        if name in HOST_SYNC_PRIMS and name not in seen_sync:
            seen_sync.add(name)
            cb = eqn.params.get("callback")
            f.append(Finding(
                "SL201", subject,
                f"host-sync primitive '{name}'"
                + (f" ({cb})" if cb is not None else "")
                + " inside the jitted step: stalls the device pipeline on "
                "a host round-trip every step", {}))
        # SL203: wide-dtype creep
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in WIDE_DTYPES and (name, dt) not in seen_wide:
                seen_wide.add((name, dt))
                f.append(Finding(
                    "SL203", subject,
                    f"primitive '{name}' produces {dt} "
                    f"{getattr(aval, 'shape', ())} — unintended wide-dtype "
                    "promotion", {"dtype": dt}))
        # SL205: shard_map missing-collective
        if name == "shard_map":
            f.extend(_lint_shard_map(eqn, subject))
    return f


def _int8_slab_shapes(closed, mesh) -> Set[Tuple[int, ...]]:
    """Shapes of whole int8 slabs / KV page pools entering the traced
    program (int8 inputs of rank >= 4), plus their shard-local variants:
    under the junction/cache shard_map the leading block-row (or expert /
    page) dim arrives divided by the model-axis size."""
    shapes: Set[Tuple[int, ...]] = set()
    n = int(mesh.shape["model"]) if mesh is not None \
        and "model" in mesh.axis_names else 1
    for var in closed.jaxpr.invars:
        aval = getattr(var, "aval", None)
        if aval is None or str(getattr(aval, "dtype", "")) != "int8" \
                or len(getattr(aval, "shape", ())) < 4:
            continue
        shapes.add(tuple(aval.shape))
        if n > 1:
            for d in (0, 1):
                if aval.shape[d] % n == 0:
                    local = list(aval.shape)
                    local[d] //= n
                    shapes.add(tuple(local))
    return shapes


def _lint_quant(closed, subject: str, mesh) -> List[Finding]:
    """SL206 over one traced program (no-op when it has no int8 slabs)."""
    slab_shapes = _int8_slab_shapes(closed, mesh)
    f: List[Finding] = []
    if not slab_shapes:
        return f
    seen: Set[Tuple[int, ...]] = set()
    for eqn in _iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        aval = getattr(eqn.invars[0], "aval", None)
        if aval is None or str(getattr(aval, "dtype", "")) != "int8":
            continue
        shp = tuple(getattr(aval, "shape", ()))
        if shp in slab_shapes and shp not in seen:
            seen.add(shp)
            f.append(Finding(
                "SL206", subject,
                f"whole int8 slab {shp} upcast to "
                f"{eqn.params.get('new_dtype')} — a full-width copy of "
                "the quantized tensor enters HBM traffic, erasing the "
                "int8 bandwidth win; dequantize per-slot/per-page inside "
                "the junction instead", {"shape": shp}))
    return f


def _lint_shard_map(eqn, subject: str) -> List[Finding]:
    f: List[Finding] = []
    params = eqn.params
    body = params.get("jaxpr")
    if hasattr(body, "jaxpr"):
        body = body.jaxpr
    if body is None:
        return f
    in_names = params.get("in_names") or ()
    out_names = params.get("out_names") or ()
    mapped_in: Set[str] = set()
    for names in in_names:
        mapped_in |= _names_axes(names)
    if not mapped_in:
        return f  # fully replicated body: no reduction obligation
    have = _collective_axes(body)
    for o, names in enumerate(out_names):
        missing = mapped_in - _names_axes(names) - have
        for ax in sorted(missing):
            f.append(Finding(
                "SL205", subject,
                f"shard_map out[{o}] is unmapped over mesh axis '{ax}' "
                f"but some input is mapped over it and the body has no "
                f"collective over '{ax}' — per-device partials would be "
                "passed off as the reduced result (check_vma=False hides "
                "this)", {"axis": ax, "out": o}))
    return f


# -- donation (SL202) -------------------------------------------------------

# the attr dict can contain quoted strings with nested braces, e.g.
# mhlo.sharding = "{devices=[2,4]<=[8]}" — consume strings atomically
_ARG_RE = re.compile(
    r"%arg(\d+): tensor<[^>]*>\s*(\{(?:[^}\"]|\"[^\"]*\")*\})?")


def lint_donation(lowered_text: str, in_avals, subject: str,
                  threshold: int = DEFAULT_DONATE_THRESHOLD
                  ) -> List[Finding]:
    """Parse the lowered StableHLO signature for ``tf.aliasing_output``
    markers and flag large non-donated inputs (``in_avals`` is the traced
    call's argument pytree of ShapeDtypeStructs)."""
    import jax

    f: List[Finding] = []
    seen: Dict[int, bool] = {}
    for m in _ARG_RE.finditer(lowered_text):
        i = int(m.group(1))
        if i not in seen:
            attrs = m.group(2) or ""
            seen[i] = ("tf.aliasing_output" in attrs
                       or "jax.buffer_donor" in attrs)
    if not seen:
        return f
    donated = {i for i, d in seen.items() if d}
    leaves = jax.tree_util.tree_flatten_with_path(in_avals)[0]
    if not donated:
        f.append(Finding(
            "SL202", subject,
            "step executable donates no input buffer at all — params/"
            "optimizer/cache state is double-buffered in HBM every step",
            {"n_args": len(seen)}))
        return f
    if len(leaves) != len(seen):
        return f  # pruned/unflattened args: index mapping unreliable
    for i, (path, aval) in enumerate(leaves):
        if i in donated:
            continue
        nbytes = int(np.prod(aval.shape or (1,))) * \
            np.dtype(aval.dtype).itemsize
        if nbytes > threshold:
            f.append(Finding(
                "SL202", subject,
                f"input {jax.tree_util.keystr(path)} "
                f"({aval.shape} {aval.dtype}, {nbytes} B) is not donated",
                {"bytes": nbytes}))
    return f


# -- tracing the registered configs ----------------------------------------


def _train_subject(name: str) -> str:
    return f"train_step[{name}]"


def _trace_train(name: str, mesh) -> Tuple[Any, Any, str]:
    """Trace the real trainer step for one config. Returns
    (traced, in_avals, subject)."""
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..nn.common import mesh_context
    from ..nn.model import build_model
    from ..optim import adam
    from ..train.trainer import Trainer, TrainerConfig

    cfg = get_config(name, smoke=True)
    model = build_model(cfg)
    # batch divisible by the full mesh (the batch rule shards it over
    # every data-like axis)
    b, s = 8, 32
    batch = {"tokens": np.zeros((b, s), np.int32),
             "labels": np.zeros((b, s), np.int32)}
    if cfg.input_mode == "embeddings" or cfg.enc_dec is not None:
        batch["embeds"] = np.zeros((b, s, cfg.frontend_dim), np.float32)
    trainer = Trainer(model, TrainerConfig(), mesh=mesh)
    step = trainer._make_step(batch)
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    o_avals = jax.eval_shape(adam.init, p_avals)
    b_avals = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape,
                                                          x.dtype), batch)
    args = (p_avals, o_avals, b_avals)
    if mesh is not None:
        with mesh, mesh_context(mesh, trainer.rules):
            traced = step.trace(*args)
    else:
        traced = step.trace(*args)
    return traced, args, _train_subject(name)


def _trace_paged(name: str, mesh) -> Optional[Tuple[Any, Any, str]]:
    """Trace the serving engine's step (``LM.paged_step`` under the
    engine's ``raw_step``/donation contract). None for configs that do not
    serve through the paged path (frontends / enc-dec)."""
    import jax

    from ..configs import get_config
    from ..nn.common import dtype_of, mesh_context
    from ..nn.model import build_model
    from ..sharding import policy

    cfg = get_config(name, smoke=True)
    if cfg.input_mode != "tokens" or cfg.enc_dec is not None:
        return None
    model = build_model(cfg)
    slots, pages, page_size, max_pages = 2, 8, 16, 4
    cache_avals = jax.eval_shape(
        lambda: model.stack.init_paged_cache(slots, pages, page_size,
                                             dtype_of(cfg)))
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    i32 = np.int32

    def raw_step(params, cache, page_table, tokens, pos, n_new, slot_ids):
        return model.paged_step(params, tokens, pos, n_new, cache,
                                page_table, slot_ids, backend="auto",
                                interpret=True)

    step = jax.jit(raw_step, donate_argnums=(1,))
    args = (p_avals, cache_avals,
            jax.ShapeDtypeStruct((slots, max_pages), i32),
            jax.ShapeDtypeStruct((slots, 1), i32),
            jax.ShapeDtypeStruct((slots,), i32),
            jax.ShapeDtypeStruct((slots,), i32),
            jax.ShapeDtypeStruct((slots,), i32))
    if mesh is not None:
        rules = policy.rules_for("decode", slots, mesh, cfg)
        with mesh, mesh_context(mesh, rules):
            traced = step.trace(*args)
    else:
        traced = step.trace(*args)
    return traced, args, f"paged_step[{name}]"


def _trace_verify(name: str, mesh) -> Optional[Tuple[Any, Any, str]]:
    """Trace the engine's speculative verify step: ``paged_step`` over a
    ``1 + spec_k`` token chunk with ``all_logits=True`` (greedy
    acceptance needs per-position logits, not just the last row). This
    is a distinct executable from the C==1 decode step — different token
    width, different attention path (chunk instead of paged-decode
    kernel) — so it is linted as its own subject. None for configs the
    engine never speculates on: recurrent (mamba) state cannot be rolled
    back, so ``spec_k`` is clamped to 0 there."""
    import jax

    from ..configs import get_config
    from ..nn.common import dtype_of, mesh_context
    from ..nn.model import build_model
    from ..sharding import policy

    cfg = get_config(name, smoke=True)
    if cfg.input_mode != "tokens" or cfg.enc_dec is not None:
        return None
    if "mamba" in cfg.layer_kinds:
        return None
    model = build_model(cfg)
    slots, pages, page_size, max_pages = 2, 8, 16, 4
    spec_k = 4
    cache_avals = jax.eval_shape(
        lambda: model.stack.init_paged_cache(slots, pages, page_size,
                                             dtype_of(cfg)))
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    i32 = np.int32

    def raw_verify(params, cache, page_table, tokens, pos, n_new,
                   slot_ids):
        return model.paged_step(params, tokens, pos, n_new, cache,
                                page_table, slot_ids, backend="auto",
                                interpret=True, all_logits=True)

    step = jax.jit(raw_verify, donate_argnums=(1,))
    args = (p_avals, cache_avals,
            jax.ShapeDtypeStruct((slots, max_pages), i32),
            jax.ShapeDtypeStruct((slots, 1 + spec_k), i32),
            jax.ShapeDtypeStruct((slots,), i32),
            jax.ShapeDtypeStruct((slots,), i32),
            jax.ShapeDtypeStruct((slots,), i32))
    if mesh is not None:
        rules = policy.rules_for("decode", slots, mesh, cfg)
        with mesh, mesh_context(mesh, rules):
            traced = step.trace(*args)
    else:
        traced = step.trace(*args)
    return traced, args, f"spec_verify[{name}]"


def _trace_quant(name: str, mesh) -> Optional[Tuple[Any, Any, str]]:
    """Trace the *quantized* serving step: params through
    ``quantize_tree`` (int8 slabs + per-block scales) and the paged cache
    built with ``quant_kv=True`` (int8 pages + per-token scales). The
    trace proves the executable the int8 engine actually runs keeps the
    slabs quantized end to end (SL206) on top of the standard SL20x
    checks. None for configs whose smoke variant has no block-sparse
    junction to quantize — there would be nothing int8 in the program."""
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..core.quant import quantize_tree
    from ..nn.common import dtype_of, mesh_context
    from ..nn.model import build_model
    from ..sharding import policy

    cfg = get_config(name, smoke=True)
    if cfg.input_mode != "tokens" or cfg.enc_dec is not None:
        return None
    model = build_model(cfg)
    spec = model.spec()
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    q_avals = jax.eval_shape(lambda p: quantize_tree(p, spec)[0], p_avals)
    if not any(l.dtype == jnp.int8 for l in jax.tree.leaves(q_avals)):
        return None
    slots, pages, page_size, max_pages = 2, 8, 16, 4
    cache_avals = jax.eval_shape(
        lambda: model.stack.init_paged_cache(slots, pages, page_size,
                                             dtype_of(cfg), quant_kv=True))
    i32 = np.int32

    def raw_step(params, cache, page_table, tokens, pos, n_new, slot_ids):
        return model.paged_step(params, tokens, pos, n_new, cache,
                                page_table, slot_ids, backend="auto",
                                interpret=True)

    step = jax.jit(raw_step, donate_argnums=(1,))
    args = (q_avals, cache_avals,
            jax.ShapeDtypeStruct((slots, max_pages), i32),
            jax.ShapeDtypeStruct((slots, 1), i32),
            jax.ShapeDtypeStruct((slots,), i32),
            jax.ShapeDtypeStruct((slots,), i32),
            jax.ShapeDtypeStruct((slots,), i32))
    if mesh is not None:
        rules = policy.rules_for("decode", slots, mesh, cfg)
        with mesh, mesh_context(mesh, rules):
            traced = step.trace(*args)
    else:
        traced = step.trace(*args)
    return traced, args, f"quant_step[{name}]"


def _trace_quant_inject(mesh) -> Tuple[Any, Any, str]:
    """Selftest subject: a deliberately quantization-defeating junction
    that dequantizes the WHOLE int8 slab up front and feeds the f32 copy
    to ``csd_matmul``. The full-slab ``convert_element_type`` this
    produces MUST trip SL206 — CI runs it to prove the gate has teeth."""
    import jax
    import jax.numpy as jnp

    from ..core.block_pattern import make_block_pattern
    from ..core.quant import dequantize_slab
    from ..kernels import ops as kops

    bp = make_block_pattern(64, 64, 0.5, block_in=16, block_out=16, seed=0)
    x_aval = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    w_aval = jax.ShapeDtypeStruct(
        (bp.n_rb, bp.d_in_b, 16, 16), jnp.int8)
    s_aval = jax.ShapeDtypeStruct((bp.n_rb, bp.d_in_b), jnp.float32)

    def bad(x, w, s):
        return kops.csd_matmul(x, dequantize_slab(w, s), bp,
                               backend="xla")

    traced = jax.jit(bad).trace(x_aval, w_aval, s_aval)
    return traced, (x_aval, w_aval, s_aval), "quant_inject[selftest]"


def run(config_names: Optional[Sequence[str]] = None,
        mesh_shape: Tuple[int, int] = (2, 4),
        const_threshold: int = DEFAULT_CONST_THRESHOLD,
        donate_threshold: int = DEFAULT_DONATE_THRESHOLD,
        inject: bool = False
        ) -> Tuple[List[Finding], List[str], List[str]]:
    """Lint the train and paged-serve steps of every registered config.

    Returns (findings, covered subjects, errors). A config that fails to
    trace is an *error* (gating): a hot path the linter cannot see is not
    a certified hot path.
    """
    import jax

    from ..configs import ARCHS

    n_dev = len(jax.devices())
    need = int(np.prod(mesh_shape))
    mesh = None
    errors: List[str] = []
    if n_dev >= need:
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        errors.append(
            f"sharded-path lint skipped: {n_dev} device(s) < {need} "
            "(run via `python -m repro.analysis.lint`, which forces a "
            f"{need}-device host platform)")

    findings: List[Finding] = []
    covered: List[str] = []
    for name in (config_names or ARCHS):
        for tracer in (_trace_train, _trace_paged, _trace_verify,
                       _trace_quant):
            try:
                res = tracer(name, mesh)
            except Exception as e:
                errors.append(f"{tracer.__name__}[{name}]: "
                              f"{type(e).__name__}: {e}")
                continue
            if res is None:
                continue
            traced, in_avals, subject = res
            findings.extend(lint_closed_jaxpr(traced.jaxpr, subject,
                                              const_threshold))
            findings.extend(_lint_quant(traced.jaxpr, subject, mesh))
            try:
                text = traced.lower().as_text()
            except Exception as e:
                errors.append(f"lower[{subject}]: {type(e).__name__}: {e}")
            else:
                findings.extend(lint_donation(text, in_avals, subject,
                                              donate_threshold))
            covered.append(subject)
    if inject:
        try:
            traced, _, subject = _trace_quant_inject(mesh)
        except Exception as e:
            errors.append(f"_trace_quant_inject: {type(e).__name__}: {e}")
        else:
            findings.extend(_lint_quant(traced.jaxpr, subject, mesh))
            covered.append(subject)
    return findings, covered, errors
