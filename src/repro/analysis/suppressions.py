"""Checked-in suppression table for sparselint.

Every entry waives one finding class on one subject and MUST carry a
justification — the same discipline as a timing-constraint waiver in the
FPGA flow the paper's hardware companion uses. Entries are
``(code, subject-substring, justification)``; a finding is suppressed when
its code matches exactly and the substring occurs in its subject. The
finding stays in the report, marked suppressed, so waivers are visible in
every CI artifact.

Add entries here (with a comment) rather than passing ``--no-suppress``
exceptions around; the lint CI gate reads exactly this table.
"""
from __future__ import annotations

from typing import List

from .findings import Suppression

SUPPRESSIONS: List[Suppression] = [
    # The decode kernel walks the page pool through a page table whose
    # unused entries are -1, clamped to page 0 in the index map; grid rows
    # past a sequence's length therefore re-read page 0 and their output
    # contribution is masked by the in-kernel length predicate. The grid
    # pass sees the clamped revisits of kv page 0 as non-monotone input
    # streaming, which is real (and intentional: the pool has no "null
    # page") but touches only *inputs*; outputs are visited once.
    # -> nothing currently fires for this; kept as the worked example of
    #    the format. Remove when a first real waiver lands.
]
