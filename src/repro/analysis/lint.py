"""sparselint CLI: ``python -m repro.analysis.lint``.

Runs the three static passes over every shipped kernel and registered
config, on CPU, with no TPU time:

* grid pass  — SL1xx: Pallas grid races / divisibility / epilogue / VMEM
* jaxpr pass — SL2xx: host sync, donation, dtype creep, baked constants,
               shard_map missing collectives (forced 8-device mesh)
* pattern pass — SL3xx: BlockPattern / partition invariants

An optional fourth pass (``--passes ...,tune``) audits a persisted
``repro.tune`` dispatch cache — SL4xx: illegal tuned entries, plus SL1xx
re-certification of every cached Pallas configuration (``--tune-cache``
names the file; default is the path runtime lookups resolve).

Exits non-zero on any unsuppressed finding or any pass error (a hot path
the linter cannot trace is not a certified hot path). ``--selftest-inject``
adds a deliberately race-broken copy of ``csd_spmm_fwd`` to the grid pass
and a whole-slab-dequantizing junction to the jaxpr pass (SL206), and must
make the lint fail — CI runs it to prove the gate has teeth.

The forced-host-device environment (``--devices``, default 8) is set up
*before* jax is imported, which is why every pass imports jax lazily. When
jax is already imported (library use, pytest), the flag cannot take effect
and the sharded-path lint degrades gracefully (reported as an error unless
enough devices already exist).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional


def _force_devices(n: int) -> None:
    if "jax" in sys.modules:
        return  # too late; jaxpr pass will report if devices are short
    flag = f"--xla_force_host_platform_device_count={n}"
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in prev:
        os.environ["XLA_FLAGS"] = (prev + " " + flag).strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static certifier for Pallas grids, BlockPattern "
                    "invariants, and sharded-junction collectives")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--output", default=None,
                    help="write the report to this file as well as stdout")
    ap.add_argument("--passes", default="grid,jaxpr,pattern",
                    help="comma list from {grid,jaxpr,pattern,tune}")
    ap.add_argument("--tune-cache", default=None,
                    help="tune pass: cache file to audit (default: the "
                         "path runtime lookups resolve)")
    ap.add_argument("--configs", default=None,
                    help="comma list of arch names (default: all registered)")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="grid-pass VMEM budget in bytes (default 8 MiB)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count for the sharded lint")
    ap.add_argument("--no-suppress", action="store_true",
                    help="ignore the checked-in suppression table")
    ap.add_argument("--selftest-inject", action="store_true",
                    help="add a race-broken kernel copy; lint MUST fail")
    args = ap.parse_args(argv)

    _force_devices(args.devices)

    # deferred so _force_devices precedes the first jax import
    from . import grid_pass, jaxpr_pass, pattern_pass, tune_pass
    from .findings import Report, apply_suppressions
    from .suppressions import SUPPRESSIONS

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = set(passes) - {"grid", "jaxpr", "pattern", "tune"}
    if unknown:
        ap.error(f"unknown pass(es): {sorted(unknown)}")
    configs = [c.strip() for c in args.configs.split(",")] \
        if args.configs else None

    report = Report()
    if "grid" in passes:
        budget = args.vmem_budget or grid_pass.DEFAULT_VMEM_BUDGET
        f, cost, covered = grid_pass.run(vmem_budget=budget,
                                         inject=args.selftest_inject)
        report.extend(f)
        report.cost.update(cost)
        report.covered["grid"] = covered
    if "pattern" in passes:
        f, covered = pattern_pass.run(configs)
        report.extend(f)
        report.covered["pattern"] = covered
    if "jaxpr" in passes:
        f, covered, errors = jaxpr_pass.run(configs,
                                            inject=args.selftest_inject)
        report.extend(f)
        report.covered["jaxpr"] = covered
        report.errors.extend(errors)
    if "tune" in passes:
        f, covered = tune_pass.run(args.tune_cache)
        report.extend(f)
        report.covered["tune"] = covered

    if not args.no_suppress:
        report.findings = apply_suppressions(report.findings, SUPPRESSIONS)

    out = report.to_json() if args.format == "json" else report.to_text()
    print(out)
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(out + "\n")

    return 1 if (report.unsuppressed() or report.errors) else 0


if __name__ == "__main__":
    sys.exit(main())
