"""Capture harness: record ``pl.pallas_call`` launch specs without running.

The grid pass needs the *real* grids, BlockSpecs and index maps the shipped
kernel entry points construct — not a hand-maintained mirror that silently
drifts. We get them by patching ``pallas.pallas_call`` while invoking the
entry function with representative operands: the patched call records the
grid spec plus the concrete operands and aborts the launch by raising a
control-flow exception before anything executes. This is the software
analogue of extracting the address-generator netlist from the synthesized
design instead of re-deriving it from the HDL by hand.

Index maps are then *evaluated on the host* for every grid point (with the
actual scalar-prefetch operands — the pattern arrays — passed through,
exactly as Mosaic's scalar prefetch would), which is what makes the race /
divisibility / epilogue checks exact rather than heuristic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np
from jax.experimental import pallas as pl


class _CaptureSignal(Exception):
    """Control-flow: carries the captured launch out of the entry fn."""

    def __init__(self, launch: "CapturedLaunch"):
        super().__init__("pallas_call captured")
        self.launch = launch


def _aval(x) -> Tuple[Tuple[int, ...], Any]:
    shape = tuple(int(d) for d in x.shape)
    return shape, np.dtype(getattr(x, "dtype", np.float32))


@dataclasses.dataclass
class CapturedLaunch:
    """One recorded ``pl.pallas_call`` invocation."""

    name: str
    grid: Tuple[int, ...]
    in_specs: List[pl.BlockSpec]
    out_specs: List[pl.BlockSpec]
    out_shapes: List[Tuple[Tuple[int, ...], Any]]   # (shape, dtype)
    in_shapes: List[Tuple[Tuple[int, ...], Any]]    # post-prefetch operands
    scalar_args: List[np.ndarray]                   # prefetched operands
    scratch_shapes: List[Tuple[Tuple[int, ...], Any]]
    num_scalar_prefetch: int

    @property
    def n_steps(self) -> int:
        return int(np.prod(self.grid)) if self.grid else 1

    def eval_index_map(self, spec: pl.BlockSpec,
                       step: Sequence[int]) -> Tuple[int, ...]:
        """Evaluate one BlockSpec's index map at a grid point, feeding the
        scalar-prefetch operands through (their refs ARE the host arrays
        here). Returns concrete block coordinates."""
        out = spec.index_map(*step, *self.scalar_args)
        if not isinstance(out, tuple):
            out = (out,)
        return tuple(int(i) for i in out)


def _as_list(specs) -> list:
    if specs is None:
        return []
    if isinstance(specs, (list, tuple)):
        return list(specs)
    return [specs]


def _scratch_aval(s) -> Tuple[Tuple[int, ...], Any]:
    # pltpu.VMEM(...) scratch entries are MemoryRef-like: shape + dtype
    shape = tuple(int(d) for d in s.shape)
    return shape, np.dtype(s.dtype)


def capture_launch(fn: Callable, *args, name: Optional[str] = None,
                   **kwargs) -> CapturedLaunch:
    """Run ``fn(*args, **kwargs)`` with ``pl.pallas_call`` patched to record
    its launch spec; returns the first launch. The kernel never executes.
    """
    recorded: List[CapturedLaunch] = []
    real = pl.pallas_call

    def fake_pallas_call(kernel, *, grid_spec=None, grid=None, in_specs=None,
                         out_specs=None, out_shape=None, scratch_shapes=(),
                         interpret=False, **extra):
        nsp = 0
        if grid_spec is not None:
            grid_ = tuple(grid_spec.grid)
            ins = _as_list(grid_spec.in_specs)
            outs = _as_list(grid_spec.out_specs)
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
            scratch = _as_list(getattr(grid_spec, "scratch_shapes", ()) or ())
        else:
            grid_ = tuple(grid) if grid is not None else ()
            ins = _as_list(in_specs)
            outs = _as_list(out_specs)
            scratch = _as_list(scratch_shapes)
        oshapes = [_aval(s) for s in _as_list(out_shape)]

        def runner(*operands):
            scal = [np.asarray(o) for o in operands[:nsp]]
            launch = CapturedLaunch(
                name=name or getattr(kernel, "__name__",
                                     getattr(getattr(kernel, "func", None),
                                             "__name__", "kernel")),
                grid=grid_, in_specs=ins, out_specs=outs,
                out_shapes=oshapes,
                in_shapes=[_aval(o) for o in operands[nsp:]],
                scalar_args=scal,
                scratch_shapes=[_scratch_aval(s) for s in scratch],
                num_scalar_prefetch=nsp)
            recorded.append(launch)
            raise _CaptureSignal(launch)

        return runner

    pl.pallas_call = fake_pallas_call
    try:
        fn(*args, **kwargs)
    except _CaptureSignal:
        pass
    finally:
        pl.pallas_call = real
    if not recorded:
        raise RuntimeError(
            f"{fn!r} made no pallas_call — nothing to analyze")
    return recorded[0]
