"""Certification for repro.obs: registry semantics, replayable JSONL,
FLOP accounting parity with hand counts, and — the load-bearing contract —
jit purity: instrumentation must never change a traced program.

Layers:

* **registry**   — counter/gauge/histogram semantics, label-cardinality
  budget, Prometheus text golden, snapshot shapes;
* **stream**     — JSONL events replayed by ``repro.obs.dump`` in a fresh
  registry reconstruct identical state (the CI-artifact contract);
* **flops**      — per-junction gauges match MAC/storage counts derived
  independently from the pattern's dense mask (the paper's rho and
  complexity-reduction factor);
* **purity**     — the engine's jitted paged step and the trainer's step
  lower to byte-identical HLO with metrics on vs off, and sparselint's
  SL201 pass finds no host-sync primitive in either;
* **surfaces**   — the ``/metrics`` HTTP endpoint and the dump CLI.
"""
import json
import os
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_block_pattern
from repro.obs import dump, flops, metrics, trace
from repro.obs.metrics import CardinalityError, Registry


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_counter_and_gauge_basics():
    reg = Registry()
    c = reg.counter("c_total", "help text")
    c.inc()
    c.inc(2.5, phase="prefill")
    assert c.value() == 1.0
    assert c.value(phase="prefill") == 2.5
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("g")
    g.set(3.0)
    g.set_max(1.0)          # high-water keeps the max
    assert g.value() == 3.0
    g.set_max(7.0)
    assert g.value() == 7.0
    # same name returns the same metric; kind mismatch raises
    assert reg.counter("c_total") is c
    with pytest.raises(ValueError):
        reg.gauge("c_total")


def test_disabled_registry_is_noop():
    reg = Registry(enabled=False)
    c = reg.counter("c")
    c.inc(5)
    reg.gauge("g").set(1)
    reg.histogram("h").observe(0.1)
    reg.record_span("s", 0.5)
    assert c.value() == 0.0
    assert reg.snapshot()["counters"]["c"]["series"] == []
    assert reg.span_durations("s") == []


def test_label_cardinality_budget():
    reg = Registry(max_series=4)
    c = reg.counter("c")
    for i in range(4):
        c.inc(series=i)
    with pytest.raises(CardinalityError):
        c.inc(series="one-too-many")
    # existing series still record after the breach attempt
    c.inc(series=0)
    assert c.value(series=0) == 2.0


def test_histogram_buckets_exact():
    reg = Registry()
    h = reg.histogram("h", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 2.0, 100.0):
        h.observe(v)
    s = reg.snapshot()["histograms"]["h"]["series"][0]
    # le-0.1 gets 0.05 and 0.1 (boundary is inclusive), le-1.0 gets 0.5,
    # le-10 gets 2.0, +Inf gets 100.0
    assert s["bucket_counts"] == [2, 1, 1, 1]
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(102.65)
    assert h.stats() == (5, pytest.approx(102.65))


def test_prometheus_text_golden():
    reg = Registry()
    reg.counter("req_total", "requests").inc(3, kind="a")
    reg.gauge("depth").set(2)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    assert reg.prometheus_text() == (
        '# TYPE depth gauge\n'
        'depth 2\n'
        '# HELP lat_seconds latency\n'
        '# TYPE lat_seconds histogram\n'
        'lat_seconds_bucket{le="0.1"} 1\n'
        'lat_seconds_bucket{le="1"} 2\n'
        'lat_seconds_bucket{le="+Inf"} 3\n'
        'lat_seconds_sum 5.55\n'
        'lat_seconds_count 3\n'
        '# HELP req_total requests\n'
        '# TYPE req_total counter\n'
        'req_total{kind="a"} 3\n')


def test_span_recording():
    reg = Registry()
    with trace.span("phase/x", registry=reg, n=3):
        pass
    ds = reg.span_durations("phase/x")
    assert len(ds) == 1 and ds[0] >= 0.0
    cnt, _ = reg.histogram("repro_span_seconds").stats(span="phase/x")
    assert cnt == 1


# ---------------------------------------------------------------------------
# JSONL stream -> dump replay
# ---------------------------------------------------------------------------


def test_jsonl_replay_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    reg = Registry(jsonl_path=path)
    reg.counter("tok_total", "tokens").inc(7, phase="decode")
    reg.gauge("occ").set(0.5)
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.7)
    with trace.span("bench/x", registry=reg):
        pass
    reg.close()
    replayed = dump.replay(path)
    a, b = reg.snapshot(), replayed.snapshot()
    assert a["counters"] == b["counters"]
    assert a["gauges"] == b["gauges"]
    assert a["histograms"] == b["histograms"]
    assert replayed.span_durations("bench/x") == \
        reg.span_durations("bench/x")
    # and the exporters agree byte-for-byte
    assert reg.prometheus_text() == replayed.prometheus_text()


def test_dump_cli(tmp_path, capsys):
    path = str(tmp_path / "m.jsonl")
    reg = Registry(jsonl_path=path)
    reg.counter("c").inc(2)
    reg.close()
    assert dump.main(["--input", path, "--format", "json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["counters"]["c"]["series"][0]["value"] == 2.0
    outfile = str(tmp_path / "m.prom")
    assert dump.main(["--input", path, "--format", "prom",
                      "-o", outfile]) == 0
    assert "c 2" in open(outfile).read()


# ---------------------------------------------------------------------------
# FLOP accounting vs hand counts
# ---------------------------------------------------------------------------


def test_junction_stats_match_mask_hand_count():
    n_in, n_out, rho, b = 64, 128, 0.25, 16
    bp = make_block_pattern(n_in, n_out, rho, block_in=b, block_out=b,
                            seed=0)
    st = flops.junction_stats(bp)
    mask = bp.to_mask()
    nnz = int(mask.sum())           # surviving weight elements
    assert st.dense_macs == n_in * n_out
    assert st.sparse_macs == nnz    # one MAC per stored weight per row
    assert st.density == pytest.approx(nnz / (n_in * n_out))
    assert st.speedup == pytest.approx((n_in * n_out) / nnz)
    assert st.weight_bytes == 4 * nnz
    assert st.dense_weight_bytes == 4 * n_in * n_out
    assert st.index_bytes == 4 * bp.block_idx.size
    assert st.label == f"64x128b16x16r{st.density:g}"


def test_register_exports_gauges():
    reg = Registry()
    bp = make_block_pattern(64, 64, 0.5, block_in=16, block_out=16, seed=1)
    st = flops.register(bp, registry=reg)
    j = st.label
    assert reg.gauge("repro_junction_density").value(junction=j) == \
        pytest.approx(st.density)
    assert reg.gauge("repro_junction_sparse_macs").value(junction=j) == \
        st.sparse_macs
    assert reg.gauge("repro_junction_speedup").value(junction=j) == \
        pytest.approx(st.speedup)
    flops.register(bp, registry=reg)   # idempotent gauges, counted twice
    assert reg.counter("repro_junction_patterns_total").value(
        junction=j) == 2.0


def test_fit_block_pattern_registers_into_default_registry():
    from repro.core.block_pattern import fit_block_pattern
    from repro.nn.common import SparsityConfig
    sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                        block_in=16, block_out=16)
    bp = fit_block_pattern(48, 96, 0.5, sp)
    st = flops.junction_stats(bp)
    reg = metrics.get_registry()
    assert reg.gauge("repro_junction_dense_macs").value(
        junction=st.label) == st.dense_macs


# ---------------------------------------------------------------------------
# jit purity: metrics on == metrics off, on the lowered HLO
# ---------------------------------------------------------------------------


def _tiny_model():
    from repro.nn import ModelConfig, SparsityConfig, build_model
    cfg = ModelConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, attn_chunk=16, loss_chunk=16, dtype="float32",
        remat=False,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                                block_in=16, block_out=16))
    return build_model(cfg)


def _paged_step_hlo(metrics_on: bool) -> str:
    from repro.nn.common import dtype_of
    from repro.serving import EngineConfig, ServingEngine
    model = _tiny_model()
    params = model.init(jax.random.key(0))
    eng = ServingEngine(
        model, params,
        EngineConfig(max_slots=2, page_size=8, total_pages=16,
                     max_pages_per_seq=4, token_budget=8,
                     prefill_chunk=8, metrics=metrics_on),
        registry=Registry(enabled=metrics_on))
    i32 = np.int32
    cache_avals = jax.eval_shape(
        lambda: model.stack.init_paged_cache(2, 16, 8,
                                             dtype_of(model.cfg)))
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    args = (p_avals, cache_avals,
            jax.ShapeDtypeStruct((2, 4), i32),
            jax.ShapeDtypeStruct((2, 1), i32),
            jax.ShapeDtypeStruct((2,), i32),
            jax.ShapeDtypeStruct((2,), i32),
            jax.ShapeDtypeStruct((2,), i32))
    return eng._step.lower(*args).as_text()


def test_engine_step_hlo_identical_with_metrics_on_or_off():
    assert _paged_step_hlo(True) == _paged_step_hlo(False)


def _train_step_hlo(metrics_on: bool) -> str:
    from repro.train import Trainer, TrainerConfig
    model = _tiny_model()
    tr = Trainer(model, TrainerConfig(metrics=metrics_on),
                 registry=Registry(enabled=metrics_on))
    batch = {"tokens": np.zeros((2, 16), np.int32),
             "labels": np.zeros((2, 16), np.int32)}
    step = tr._make_step(batch)
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    from repro.optim import adam
    o_avals = jax.eval_shape(adam.init, p_avals)
    b_avals = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    return step.lower(p_avals, o_avals, b_avals).as_text()


def test_train_step_hlo_identical_with_metrics_on_or_off():
    assert _train_step_hlo(True) == _train_step_hlo(False)


def test_no_host_sync_primitives_in_instrumented_steps():
    """sparselint SL201 over the engine step and trainer step traced with
    metrics ENABLED: instrumentation must not smuggle a callback/infeed
    into the traced programs."""
    from repro.analysis.jaxpr_pass import lint_closed_jaxpr
    from repro.nn.common import dtype_of
    from repro.optim import adam
    from repro.serving import EngineConfig, ServingEngine
    from repro.train import Trainer, TrainerConfig

    model = _tiny_model()
    params = model.init(jax.random.key(0))
    eng = ServingEngine(
        model, params,
        EngineConfig(max_slots=2, page_size=8, total_pages=16,
                     max_pages_per_seq=4, token_budget=8, prefill_chunk=8),
        registry=Registry())
    i32 = np.int32
    cache_avals = jax.eval_shape(
        lambda: model.stack.init_paged_cache(2, 16, 8,
                                             dtype_of(model.cfg)))
    p_avals = jax.eval_shape(model.init, jax.random.key(0))
    traced = eng._step.trace(
        p_avals, cache_avals,
        jax.ShapeDtypeStruct((2, 4), i32),
        jax.ShapeDtypeStruct((2, 1), i32),
        jax.ShapeDtypeStruct((2,), i32),
        jax.ShapeDtypeStruct((2,), i32),
        jax.ShapeDtypeStruct((2,), i32))
    sl201 = [f for f in lint_closed_jaxpr(traced.jaxpr, "paged_step[obs]")
             if f.code == "SL201"]
    assert sl201 == [], sl201

    tr = Trainer(model, TrainerConfig(), registry=Registry())
    batch = {"tokens": np.zeros((2, 16), np.int32),
             "labels": np.zeros((2, 16), np.int32)}
    step = tr._make_step(batch)
    o_avals = jax.eval_shape(adam.init, p_avals)
    b_avals = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    traced = step.trace(p_avals, o_avals, b_avals)
    sl201 = [f for f in lint_closed_jaxpr(traced.jaxpr, "train_step[obs]")
             if f.code == "SL201"]
    assert sl201 == [], sl201


def test_dispatch_counter_counts_at_trace_time():
    from repro.kernels import ops
    reg = metrics.get_registry()
    c = reg.counter("repro_junction_dispatch_total")
    bp = make_block_pattern(64, 64, 0.5, block_in=16, block_out=16, seed=0)
    w = jnp.zeros((bp.n_rb, bp.d_in_b, 16, 16))
    x = jnp.zeros((4, 64))
    before = c.value(backend="xla", form="plain")
    f = jax.jit(lambda x, w: ops.csd_matmul(x, w, bp, backend="xla"))
    f(x, w)     # trace + compile: exactly one dispatch count
    f(x, w)     # cached executable: no re-trace, no new count
    assert c.value(backend="xla", form="plain") == before + 1


# ---------------------------------------------------------------------------
# surfaces: HTTP endpoint, timed_call
# ---------------------------------------------------------------------------


def test_metrics_http_endpoint():
    reg = Registry()
    reg.counter("c_total").inc(4)
    server = metrics.serve_http(reg, port=0)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert "c_total 4" in body
        j = json.loads(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics.json", timeout=5).read())
        assert j["counters"]["c_total"]["series"][0]["value"] == 4.0
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=5)
    finally:
        server.shutdown()


def test_timed_call_reads_registry_spans():
    reg = Registry()
    f = jax.jit(lambda x: x * 2)
    us = trace.timed_call(f, jnp.ones((8,)), iters=3, warmup=1,
                          name="mul", registry=reg)
    assert us > 0
    assert len(reg.span_durations("bench/mul")) == 3
    cnt, _ = reg.histogram("repro_span_seconds").stats(span="bench/mul")
    assert cnt == 3
