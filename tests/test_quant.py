"""Int8 quantized junction + KV certification (PR 9).

Coverage, mirroring the repo's oracle discipline:

* **primitives** — property-based round-trip of the per-tensor
  ``optim.compression`` quantizer (error <= scale/2, symmetric,
  zero-preserving) and the per-block ``core.quant.quantize_slab``
  (4-D and 5-D, block-wise scale shapes, exactness at the amax);
* **junction** — quantized ``csd_matmul`` vs the *dequantized* full-width
  oracle (tight: the int8 path must compute exactly the dequantized
  matmul, only fused) on both backends, both dataflows, 4-D and 5-D, and
  vs the *f32* dense oracle within the analytic error bound
  ``max(scale)/2 * max_row(sum|x|)``;
* **layout** — scale slabs survive ``split_slab``/``merge_slab`` next to
  their weight slabs; ``quantize_tree`` rewrites exactly the block-sparse
  leaves and extends the sharding spec in lock-step;
* **KV** — int8 paged KV (per-token scales) through
  ``paged_decode_attention``, Pallas-interpret vs XLA, and vs the
  full-width kernel within the per-token quantization error;
* **engine** — int8 weights + int8 KV greedy decode vs the f32 engine:
  >= 99% token agreement on the smoke configs (exact agreement is typical
  at these scales; the gate allows isolated near-tie flips).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pinned container image: degraded deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.block_pattern import (make_block_pattern, partition_pattern,
                                      split_slab, merge_slab)
from repro.core.quant import (QuantConfig, dequantize_slab, quantize_slab,
                              quantize_spec, quantize_tree)
from repro.core.sparse_linear import block_weights_to_dense
from repro.kernels import ops as kops
from repro.kernels.flash_attention import paged_decode_attention
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.serving import kv_cache


# ---------------------------------------------------------------------------
# per-tensor quantizer (optim.compression) — property-based round trip
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3),
       st.integers(1, 64))
def test_quantize_int8_roundtrip_properties(seed, amp, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=amp, size=(n,)), jnp.float32)
    x = x.at[0].set(0.0)  # always include an exact zero
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    assert q.dtype == jnp.int8
    # round-to-nearest: reconstruction error bounded by half a step
    np.testing.assert_array_less(np.abs(np.asarray(deq - x)),
                                 float(scale) / 2 + 1e-12)
    # zero-preserving: exact zeros stay exact
    assert int(q[0]) == 0 and float(deq[0]) == 0.0
    # symmetric: negating the input negates the code (scale unchanged)
    qn, sn = quantize_int8(-x)
    assert float(sn) == float(scale)
    np.testing.assert_array_equal(np.asarray(qn), -np.asarray(q))
    # codes stay in the symmetric range (no -128)
    assert int(jnp.min(q)) >= -127 and int(jnp.max(q)) <= 127


# ---------------------------------------------------------------------------
# per-block slab quantizer (core.quant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(6, 3, 8, 16), (4, 6, 3, 8, 16)],
                         ids=["4d", "5d-expert"])
def test_quantize_slab_roundtrip(shape):
    rng = np.random.default_rng(1)
    # per-block amplitudes spanning 4 orders of magnitude: a per-tensor
    # scale would destroy the small blocks, per-block must not
    amp = 10.0 ** rng.uniform(-2, 2, size=shape[:-2])
    w = rng.normal(size=shape).astype(np.float32) * amp[..., None, None]
    q, scales = quantize_slab(jnp.asarray(w))
    assert q.dtype == jnp.int8 and q.shape == shape
    assert scales.shape == shape[:-2] and scales.dtype == jnp.float32
    deq = np.asarray(dequantize_slab(q, scales))
    err = np.abs(deq - w)
    bound = np.asarray(scales)[..., None, None] / 2 + 1e-9
    assert (err <= bound).all()
    # each block's amax hits |code| 127 exactly (symmetric, saturating)
    flat_q = np.abs(np.asarray(q)).reshape(-1, shape[-2] * shape[-1])
    assert (flat_q.max(axis=-1) == 127).all()
    # zero-preserving
    z, zs = quantize_slab(jnp.zeros(shape))
    assert not np.asarray(z).any()
    assert np.asarray(dequantize_slab(z, zs)).sum() == 0.0


def test_quant_config_rejects_non_int8():
    with pytest.raises(ValueError):
        QuantConfig(bits=4)


# ---------------------------------------------------------------------------
# quantized csd_matmul vs oracles
# ---------------------------------------------------------------------------


def _bp(n_in=64, n_out=96, rho=0.5, b=16, seed=0):
    return make_block_pattern(n_in, n_out, rho, block_in=b, block_out=b,
                              seed=seed)


@pytest.mark.parametrize("backend,interp", [("xla", False),
                                            ("pallas", True)])
@pytest.mark.parametrize("dataflow", ["gather", "scatter"])
def test_quant_matmul_matches_dequant_oracle(backend, interp, dataflow):
    """The int8 path IS the dequantized matmul, just fused: parity with
    csd_matmul over dequantize_slab(w) must be near machine-exact."""
    if backend == "pallas" and dataflow == "scatter":
        pytest.skip("pallas path is gather-form only")
    bp = _bp()
    rng = np.random.default_rng(2)
    w = rng.normal(size=(bp.n_rb, bp.d_in_b, 16, 16)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(8, bp.n_in)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(bp.n_out,)), jnp.float32)
    q, s = quantize_slab(jnp.asarray(w))
    ref = kops.csd_matmul(x, dequantize_slab(q, s), bp, bias=b,
                          activation="relu", backend=backend,
                          dataflow=dataflow, interpret=interp)
    out = kops.csd_matmul(x, q, bp, bias=b, activation="relu",
                          backend=backend, dataflow=dataflow,
                          interpret=interp, w_scale=s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend,interp", [("xla", False),
                                            ("pallas", True)])
def test_quant_matmul_batched_expert_major(backend, interp):
    bp = _bp()
    e = 3
    rng = np.random.default_rng(3)
    w = rng.normal(size=(e, bp.n_rb, bp.d_in_b, 16, 16)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(e, 4, bp.n_in)), jnp.float32)
    q, s = quantize_slab(jnp.asarray(w))
    assert s.shape == (e, bp.n_rb, bp.d_in_b)
    ref = kops.csd_matmul(x, dequantize_slab(q, s), bp, backend=backend,
                          interpret=interp)
    out = kops.csd_matmul(x, q, bp, backend=backend, interpret=interp,
                          w_scale=s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("backend,interp", [("xla", False),
                                            ("pallas", True)])
def test_quant_matmul_error_bound_vs_f32_oracle(backend, interp):
    """ISSUE acceptance: the int8 junction lands within the analytic
    bound of the full-precision oracle. Per output element the dequant
    error of each weight is <= scale/2, so |y_q - y_f| <=
    max(scale)/2 * sum_f |x_f| (summing only pattern-connected inputs
    would tighten it; the loose row bound is already ~1e-1 here)."""
    bp = _bp()
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.normal(size=(bp.n_rb, bp.d_in_b, 16, 16)),
                    jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, bp.n_in)), jnp.float32)
    q, s = quantize_slab(w)
    dense = block_weights_to_dense(w, bp)
    ref = x @ dense
    out = kops.csd_matmul(x, q, bp, backend=backend, interpret=interp,
                          w_scale=s)
    bound = float(jnp.max(s)) / 2 * float(jnp.max(jnp.sum(jnp.abs(x), -1)))
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err <= bound, (err, bound)
    # and the bound is not vacuous: quantization error is real but small
    assert 0 < err < 0.5 * float(jnp.max(jnp.abs(ref)))


def test_quant_matmul_rejects_training_and_dtype_mismatch():
    bp = _bp()
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(bp.n_rb, bp.d_in_b, 16, 16)),
                    jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, bp.n_in)), jnp.float32)
    q, s = quantize_slab(w)
    with pytest.raises(ValueError):  # f32 slab with a scale: not quantized
        kops.csd_matmul(x, w, bp, backend="xla", w_scale=s)
    from repro.kernels import csd_spmm
    with pytest.raises(ValueError):  # no training through the int8 path
        csd_spmm.csd_spmm_fwd(x, q, bp.block_idx, w_scale=s,
                              save_preact=True, interpret=True)


# ---------------------------------------------------------------------------
# layout: scales ride the same partition machinery as their slabs
# ---------------------------------------------------------------------------


def test_scale_slab_split_merge_roundtrip():
    bp = _bp(n_in=64, n_out=128, b=16)
    part = partition_pattern(bp, 4)
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(bp.n_rb, bp.d_in_b, 16, 16)),
                    jnp.float32)
    q, s = quantize_slab(w)
    qs, ss = split_slab(np.asarray(q), part), split_slab(np.asarray(s), part)
    assert qs.shape == (4, bp.n_rb // 4, bp.d_in_b, 16, 16)
    assert ss.shape == (4, bp.n_rb // 4, bp.d_in_b)
    np.testing.assert_array_equal(merge_slab(qs, part), np.asarray(q))
    np.testing.assert_array_equal(merge_slab(ss, part), np.asarray(s))
    # per-shard dequant equals the matching rows of the full dequant
    for k in range(4):
        rows = np.asarray(part.shards[k].meta["rows"])
        np.testing.assert_allclose(
            np.asarray(dequantize_slab(jnp.asarray(qs[k]),
                                       jnp.asarray(ss[k]))),
            np.asarray(dequantize_slab(q, s))[rows])
    # 5-D expert-major scales too (rb axis is 1)
    e = 2
    w5 = jnp.asarray(rng.normal(size=(e, bp.n_rb, bp.d_in_b, 16, 16)),
                     jnp.float32)
    q5, s5 = quantize_slab(w5)
    ss5 = split_slab(np.asarray(s5), part)
    assert ss5.shape == (4, e, bp.n_rb // 4, bp.d_in_b)
    np.testing.assert_array_equal(merge_slab(ss5, part), np.asarray(s5))


def test_quantize_tree_rewrites_slabs_and_extends_spec():
    rng = np.random.default_rng(7)
    params = {
        "ffn": {"up": {"w": jnp.asarray(rng.normal(size=(4, 2, 16, 16)),
                                        jnp.float32),
                       "b": jnp.zeros((64,))},
                "moe": {"up": jnp.asarray(rng.normal(size=(3, 4, 2, 8, 8)),
                                          jnp.float32)}},
        "attn": {"q": {"w": jnp.asarray(rng.normal(size=(32, 32)),
                                        jnp.float32)}},
    }
    spec = {
        "ffn": {"up": {"w": ("slab", None, None, None), "b": (None,)},
                "moe": {"up": ("expert", None, None, None, None)}},
        "attn": {"q": {"w": ("embed", "mlp")}},
    }
    qp, qs = quantize_tree(params, spec)
    # block-sparse slabs became int8 with per-block scale siblings
    assert qp["ffn"]["up"]["w"].dtype == jnp.int8
    assert qp["ffn"]["up"]["w_scale"].shape == (4, 2)
    assert qs["ffn"]["up"]["w_scale"] == ("slab", None)
    assert qp["ffn"]["moe"]["up"].dtype == jnp.int8
    assert qp["ffn"]["moe"]["up_scale"].shape == (3, 4, 2)
    assert qs["ffn"]["moe"]["up_scale"] == ("expert", None, None)
    # dense weights and biases untouched
    assert qp["attn"]["q"]["w"].dtype == jnp.float32
    assert "w_scale" not in qp["attn"]["q"]
    assert qp["ffn"]["up"]["b"].dtype == jnp.float32
    # dequantized slab approximates the original
    deq = dequantize_slab(qp["ffn"]["up"]["w"], qp["ffn"]["up"]["w_scale"])
    bound = np.asarray(qp["ffn"]["up"]["w_scale"])[..., None, None] / 2
    assert (np.abs(np.asarray(deq - params["ffn"]["up"]["w"]))
            <= bound + 1e-9).all()
    # aval-only twin agrees with the materializing walk's spec
    assert quantize_spec(spec, jax.eval_shape(lambda: params)) == qs


# ---------------------------------------------------------------------------
# int8 paged KV
# ---------------------------------------------------------------------------


def _paged_fixture(seed=0):
    rng = np.random.default_rng(seed)
    b, hkv, g, dh, page, n_pages, total = 3, 2, 3, 16, 4, 5, 12
    q = jnp.asarray(rng.normal(size=(b, hkv, g, dh)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(total, page, hkv, dh)),
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(total, page, hkv, dh)),
                          jnp.float32)
    table = np.full((b, n_pages), -1, np.int32)
    perm = rng.permutation(total - 1)
    lengths = np.asarray([3, 11, 17], np.int32)
    lengths = np.minimum(lengths, n_pages * page)
    k = 0
    for i in range(b):
        for pg in range(-(-int(lengths[i]) // page)):
            table[i, pg] = perm[k]
            k += 1
    return q, k_pages, v_pages, jnp.asarray(table), jnp.asarray(lengths)


def _quantize_pages(pages):
    """Per-token int8 pages + (P, page) scales via the append-path
    quantizer (one row at a time, like write_kv_quant would)."""
    qp, sc = kv_cache.quantize_kv(pages)
    return qp, sc


def test_paged_decode_quant_interpret_matches_xla():
    q, kp, vp, table, lengths = _paged_fixture()
    kq, ks = _quantize_pages(kp)
    vq, vs = _quantize_pages(vp)
    assert kq.dtype == jnp.int8 and ks.shape == kp.shape[:2]
    ref = paged_decode_attention(q, kq, vq, table, lengths,
                                 backend="xla", k_scale=ks, v_scale=vs)
    out = paged_decode_attention(q, kq, vq, table, lengths,
                                 backend="pallas", interpret=True,
                                 k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_quant_tracks_full_width():
    """int8 KV attention stays within per-token quantization error of the
    full-width kernel (scores shift by <= |q| * scale/2 per key dim)."""
    q, kp, vp, table, lengths = _paged_fixture(seed=1)
    kq, ks = _quantize_pages(kp)
    vq, vs = _quantize_pages(vp)
    full = paged_decode_attention(q, kp, vp, table, lengths, backend="xla")
    quant = paged_decode_attention(q, kq, vq, table, lengths,
                                   backend="xla", k_scale=ks, v_scale=vs)
    err = float(jnp.max(jnp.abs(quant - full)))
    assert err < 0.05, err  # |v| ~ N(0,1); per-token dequant err ~ 4e-3
    # and the dequantized pages really round-trip
    deq = np.asarray(kq, np.float32) * np.asarray(ks)[:, :, None, None]
    assert (np.abs(deq - np.asarray(kp))
            <= np.asarray(ks)[:, :, None, None] / 2 + 1e-9).all()


def test_write_kv_quant_scatter_matches_quantize():
    """The fused write path (quantize new tokens + scatter pages AND
    scales at (phys, off)) lands the same bytes as quantizing the final
    pool — addresses shared with the full-width write_kv."""
    rng = np.random.default_rng(8)
    total, page, hkv, dh, bsz = 6, 4, 2, 8, 3
    kq = jnp.zeros((total, page, hkv, dh), jnp.int8)
    vq = jnp.zeros((total, page, hkv, dh), jnp.int8)
    ks = jnp.zeros((total, page), jnp.float32)
    vs = jnp.zeros((total, page), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(bsz, 1, hkv, dh)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(bsz, 1, hkv, dh)), jnp.float32)
    phys = jnp.asarray([[1], [3], [4]], jnp.int32)
    off = jnp.asarray([[0], [2], [3]], jnp.int32)
    kq, vq, ks, vs = kv_cache.write_kv_quant(kq, vq, ks, vs, k_new, v_new,
                                             phys, off)
    qk, sk = kv_cache.quantize_kv(k_new)
    for i, (p, o) in enumerate([(1, 0), (3, 2), (4, 3)]):
        np.testing.assert_array_equal(np.asarray(kq[p, o]),
                                      np.asarray(qk[i, 0]))
        assert float(ks[p, o]) == float(sk[i, 0])
    # untouched rows stay zero (int8 zero == dequant zero)
    assert not np.asarray(kq[0]).any() and float(ks[0].sum()) == 0.0


# ---------------------------------------------------------------------------
# engine: int8 weights + int8 KV vs the f32 engine
# ---------------------------------------------------------------------------


def _engine_cfg(**kw):
    from repro.serving import EngineConfig
    return EngineConfig(max_slots=4, page_size=8, total_pages=32,
                        token_budget=32, prefill_chunk=8, backend="xla",
                        metrics=False, **kw)


@pytest.mark.parametrize("kv", [False, True], ids=["w-only", "w+kv"])
def test_engine_int8_token_agreement(kv):
    """ISSUE acceptance: >= 99% greedy token agreement int8 vs f32."""
    from repro.nn import ModelConfig, SparsityConfig, build_model
    from repro.serving import ServingEngine
    sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0), block_in=16,
                        block_out=16)
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=256, attn_chunk=16,
                      loss_chunk=16, dtype="float32", remat=False,
                      sparsity=sp)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 15, dtype=np.int32),
               np.asarray([7, 7, 11], np.int32)]
    ref = ServingEngine(model, params, _engine_cfg()).run(prompts, 16)
    qcfg = _engine_cfg(quant=QuantConfig(weights=True, kv=kv))
    eng = ServingEngine(model, params, qcfg)
    # the engine quantized at load: int8 slabs + scale siblings in params
    leaves = jax.tree.leaves(eng.params)
    assert any(l.dtype == jnp.int8 for l in leaves)
    if kv:
        assert any(l.dtype == jnp.int8
                   for l in jax.tree.leaves(eng.cache))
    out = eng.run(prompts, 16)
    agree = sum(int((a == b).sum()) for a, b in zip(ref, out))
    total = sum(len(a) for a in ref)
    assert agree / total >= 0.99, (agree, total)


def test_engine_quant_from_model_sparsity_config():
    """A model built with SparsityConfig.quant serves quantized with no
    engine-side flag (the engine reads the model's knob)."""
    from repro.nn import ModelConfig, SparsityConfig, build_model
    from repro.serving import ServingEngine
    sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0), block_in=16,
                        block_out=16, quant=QuantConfig(kv=False))
    cfg = ModelConfig(n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=128, attn_chunk=16,
                      loss_chunk=16, dtype="float32", remat=False,
                      sparsity=sp)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    eng = ServingEngine(model, params, _engine_cfg())
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(eng.params))
    out = eng.run([np.asarray([5, 6, 7], np.int32)], 4)
    assert len(out[0]) == 4
