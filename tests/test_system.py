"""End-to-end behaviour tests for the paper's system.

The headline system test: the paper's pipeline — pre-define a clash-free
sparse pattern, train through it, verify the pattern NEVER changes (the
'pre-defined, held fixed' contract), at reduced storage/compute — and the
LM-scale integration: a sparse-FFN transformer trains, checkpoints,
restores, and serves.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparseLinear, SparseLinearSpec, storage_cost,
                        to_mask)
from repro.data import BigramLM, synthetic_mnist
from repro.nn import ModelConfig, SparsityConfig, build_model
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def test_pattern_is_fixed_through_training():
    """Pre-defined sparsity contract: training only ever touches existing
    edges — masking the trained weights by the original pattern is a
    no-op on the model's function."""
    data = synthetic_mnist(n_train=800, n_test=200, seed=0)
    cfg = MLPConfig(n_net=(800, 50, 10), rho=(0.1, 1.0),
                    method="clashfree", mode="mask")
    model = SparseMLP(cfg)
    mask_before = to_mask(model.layers[0].pattern)
    params, acc = train_mlp(model, data, epochs=2, batch=128)
    x = jnp.asarray(data[0][:8])
    logits_full = model.logits(params, x)
    params2 = dict(params)
    params2["j0"] = dict(params["j0"],
                         w=params["j0"]["w"] * jnp.asarray(mask_before))
    logits_masked = model.logits(params2, x)
    np.testing.assert_allclose(logits_full, logits_masked, atol=1e-5)


def test_sparse_mlp_storage_complexity_reduced():
    cfg = MLPConfig(n_net=(800, 100, 10), rho=(0.2, 1.0))
    m = SparseMLP(cfg)
    dense_w = 800 * 100 + 100 * 10
    assert m.n_weights() < 0.25 * dense_w


def test_lm_sparse_ffn_trains_checkpoints_and_serves():
    cfg = ModelConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, attn_chunk=16, loss_chunk=16, dtype="float32",
        remat=False,
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                                block_in=16, block_out=16))
    model = build_model(cfg)
    data = BigramLM(vocab_size=256, branching=4, noise=0.0, seed=0)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(
            opt=AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=40,
                            weight_decay=0.0),
            checkpoint_dir=d, checkpoint_every=20)
        tr = Trainer(model, tc)
        params, opt, hist = tr.fit(data.iterate(8, 32), steps=40)
        assert hist[-1]["loss"] < hist[0]["loss"]

        # restore into a fresh trainer and serve
        tr2 = Trainer(model, tc)
        (params2, _), _ = tr2.ckpt.restore(40, (params, opt))
        prompt = jnp.asarray(data.batch(99, 4, 16)["tokens"])
        logits, cache = model.prefill(params2, {"tokens": prompt}, 24)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(4):
            logits, cache = model.decode_step(params2, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert jnp.isfinite(logits).all()


def test_sparse_ffn_weight_count_scales_with_rho():
    def n_ffn_params(rho):
        cfg = ModelConfig(
            n_layers=1, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
            vocab_size=64, dtype="float32",
            sparsity=SparsityConfig(enabled=rho < 1.0, rho_ffn=(rho, rho),
                                    block_in=16, block_out=16))
        model = build_model(cfg)
        p = model.init(jax.random.key(0))
        ffn = p["stack"]["scan"][0]["ffn"]
        return sum(x.size for x in jax.tree.leaves(ffn))

    dense = n_ffn_params(1.0)
    half = n_ffn_params(0.5)
    assert half < 0.6 * dense


def test_multijunction_density_config():
    """Per-junction rho plumbed through an LM config (paper trend 3)."""
    cfg = ModelConfig(
        n_layers=1, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=64, dtype="float32",
        sparsity=SparsityConfig(enabled=True, rho_ffn=(0.25, 0.75),
                                block_in=16, block_out=16))
    model = build_model(cfg)
    blk = model.stack.unit_blocks[0]
    assert abs(blk.ffn.up.pattern.density - 0.25) < 0.01
    assert abs(blk.ffn.down.pattern.density - 0.75) < 0.01
