"""Property-based gradient certification of the ``csd_matmul`` custom VJP.

Sweeps (pattern shape, bias on/off, activation, dataflow) drawn by
hypothesis (or the deterministic fallback shim when hypothesis is not
installed) and asserts that ``jax.grad`` through ``csd_matmul`` — i.e. the
paper's FF/BP/UP wiring plus the fused-epilogue cotangent masking — matches
gradients through the ``kernels.ref`` einsum oracle on BOTH backends:

* ``backend="xla"`` with the drawn dataflow (gather/scatter lowering);
* ``backend="pallas"`` in interpret mode (the same kernel bodies that
  compile to Mosaic on TPU).

The batched (expert-major) property certifies the same contract for the
MoE junction layout ``w: (E, n_rb, d_in_b, bL, bR)``.

Interpret-mode Pallas gradients cost seconds per example, so each property
runs twice: a small always-on sweep for tier-1 CI, and a ``slow``-marked
wide sweep for the full ladder.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pinned container image: degraded deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import make_block_pattern
from repro.kernels import ops
from repro.kernels.csd_spmm import apply_activation
from repro.kernels.ref import block_gather_ref

_BACKENDS = (
    dict(backend="xla"),
    dict(backend="pallas", block_m=8, interpret=True),
)


@st.composite
def junction_cases(draw, wide: bool):
    bl = draw(st.sampled_from([4, 8] if wide else [4]))
    br = draw(st.sampled_from([4, 8] if wide else [4, 8]))
    n_lb = draw(st.integers(min_value=2, max_value=4 if wide else 3))
    n_rb = draw(st.integers(min_value=2, max_value=4 if wide else 3))
    rho = draw(st.sampled_from([1.0 / 3.0, 0.5, 0.75, 1.0]))
    m = draw(st.integers(min_value=1, max_value=12 if wide else 6))
    use_bias = draw(st.booleans())
    activation = draw(st.sampled_from([None, "relu", "gelu"]))
    dataflow = draw(st.sampled_from(["gather", "scatter"]))
    seed = draw(st.integers(min_value=0, max_value=5))
    return (n_lb * bl, n_rb * br, bl, br, rho, m, use_bias, activation,
            dataflow, seed)


def _oracle(x, w, b, bp, activation):
    """Gradient ground truth: the kernels.ref gather-einsum form with the
    epilogue applied outside (plain autodiff, no custom VJP)."""
    z = block_gather_ref(x, w, bp.block_idx, bp.block_in, bp.block_out)
    if b is not None:
        z = z + b
    return apply_activation(z, activation)


def _check_case(case):
    (n_in, n_out, bl, br, rho, m, use_bias, activation, dataflow,
     seed) = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=seed)
    ks = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(ks[0], (m, n_in))
    w = jax.random.normal(ks[1], (bp.n_rb, bp.d_in_b, bl, br))
    b = jax.random.normal(ks[2], (n_out,)) if use_bias else None

    def loss_ref(w, x, b=None):
        return jnp.sum(jnp.sin(_oracle(x, w, b, bp, activation)))

    args = (w, x) + ((b,) if use_bias else ())
    argnums = tuple(range(len(args)))
    g_ref = jax.grad(loss_ref, argnums=argnums)(*args)

    for kw in _BACKENDS:
        def loss(w, x, b=None, kw=kw):
            y = ops.csd_matmul(x, w, bp, bias=b, activation=activation,
                               dataflow=dataflow, **kw)
            return jnp.sum(jnp.sin(y))

        g = jax.grad(loss, argnums=argnums)(*args)
        for got, ref in zip(g, g_ref):
            np.testing.assert_allclose(
                got, ref, atol=1e-4, rtol=1e-4,
                err_msg=f"{kw} act={activation} bias={use_bias} "
                        f"dataflow={dataflow} case={bp.n_lb}x{bp.n_rb} "
                        f"bl={bl} br={br} rho={rho} m={m}")


@given(junction_cases(wide=False))
@settings(max_examples=3, deadline=None)
def test_csd_matmul_grad_matches_ref_oracle(case):
    _check_case(case)


@pytest.mark.slow
@given(junction_cases(wide=True))
@settings(max_examples=25, deadline=None)
def test_csd_matmul_grad_matches_ref_oracle_wide(case):
    _check_case(case)


@st.composite
def batched_cases(draw, wide: bool):
    bl = draw(st.sampled_from([4, 8] if wide else [4]))
    n_lb = draw(st.integers(min_value=2, max_value=3))
    n_rb = draw(st.integers(min_value=2, max_value=3))
    rho = draw(st.sampled_from([0.5, 1.0]))
    e = draw(st.integers(min_value=1, max_value=3))
    m = draw(st.integers(min_value=1, max_value=9 if wide else 6))
    use_bias = draw(st.booleans())
    activation = draw(st.sampled_from([None, "relu", "gelu"]))
    seed = draw(st.integers(min_value=0, max_value=3))
    return (n_lb * bl, n_rb * bl, bl, rho, e, m, use_bias, activation, seed)


def _check_batched_case(case):
    """Expert-major layout: grads through the batched custom VJP must match
    the per-expert einsum oracle vmapped over the expert dim."""
    n_in, n_out, bl, rho, e, m, use_bias, activation, seed = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=bl,
                            seed=seed)
    ks = jax.random.split(jax.random.key(seed + 17), 3)
    x = jax.random.normal(ks[0], (e, m, n_in))
    w = jax.random.normal(ks[1], (e, bp.n_rb, bp.d_in_b, bl, bl))
    b = jax.random.normal(ks[2], (e, n_out)) if use_bias else None

    def loss_ref(w, x, b=None):
        z = jax.vmap(lambda xe, we: block_gather_ref(
            xe, we, bp.block_idx, bp.block_in, bp.block_out))(x, w)
        if b is not None:
            z = z + b[:, None]
        return jnp.sum(jnp.sin(apply_activation(z, activation)))

    args = (w, x) + ((b,) if use_bias else ())
    argnums = tuple(range(len(args)))
    g_ref = jax.grad(loss_ref, argnums=argnums)(*args)

    for kw in _BACKENDS:
        def loss(w, x, b=None, kw=kw):
            y = ops.csd_matmul(x, w, bp, bias=b, activation=activation,
                               **kw)
            return jnp.sum(jnp.sin(y))

        g = jax.grad(loss, argnums=argnums)(*args)
        for got, ref in zip(g, g_ref):
            np.testing.assert_allclose(
                got, ref, atol=1e-4, rtol=1e-4,
                err_msg=f"{kw} act={activation} bias={use_bias} E={e} m={m}")


@given(batched_cases(wide=False))
@settings(max_examples=3, deadline=None)
def test_batched_csd_matmul_grad_matches_ref_oracle(case):
    _check_batched_case(case)


@pytest.mark.slow
@given(batched_cases(wide=True))
@settings(max_examples=15, deadline=None)
def test_batched_csd_matmul_grad_matches_ref_oracle_wide(case):
    _check_batched_case(case)


# ---------------------------------------------------------------------------
# Fused backward epilogue: the Pallas BP/UP kernels mask the cotangent
# in-kernel (and fold db into the UP sweep). Kernel-level parity against
# the XLA fallback's mask-then-sweep form, which is the unchanged
# semantic reference. (The property sweeps above already certify the
# end-to-end grads through both backends; this pins the kernel surface.)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batched", [False, True],
                         ids=["unbatched", "batched"])
@pytest.mark.parametrize("activation", ["relu", "gelu"])
def test_fused_backward_epilogue_kernels_match_masked_xla(
        batched, activation):
    from repro.kernels.csd_spmm import csd_spmm_dx, csd_spmm_dw
    bl = br = 4
    bp = make_block_pattern(3 * bl, 4 * br, 0.5, block_in=bl, block_out=br,
                            seed=1)
    rng = np.random.default_rng(2)
    lead = (2,) if batched else ()
    m = 6
    x = jnp.asarray(rng.normal(size=lead + (m, bp.n_in)), jnp.float32)
    w = jnp.asarray(rng.normal(
        size=lead + (bp.n_rb, bp.d_in_b, bl, br)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=lead + (m, bp.n_out)), jnp.float32)
    if batched:
        z = jax.vmap(lambda xe, we: block_gather_ref(
            xe, we, bp.block_idx, bl, br))(x, w)
    else:
        z = block_gather_ref(x, w, bp.block_idx, bl, br)
    y = apply_activation(z, activation)
    aux = y if activation == "relu" else z

    dym = ops._mask_dy_xla(dy, aux, activation)
    if batched:
        dx_ref = jax.vmap(lambda de, we: ops._xla_dx(
            de, we, bp.out_idx, bp.out_slot))(dym, w)
        dw_ref = jax.vmap(lambda xe, de: ops._xla_dw(
            xe, de, bp.block_idx, bl, br))(x, dym)
        db_ref = jnp.sum(dym, axis=1)
    else:
        dx_ref = ops._xla_dx(dym, w, bp.out_idx, bp.out_slot)
        dw_ref = ops._xla_dw(x, dym, bp.block_idx, bl, br)
        db_ref = jnp.sum(dym, axis=0)

    dx = csd_spmm_dx(dy, w, bp.out_idx, bp.out_slot, aux=aux,
                     activation=activation, block_m=2, interpret=True)
    dw, db = csd_spmm_dw(x, dy, bp.block_idx, block_in=bl, block_out=br,
                         aux=aux, activation=activation, want_db=True,
                         block_m=2, interpret=True)
    np.testing.assert_allclose(dx, dx_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(dw, dw_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(db, db_ref, atol=1e-4, rtol=1e-4)


def test_fused_backward_db_ignores_padding_rows():
    """Padded-M regression for the in-kernel db: cotangent padding rows
    are zero, so db must equal the unpadded reduction even though the
    padded y/preact rows are nonzero (bias + activation of zero x)."""
    from repro.kernels.csd_spmm import csd_spmm_dw
    bl = br = 4
    bp = make_block_pattern(2 * bl, 3 * br, 0.5, block_in=bl, block_out=br)
    rng = np.random.default_rng(3)
    m, block_m = 3, 4
    pad = block_m - m
    x = jnp.asarray(rng.normal(size=(m, bp.n_in)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(bp.n_rb, bp.d_in_b, bl, br)),
                    jnp.float32)
    b = jnp.asarray(rng.normal(size=(bp.n_out,)), jnp.float32)
    dy = jnp.asarray(rng.normal(size=(m, bp.n_out)), jnp.float32)
    z = block_gather_ref(x, w, bp.block_idx, bl, br) + b
    y = apply_activation(z, "relu")
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    zp = block_gather_ref(xp, w, bp.block_idx, bl, br) + b  # pad rows != 0
    yp = apply_activation(zp, "relu")
    dyp = jnp.pad(dy, ((0, pad), (0, 0)))
    _, db = csd_spmm_dw(xp, dyp, bp.block_idx, block_in=bl, block_out=br,
                        aux=yp, activation="relu", want_db=True,
                        block_m=block_m, interpret=True)
    db_ref = jnp.sum(ops._mask_dy_xla(dy, y, "relu"), axis=0)
    np.testing.assert_allclose(db, db_ref, atol=1e-4, rtol=1e-4)
