"""Validate the loop-aware HLO cost model against XLA's own numbers on
loop-free programs, and against unrolled ground truth on scanned ones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze, xla_cost_analysis


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_matches_xla_on_loop_free_dot():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 32))
    c = _compiled(lambda x, w: jnp.tanh(x @ w), x, w)
    ours = analyze(c.as_text())
    theirs = xla_cost_analysis(c)
    assert ours["flops"] == pytest.approx(theirs["flops"], rel=0.05)


def test_scan_flops_equal_unrolled():
    w = jnp.ones((128, 128))
    x = jnp.ones((128, 128))

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def unrolled(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    ours_scan = analyze(_compiled(scanned, x, w).as_text())
    xla_unrolled = xla_cost_analysis(_compiled(unrolled, x, w))
    # rolled-up scan must match the unrolled ground truth, not the 1x body
    assert ours_scan["flops"] == pytest.approx(xla_unrolled["flops"],
                                               rel=0.05)
    xla_scan = xla_cost_analysis(_compiled(scanned, x, w))
    assert xla_scan["flops"] < ours_scan["flops"] / 5  # the bug we fix


def test_nested_scan_multiplies():
    x = jnp.ones((32, 32))
    w = jnp.ones((32, 32))

    def nested(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            d, _ = jax.lax.scan(inner, c, None, length=4)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    ours = analyze(_compiled(nested, x, w).as_text())
    # 12 matmuls of 2*32^3
    assert ours["flops"] == pytest.approx(12 * 2 * 32 ** 3, rel=0.1)


def test_dot_general_batched():
    a = jnp.ones((8, 16, 32))
    b = jnp.ones((8, 32, 24))
    c = _compiled(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b)
    ours = analyze(c.as_text())
    assert ours["flops"] == pytest.approx(2 * 8 * 16 * 32 * 24, rel=0.05)


def test_bytes_scale_with_loop():
    x = jnp.ones((256, 256))

    def f(x, n):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    b2 = analyze(_compiled(lambda x: f(x, 2), x).as_text())["bytes"]
    b8 = analyze(_compiled(lambda x: f(x, 8), x).as_text())["bytes"]
    assert 2.5 < b8 / b2 < 5.0  # ~4x body traffic, fixed overhead aside
