import os
import sys

# Smoke tests and benches must see the real single CPU device — do NOT set
# xla_force_host_platform_device_count here (dry-run tests that need fake
# devices spawn subprocesses instead).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
