"""repro.tune: cache lifecycle, dispatch parity, certification gate.

The autotuner's contract is *performance-only*: a cache hit may change
which legal backend runs, never the numbers that backend produces — so
the parity tests here compare ``backend="auto"`` against the explicitly
named backend bit-for-bit (``np.array_equal``, not allclose). Lifecycle
tests cover the graceful-fallback matrix from ISSUE 10: round-trip,
corrupt/truncated file, schema-version mismatch, and the
``REPRO_TUNE_DISABLE=1`` kill switch restoring the static heuristic.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import make_block_pattern
from repro.kernels import ops
from repro.tune import cache as tcache
from repro.tune import certify, tuner

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own cache file; no test touches the user's
    XDG cache or leaks a singleton into the next test."""
    monkeypatch.setenv(tcache.ENV_PATH, str(tmp_path / "tune_cache.json"))
    monkeypatch.delenv(tcache.ENV_DISABLE, raising=False)
    monkeypatch.delenv(tcache.ENV_BLOCKS, raising=False)
    tune.reset_cache()
    tune.clear_pending()
    yield
    tune.reset_cache()
    tune.clear_pending()


def _pattern(n_in=128, n_out=256, rho=0.5, block=32):
    return make_block_pattern(n_in, n_out, rho, block_in=block,
                              block_out=block, seed=0)


def _put_junction_entry(bp, m, entry, **kw):
    """Write one dispatch entry for (bp, m) into the active cache file and
    force a re-load so the next trace-time lookup hits it."""
    key = tune.junction_key(m=m, n_in=bp.n_in, n_out=bp.n_out,
                            rho=bp.density, E=kw.pop("E", 0),
                            dtype=kw.pop("dtype", "float32"),
                            quant=kw.pop("quant", False),
                            form=kw.pop("form", "plain"))
    c = tcache.TuneCache(tcache.default_path())
    c.load()
    c.put(key, entry)
    tune.reset_cache()
    return key


# ---------------------------------------------------------------------------
# cache lifecycle
# ---------------------------------------------------------------------------


def test_cache_roundtrip(tmp_path):
    p = str(tmp_path / "rt.json")
    c = tcache.TuneCache(p)
    c.put("k1", {"backend": "xla", "dataflow": "scatter"})
    c.put("k2", {"backend": "dense"})
    c2 = tcache.TuneCache(p).load()
    assert c2.load_error is None
    assert c2.entries == c.entries
    doc = json.load(open(p))
    assert doc["schema"] == tcache.SCHEMA_VERSION
    # atomic write leaves no temp litter behind
    assert [f for f in os.listdir(tmp_path) if f != "rt.json"] == []


@pytest.mark.parametrize("payload", [
    "{not json at all",                                   # corrupt
    json.dumps({"schema": tcache.SCHEMA_VERSION,
                "entries": {"k": {"backend": "xla"}}})[:-9],  # truncated
    json.dumps([1, 2, 3]),                                # wrong root type
])
def test_cache_corrupt_loads_empty(tmp_path, payload):
    p = tmp_path / "bad.json"
    p.write_text(payload)
    c = tcache.TuneCache(str(p)).load()
    assert c.entries == {}
    assert c.load_error is not None


def test_cache_schema_mismatch_ignored(tmp_path):
    p = tmp_path / "old.json"
    p.write_text(json.dumps({
        "schema": tcache.SCHEMA_VERSION + 1,
        "entries": {"k": {"backend": "dense"}}}))
    c = tcache.TuneCache(str(p)).load()
    assert c.entries == {}          # wholesale ignore, never partial
    assert "schema" in c.load_error


def test_cache_missing_file_is_clean_empty(tmp_path):
    c = tcache.TuneCache(str(tmp_path / "never_written.json")).load()
    assert c.entries == {} and c.load_error is None


def test_non_dict_entries_filtered(tmp_path):
    p = tmp_path / "mixed.json"
    p.write_text(json.dumps({
        "schema": tcache.SCHEMA_VERSION,
        "entries": {"good": {"backend": "xla"}, "bad": "a string"}}))
    c = tcache.TuneCache(str(p)).load()
    assert list(c.entries) == ["good"]


def test_m_bucket():
    assert [tcache.m_bucket(m) for m in (1, 2, 3, 8, 100, 500)] == \
        [1, 2, 4, 8, 128, 512]
    assert tcache.m_bucket(10 ** 7) == 4096   # capped
    assert tcache.m_bucket(0) == 1


# ---------------------------------------------------------------------------
# decide_*: miss recording, invalid-entry guards, kill switch
# ---------------------------------------------------------------------------


def test_decide_miss_records_pending_spec():
    assert tune.decide_junction(m=7, n_in=64, n_out=128, rho=0.5) is None
    (key, spec), = tune.pending().items()
    assert key.startswith("csd_spmm|plain|m8|in64|out128|rho0.5")
    assert spec == dict(op="csd_spmm", m=7, n_in=64, n_out=128, rho=0.5,
                        E=0, dtype="float32", quant=False, form="plain",
                        block_in=128, block_out=128)


def test_decide_rejects_illegal_entries():
    bp = _pattern()
    # pallas decision tuned on TPU must not dispatch on this CPU host
    _put_junction_entry(bp, 16, {"backend": "pallas", "dataflow": "gather"})
    assert tune.decide_junction(m=16, n_in=bp.n_in, n_out=bp.n_out,
                                rho=bp.density) is None
    # unknown backend
    _put_junction_entry(bp, 16, {"backend": "bogus"})
    assert tune.decide_junction(m=16, n_in=bp.n_in, n_out=bp.n_out,
                                rho=bp.density) is None
    # dense is illegal for the quant form
    _put_junction_entry(bp, 16, {"backend": "dense"}, quant=True,
                        form="quant")
    assert tune.decide_junction(m=16, n_in=bp.n_in, n_out=bp.n_out,
                                rho=bp.density, quant=True,
                                form="quant") is None


def test_disable_env_kills_lookups(monkeypatch):
    bp = _pattern()
    _put_junction_entry(bp, 16, {"backend": "dense"})
    assert tune.decide_junction(m=16, n_in=bp.n_in, n_out=bp.n_out,
                                rho=bp.density) is not None
    monkeypatch.setenv(tcache.ENV_DISABLE, "1")
    assert tune.decide_junction(m=16, n_in=bp.n_in, n_out=bp.n_out,
                                rho=bp.density) is None
    assert not tune.pending()       # disabled lookups don't queue work


# ---------------------------------------------------------------------------
# numerical parity: tuning changes performance only
# ---------------------------------------------------------------------------


def _operands(bp, m=16, E=0, seed=0):
    lead = (E,) if E else ()
    x = jax.random.normal(jax.random.key(seed), lead + (m, bp.n_in))
    w = jax.random.normal(
        jax.random.key(seed + 1),
        lead + (bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out)) * 0.05
    return x, w


@pytest.mark.parametrize("entry,explicit", [
    ({"backend": "xla", "dataflow": "gather"},
     dict(backend="xla", dataflow="gather")),
    ({"backend": "xla", "dataflow": "scatter"},
     dict(backend="xla", dataflow="scatter")),
    ({"backend": "dense"}, dict(backend="dense")),
])
def test_auto_bit_identical_to_forced_backend(entry, explicit):
    """A cache hit dispatches the winner's exact executable: auto output
    == explicit-backend output, bitwise."""
    bp = _pattern()
    x, w = _operands(bp, m=16)
    _put_junction_entry(bp, 16, entry)
    y_auto = jax.jit(lambda x, w: ops.csd_matmul(
        x, w, bp, backend="auto"))(x, w)
    y_exp = jax.jit(lambda x, w: ops.csd_matmul(
        x, w, bp, **explicit))(x, w)
    assert np.array_equal(np.asarray(y_auto), np.asarray(y_exp))


def test_disable_restores_heuristic_bitwise(monkeypatch):
    """With a dense winner cached, REPRO_TUNE_DISABLE=1 must reproduce the
    static heuristic's output exactly (xla/gather on CPU)."""
    bp = _pattern()
    x, w = _operands(bp, m=16)
    _put_junction_entry(bp, 16, {"backend": "dense"})
    monkeypatch.setenv(tcache.ENV_DISABLE, "1")
    y_auto = jax.jit(lambda x, w: ops.csd_matmul(
        x, w, bp, backend="auto"))(x, w)
    y_xla = jax.jit(lambda x, w: ops.csd_matmul(
        x, w, bp, backend="xla"))(x, w)
    assert np.array_equal(np.asarray(y_auto), np.asarray(y_xla))


@pytest.mark.parametrize("E", [0, 3])
@pytest.mark.parametrize("activation", [None, "relu"])
def test_dense_backend_matches_xla(E, activation):
    """The dense-ref escape hatch is the same junction: forward within
    f32 reassociation tolerance, grads at pattern blocks near-exact."""
    bp = _pattern(n_in=96, n_out=160, rho=0.5, block=32)
    x, w = _operands(bp, m=24, E=E)
    bshape = ((E,) if E else ()) + (bp.n_out,)
    b = jax.random.normal(jax.random.key(9), bshape) * 0.1
    kw = dict(bias=b, activation=activation)
    y_d = ops.csd_matmul(x, w, bp, backend="dense", **kw)
    y_x = ops.csd_matmul(x, w, bp, backend="xla", **kw)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_x),
                               atol=1e-5, rtol=1e-5)

    def loss(be):
        def f(x, w, b):
            return jnp.mean(ops.csd_matmul(x, w, bp, bias=b,
                                           activation=activation,
                                           backend=be) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))

    for gd, gx in zip(loss("dense")(x, w, b), loss("xla")(x, w, b)):
        np.testing.assert_allclose(np.asarray(gd), np.asarray(gx),
                                   atol=1e-6, rtol=1e-5)


def test_dense_backend_rejected_for_quant_and_sharded():
    bp = _pattern()
    x, w = _operands(bp, m=8)
    from repro.core.quant import quantize_slab
    q, s = quantize_slab(w)
    with pytest.raises(ValueError, match="dense"):
        ops.csd_matmul(x, q, bp, backend="dense", w_scale=s)


# ---------------------------------------------------------------------------
# tuner: measurement + certification gate
# ---------------------------------------------------------------------------


def test_bench_junction_tiny_picks_and_caches_winner():
    spec = dict(m=8, n_in=64, n_out=64, rho=0.5, E=0, dtype="float32",
                quant=False, form="plain", block_in=32, block_out=32)
    c = tune.get_cache()
    ent = tuner.bench_junction(spec, cache=c, iters=1, repeats=1)
    assert ent["backend"] in ("xla", "dense")
    assert ent["score_by"] == "fwd"                  # skinny M
    assert ent["block_in"] == 32 and ent["block_out"] == 32
    scores = [i["score_us"] for i in ent["candidates"].values()
              if "score_us" in i]
    assert ent["score_us"] == min(scores)
    # persisted and consulted: the recorded decision round-trips disk
    tune.reset_cache()
    key = tune.junction_key(m=8, n_in=64, n_out=64, rho=0.5, E=0,
                            dtype="float32", quant=False, form="plain")
    assert tune.get_cache().get(key)["backend"] == ent["backend"]


def test_bench_junction_quant_excludes_dense():
    spec = dict(m=4, n_in=64, n_out=64, rho=0.5, E=0, dtype="float32",
                quant=True, form="quant", block_in=32, block_out=32)
    ent = tuner.bench_junction(spec, cache=None)
    assert "dense" not in ent["candidates"]
    assert ent["backend"] == "xla"


def test_certify_injected_is_rejected():
    """The has-teeth proof: sparselint's race-broken kernel, presented as
    a tuned Pallas candidate, must fail SL101-SL105 certification."""
    ok, findings = certify.certify_injected()
    assert not ok
    assert "SL101" in {f.code for f in findings}


def test_certify_accepts_shipped_kernel():
    bp = _pattern(n_in=256, n_out=256, rho=0.5, block=128)
    ok, findings = certify.certify_junction(bp, m=128, block_m=128)
    assert ok, [f"{f.code}: {f.message}" for f in findings]


# ---------------------------------------------------------------------------
# analysis: the sparselint tune pass audits persisted caches
# ---------------------------------------------------------------------------


def test_tune_pass_flags_illegal_and_unreadable(tmp_path):
    from repro.analysis import tune_pass
    legal_key = tune.junction_key(m=8, n_in=64, n_out=64, rho=0.5)
    quant_key = tune.junction_key(m=8, n_in=64, n_out=64, rho=0.5,
                                  quant=True, form="quant")
    p = tmp_path / "audit.json"
    p.write_text(json.dumps({
        "schema": tcache.SCHEMA_VERSION,
        "entries": {
            legal_key: {"backend": "dense"},
            quant_key: {"backend": "dense"},      # illegal: quant regime
            "not|a|key": {"backend": "xla"},
        }}))
    findings, covered = tune_pass.run(str(p))
    assert sorted(f.code for f in findings) == ["SL401", "SL402"]
    assert legal_key in covered

    bad = tmp_path / "corrupt.json"
    bad.write_text("{")
    findings, _ = tune_pass.run(str(bad))
    assert [f.code for f in findings] == ["SL402"]


# ---------------------------------------------------------------------------
# engine: tuned decode-kernel selection is performance-only
# ---------------------------------------------------------------------------


def test_engine_decode_tuned_token_parity():
    """An engine running backend="auto" over a tuned cache entry emits the
    same tokens as one forced to that entry's backend, and records the
    decision on its obs registry."""
    from repro.nn import ModelConfig, SparsityConfig, build_model
    from repro.serving import EngineConfig, ServingEngine

    sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                        block_in=16, block_out=16)
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=256, attn_chunk=16,
                      loss_chunk=16, dtype="float32", remat=False,
                      sparsity=sp)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    ec = dict(max_slots=4, page_size=4, total_pages=24,
              max_pages_per_seq=6, token_budget=16, prefill_chunk=8)
    key = tune.decode_key(b=4, h_kv=2, groups=2, head_dim=cfg.head_dim,
                          page_size=4, n_pages=6, pool=24, quant=False,
                          dtype="float32")
    c = tcache.TuneCache(tcache.default_path())
    c.put(key, {"backend": "xla", "score_us": 1.0})
    tune.reset_cache()

    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 6, 12)]
    eng_auto = ServingEngine(model, params,
                             EngineConfig(backend="auto", **ec))
    eng_xla = ServingEngine(model, params,
                            EngineConfig(backend="xla", **ec))
    out_a = eng_auto.run(prompts, 6)
    out_x = eng_xla.run(prompts, 6)
    assert [list(map(int, o)) for o in out_a] == \
        [list(map(int, o)) for o in out_x]
    n_tuned = eng_auto.obs.counter("repro_tune_engine_decode_total").value(
        backend="xla", tuned="true")
    assert n_tuned == 1


# ---------------------------------------------------------------------------
# benchmarks plumbing: structured rows + the tuned-row gate
# ---------------------------------------------------------------------------


def test_emit_structured_rows():
    from benchmarks import common
    saved = list(common.ROWS)
    common.ROWS.clear()
    try:
        common.emit("t/a", 12.345, {"speedup": 1.5})
        common.emit("t/b", 1.0, 0.25)          # scalar -> {"value": ...}
        common.emit("t/c", 0.0, "")            # empty -> {}
        assert common.ROWS == [
            {"name": "t/a", "us_per_call": 12.35,
             "derived": {"speedup": 1.5}},
            {"name": "t/b", "us_per_call": 1.0,
             "derived": {"value": 0.25}},
            {"name": "t/c", "us_per_call": 0.0, "derived": {}},
        ]
        assert all(isinstance(r["us_per_call"], float)
                   for r in common.ROWS)
    finally:
        common.ROWS[:] = saved


def test_check_tuned_gate():
    from benchmarks.check_tuned import check
    rows = [{"name": "kernel/csd_spmm_rho0.5_tuned", "us_per_call": 9.0,
             "derived": {"tuned_speedup": 1.4, "speedup_vs_dense": 0.95}},
            {"name": "kernel/csd_decode_m2_rho0.25_tuned",
             "us_per_call": 8.0,
             "derived": {"tuned_speedup": 7.0, "speedup_vs_dense": 1.2}},
            {"name": "kernel/other", "us_per_call": 1.0,
             "derived": {"speedup_vs_dense": 0.1}}]    # untuned: ignored
    assert check(rows) == []
    rows[0]["derived"]["tuned_speedup"] = 0.9          # lost to heuristic
    assert len(check(rows)) == 1
    assert check([]) != []                             # no rows = failure


def test_timed_call_repeats_best_of_medians():
    from repro.obs.trace import timed_call
    us = timed_call(lambda x: x + 1, jnp.ones((4,)), iters=2, warmup=1,
                    repeats=3, name="t")
    assert 0 < us < 1e6
