"""Serving subsystem certification: paged KV cache + scheduler + engine.

Three layers of coverage, mirroring the repo's kernel-test discipline:

* **allocator** — PageState alloc/free invariants (incl. under ``jit``)
  and property-based scheduler runs (admit/evict/preempt streams drawn by
  hypothesis or the deterministic fallback shim) asserting no page leaks
  or double-frees at every step;
* **kernel** — the Pallas paged-decode attention kernel (interpret mode)
  against the gather-based XLA lowering, over GQA/window/softcap cases;
* **engine** — paged-cache decode is consistent with full-recompute
  generation: per-step logits match the full forward at the same position
  (dense + sparse junctions, both backends) and greedy token-id parity
  over >= 32 steps, including mixed prompt lengths, preemption under a
  tiny page pool, and SSM recurrent state riding the cache interface.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pinned container image: degraded deterministic sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.flash_attention import paged_decode_attention
from repro.launch.serve import generate, generate_cached
from repro.nn import ModelConfig, SparsityConfig, build_model
from repro.serving import EngineConfig, ServingEngine, kv_cache
from repro.serving.scheduler import Request, Scheduler, StepPlan
from repro.serving.spec import propose_drafts


# ---------------------------------------------------------------------------
# configs / oracles
# ---------------------------------------------------------------------------


def _tiny_cfg(sparse: bool = False, **kw) -> ModelConfig:
    sp = SparsityConfig(enabled=sparse, rho_ffn=(0.5, 1.0),
                        block_in=16, block_out=16)
    return ModelConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, attn_chunk=16, loss_chunk=16, dtype="float32",
        remat=False, sparsity=sp, **kw)


def _recompute_tokens(model, params, prompt: np.ndarray,
                      steps: int) -> list:
    """Greedy full-recompute oracle: forward over a fixed padded buffer."""
    buf = np.zeros((1, len(prompt) + steps), np.int32)
    buf[0, :len(prompt)] = prompt
    fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
    out, n = [], len(prompt)
    for _ in range(steps):
        h = fwd(params, jnp.asarray(buf))
        tok = int(jnp.argmax(model.logits_fn(params, h[:, n - 1:n])[0, 0]))
        out.append(tok)
        if n < buf.shape[1]:
            buf[0, n] = tok
        n += 1
    return out


def _check_engine_parity(model, params, prompts, steps, ecfg):
    eng = ServingEngine(model, params, ecfg)
    for i, p in enumerate(prompts):
        eng.add_request(p, steps, req_id=i)
    while eng.sched.has_work():
        eng.step()
        eng.sched.check_invariants()
    for i, p in enumerate(prompts):
        ref = _recompute_tokens(model, params, p, steps)
        assert eng.outputs[i].tolist() == ref, \
            f"req {i} (len {len(p)}): {eng.outputs[i].tolist()} != {ref}"
    return eng


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


def test_page_state_alloc_free_roundtrip():
    st_ = kv_cache.init_page_state(slots=3, total_pages=8,
                                   max_pages_per_seq=4)
    st_ = kv_cache.alloc_pages(st_, 0, 3)
    st_ = kv_cache.alloc_pages(st_, 1, 2)
    assert int(st_.free_count) == 3
    table = np.asarray(st_.page_table)
    mapped = table[table >= 0]
    assert len(set(mapped.tolist())) == 5  # no double-mapping
    st_ = kv_cache.free_slot(st_, 0)
    assert int(st_.free_count) == 6
    assert (np.asarray(st_.page_table[0]) == -1).all()
    # freed ids are allocatable again and still unique
    st_ = kv_cache.alloc_pages(st_, 2, 4)
    table = np.asarray(st_.page_table)
    mapped = table[table >= 0]
    assert len(set(mapped.tolist())) == len(mapped) == 6


def test_page_state_ops_work_under_jit():
    st_ = kv_cache.init_page_state(slots=2, total_pages=6,
                                   max_pages_per_seq=3)
    alloc2 = jax.jit(lambda s, slot: kv_cache.alloc_pages(s, slot, 2))
    free = jax.jit(kv_cache.free_slot)
    st_ = alloc2(st_, jnp.asarray(0))
    st_ = alloc2(st_, jnp.asarray(1))
    assert int(st_.free_count) == 2
    st_ = free(st_, jnp.asarray(0))
    assert int(st_.free_count) == 4
    ids = np.asarray(st_.free_stack)[:4]
    assert len(set(ids.tolist())) == 4


def test_physical_addresses_redirect_invalid_to_trash():
    table = jnp.asarray([[2, 0, -1, -1]], jnp.int32)
    pos = jnp.asarray([[0, 3, 4, 9]], jnp.int32)   # page size 4
    valid = jnp.asarray([[True, True, True, False]])
    phys, off = kv_cache.physical_addresses(table, pos, valid,
                                            page_size=4, trash_page=7)
    # last entry: invalid row -> trash; pos 9 maps an unmapped (-1) page,
    # which must also redirect to trash rather than index page -1
    assert phys.tolist() == [[2, 2, 0, 7]]
    assert off.tolist() == [[0, 3, 0, 1]]


def test_truncate_releases_tail_pages():
    """Unit: rolling back tokens frees exactly the pages left with no
    live token, reverts their table entries, and keeps the rest."""
    st_ = kv_cache.init_page_state(slots=2, total_pages=8,
                                   max_pages_per_seq=4)
    st_ = kv_cache.alloc_pages(st_, 0, 3)          # room for 12 tokens
    st_ = kv_cache.advance(st_, 0, 9)              # 9 written (3 pages)
    st_ = kv_cache.truncate(st_, 0, 5, page_size=4)
    assert int(st_.seq_lens[0]) == 4               # 1 page still live
    assert int(st_.n_pages[0]) == 1
    assert int(st_.free_count) == 7
    row = np.asarray(st_.page_table[0])
    assert (row[1:] == -1).all() and row[0] >= 0
    # freed ids are unique and allocatable again
    ids = np.asarray(st_.free_stack)[:7]
    assert len(set(ids.tolist())) == 7
    # full rollback empties the slot
    st_ = kv_cache.truncate(st_, 0, 4, page_size=4)
    assert int(st_.n_pages[0]) == 0
    assert int(st_.free_count) == 8
    assert (np.asarray(st_.page_table[0]) == -1).all()


def test_truncate_respects_reclaimed_prefix():
    """Truncate after sliding-window reclamation: tail pages free, the
    (already-released) prefix stays untouched and first_page holds."""
    st_ = kv_cache.init_page_state(slots=1, total_pages=8,
                                   max_pages_per_seq=6)
    st_ = kv_cache.alloc_pages(st_, 0, 4)
    st_ = kv_cache.advance(st_, 0, 14)             # pages 0..3, ps=4
    st_ = kv_cache.release_prefix(st_, 0, 2)       # window reclaimed 0,1
    assert int(st_.first_page[0]) == 2
    st_ = kv_cache.truncate(st_, 0, 5, page_size=4)  # 14 -> 9 tokens
    assert int(st_.seq_lens[0]) == 9               # page 2 holds 8..11
    assert int(st_.first_page[0]) == 2
    assert int(st_.n_pages[0]) == 1                # page 3 released
    assert int(st_.free_count) == 7


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=500))
def test_truncate_page_accounting_property(seed):
    """Random alloc/advance/truncate/free streams against a host-side
    mirror: no page leaks, no double-maps/frees, tail release exact —
    the allocator-level certification of speculative rollback."""
    rng = np.random.default_rng(seed)
    slots, total, ps, maxp = 3, 10, 4, 5
    st_ = kv_cache.init_page_state(slots, total, maxp)
    n_pages = [0] * slots
    seq = [0] * slots
    free = total
    for _ in range(60):
        slot = int(rng.integers(slots))
        op = ["alloc", "advance", "truncate", "free"][int(rng.integers(4))]
        if op == "alloc":
            n = int(rng.integers(0, min(maxp - n_pages[slot], free) + 1))
            st_ = kv_cache.alloc_pages(st_, slot, n)
            n_pages[slot] += n
            free -= n
        elif op == "advance":
            n = int(rng.integers(0, n_pages[slot] * ps - seq[slot] + 1))
            st_ = kv_cache.advance(st_, slot, n)
            seq[slot] += n
        elif op == "truncate":
            n = int(rng.integers(0, seq[slot] + 1))
            st_ = kv_cache.truncate(st_, slot, n, ps)
            if n:
                seq[slot] -= n
                keep = min(-(-seq[slot] // ps), n_pages[slot])
                free += n_pages[slot] - keep
                n_pages[slot] = keep
        else:
            st_ = kv_cache.free_slot(st_, slot)
            free += n_pages[slot]
            n_pages[slot] = 0
            seq[slot] = 0
        assert int(st_.free_count) == free
        assert list(np.asarray(st_.n_pages)) == n_pages
        assert list(np.asarray(st_.seq_lens)) == seq
        table = np.asarray(st_.page_table)
        mapped = table[table >= 0].tolist()
        assert len(set(mapped)) == len(mapped) == sum(n_pages)
        stack_ids = set(np.asarray(st_.free_stack)[:free].tolist())
        assert len(stack_ids) == free, "duplicate ids on the free stack"
        assert not stack_ids & set(mapped), "page both free and mapped"
    # drain everything: the whole pool must come back exactly once
    for slot in range(slots):
        st_ = kv_cache.free_slot(st_, slot)
    assert int(st_.free_count) == total
    assert set(np.asarray(st_.free_stack).tolist()) == set(range(total))


# ---------------------------------------------------------------------------
# prompt-lookup drafter
# ---------------------------------------------------------------------------


def test_prompt_lookup_drafter_continues_periodic_runs():
    # periodic sequence: the 3-gram suffix recurs, drafts continue it
    assert propose_drafts([1, 2, 3, 1, 2, 3, 1, 2], 3) == [3, 1, 2]
    # most RECENT earlier occurrence wins
    assert propose_drafts([7, 5, 9, 5, 8, 5], 2,
                          max_ngram=1) == [8, 5]
    # falls back to shorter n-grams when the long suffix never recurred
    assert propose_drafts([1, 2, 9, 3, 9], 2) == [3, 9]
    # fewer than k tokens may follow the match
    assert propose_drafts([9, 9, 9, 9], 2) == [9]
    # no match / degenerate inputs -> no drafts, never an exception
    assert propose_drafts([5, 6, 7], 2) == []
    assert propose_drafts([5], 3) == []
    assert propose_drafts([1, 2, 3], 0) == []


# ---------------------------------------------------------------------------
# scheduler properties
# ---------------------------------------------------------------------------


@st.composite
def scheduler_cases(draw):
    slots = draw(st.integers(min_value=1, max_value=3))
    total_pages = draw(st.integers(min_value=2, max_value=10))
    page_size = draw(st.sampled_from([2, 4]))
    max_pages = draw(st.integers(min_value=2, max_value=6))
    budget = draw(st.integers(min_value=1, max_value=12))
    chunk = draw(st.sampled_from([2, 4, 8]))
    n_reqs = draw(st.integers(min_value=1, max_value=6))
    seed = draw(st.integers(min_value=0, max_value=100))
    return slots, total_pages, page_size, max_pages, budget, chunk, \
        n_reqs, seed


@settings(max_examples=30, deadline=None)
@given(scheduler_cases())
def test_scheduler_no_page_leaks_across_admit_evict_preempt(case):
    """Drive the scheduler exactly as the engine does (without a model)
    through random request streams on tiny pools — forcing admissions,
    evictions and recompute-preemptions — and assert the page-pool
    invariants (no leaks, no double-frees/maps) after every step."""
    slots, total_pages, page_size, max_pages, budget, chunk, n_reqs, seed \
        = case
    rng = np.random.default_rng(seed)
    cap = min(max_pages, total_pages) * page_size
    sched = Scheduler(slots=slots, total_pages=total_pages,
                      page_size=page_size, max_pages_per_seq=max_pages,
                      token_budget=budget, prefill_chunk=chunk)
    for i in range(n_reqs):
        plen = int(rng.integers(1, max(2, cap - 1)))
        gen = int(rng.integers(1, max(2, cap - plen)))
        sched.add(Request(req_id=i, prompt=rng.integers(0, 99, plen),
                          max_new_tokens=gen))
    for _ in range(500):
        if not sched.has_work():
            break
        plan = sched.schedule()
        sched.check_invariants()
        for slot, start, toks in plan.prefills:
            seq = sched.active[slot]
            assert start == seq.n_prefilled
            sched.advance_prefill(slot, len(toks))
            if not seq.prefilling and len(seq.tokens) == seq.n_prefilled:
                sched.append_token(slot, int(rng.integers(0, 99)))
        for slot in plan.decode_slots:
            sched.note_decoded(slot)
            sched.append_token(slot, int(rng.integers(0, 99)))
        for slot in range(slots):
            seq = sched.active[slot]
            if seq is not None and seq.done:
                sched.finish(slot)
        sched.check_invariants()
        if plan.n_tokens == 0 and not plan.admitted:
            break  # pool too small for any resident sequence
    sched.check_invariants()
    # every page must be back on the free list once all slots drain
    if not any(s is not None for s in sched.active) and not sched.waiting:
        assert sched.state.free() == total_pages


@settings(max_examples=20, deadline=None)
@given(scheduler_cases())
def test_windowed_scheduler_reclaims_without_leaks_or_double_frees(case):
    """The sliding-window reclamation property test: same random driver
    as above but with a window installed — ``check_invariants`` now also
    asserts no live (in-window) page is ever reclaimed, and the pool must
    still fully drain (every reclaimed page returned exactly once)."""
    slots, total_pages, page_size, max_pages, budget, chunk, n_reqs, seed \
        = case
    rng = np.random.default_rng(seed)
    window = int(rng.integers(1, 2 * page_size + 1))
    cap = min(max_pages, total_pages) * page_size
    sched = Scheduler(slots=slots, total_pages=total_pages,
                      page_size=page_size, max_pages_per_seq=max_pages,
                      token_budget=budget, prefill_chunk=chunk,
                      window=window)
    for i in range(n_reqs):
        plen = int(rng.integers(1, max(2, cap - 1)))
        gen = int(rng.integers(1, max(2, cap - plen)))
        sched.add(Request(req_id=i, prompt=rng.integers(0, 99, plen),
                          max_new_tokens=gen))
    for _ in range(500):
        if not sched.has_work():
            break
        plan = sched.schedule()
        sched.check_invariants()
        for slot, start, toks in plan.prefills:
            seq = sched.active[slot]
            sched.advance_prefill(slot, len(toks))
            sched.check_invariants()
            if not seq.prefilling and len(seq.tokens) == seq.n_prefilled:
                sched.append_token(slot, int(rng.integers(0, 99)))
        for slot in plan.decode_slots:
            sched.note_decoded(slot)
            sched.check_invariants()
            sched.append_token(slot, int(rng.integers(0, 99)))
        for slot in range(slots):
            seq = sched.active[slot]
            if seq is not None and seq.done:
                sched.finish(slot)
        sched.check_invariants()
        if plan.n_tokens == 0 and not plan.admitted:
            break
    sched.check_invariants()
    if not any(s is not None for s in sched.active) and not sched.waiting:
        assert sched.state.free() == total_pages


@settings(max_examples=20, deadline=None)
@given(scheduler_cases())
def test_scheduler_spec_rollback_no_leaks(case):
    """The speculative property test: same random driver, but decode
    slots carry random drafts and the driver accepts a random prefix
    (mimicking greedy verification), exercising note_verified's
    advance + truncate + (optionally window-)reclaim path. Page
    invariants must hold after every step and the pool must drain."""
    slots, total_pages, page_size, max_pages, budget, chunk, n_reqs, seed \
        = case
    rng = np.random.default_rng(seed)
    window = int(rng.integers(1, 2 * page_size + 1)) \
        if seed % 2 else None
    spec_k = int(rng.integers(1, 5))

    def random_drafter(tokens, k):
        return [int(t) for t in rng.integers(0, 99, k)]

    cap = min(max_pages, total_pages) * page_size
    sched = Scheduler(slots=slots, total_pages=total_pages,
                      page_size=page_size, max_pages_per_seq=max_pages,
                      token_budget=budget, prefill_chunk=chunk,
                      window=window, spec_k=spec_k,
                      drafter=random_drafter)
    for i in range(n_reqs):
        plen = int(rng.integers(1, max(2, cap - 1)))
        gen = int(rng.integers(1, max(2, cap - plen)))
        sched.add(Request(req_id=i, prompt=rng.integers(0, 99, plen),
                          max_new_tokens=gen))
    for _ in range(500):
        if not sched.has_work():
            break
        plan = sched.schedule()
        sched.check_invariants()
        for slot, start, toks in plan.prefills:
            seq = sched.active[slot]
            sched.advance_prefill(slot, len(toks))
            if not seq.prefilling and len(seq.tokens) == seq.n_prefilled:
                sched.append_token(slot, int(rng.integers(0, 99)))
        for slot in plan.decode_slots:
            drafts = plan.drafts.get(slot, [])
            m = int(rng.integers(0, len(drafts) + 1))
            sched.note_verified(slot, n_written=1 + len(drafts),
                                n_accepted=1 + m)
            sched.check_invariants()
            for _ in range(1 + m):
                sched.append_token(slot, int(rng.integers(0, 99)))
        for slot in range(slots):
            seq = sched.active[slot]
            if seq is not None and seq.done:
                sched.finish(slot)
        sched.check_invariants()
        if plan.n_tokens == 0 and not plan.admitted:
            break
    sched.check_invariants()
    if not any(s is not None for s in sched.active) and not sched.waiting:
        assert sched.state.free() == total_pages


def test_scheduler_skips_zero_page_victims():
    """Regression: ``_youngest_victim`` could select a sequence admitted
    earlier in the SAME ``schedule()`` call — zero pages allocated — so
    ``_try_alloc`` evicted and re-queued it while freeing nothing. Two
    decoders at a page boundary + one fresh admission force the case."""
    sched = Scheduler(slots=3, total_pages=3, page_size=2,
                      max_pages_per_seq=3, token_budget=8,
                      prefill_chunk=8)
    for i in (0, 1):
        sched.add(Request(req_id=i, prompt=np.asarray([1, 2], np.int32),
                          max_new_tokens=4))
    plan = sched.schedule()
    for slot, start, toks in plan.prefills:
        sched.advance_prefill(slot, len(toks))
        seq = sched.active[slot]
        if not seq.prefilling and len(seq.tokens) == seq.n_prefilled:
            sched.append_token(slot, 7)
    sched.check_invariants()
    # both residents decode next step and need a fresh page (boundary);
    # one free page remains, so the younger decoder's allocation fails
    # with the just-admitted (zero-page) request as the youngest resident
    sched.add(Request(req_id=2, prompt=np.asarray([5, 6], np.int32),
                      max_new_tokens=1))
    plan2 = sched.schedule()
    assert plan2.admitted == [2]
    # pre-fix: slot 2 was evicted (freeing zero pages) and re-queued,
    # leaving the slot empty and the pool no better off
    assert sched.active[2] is not None, \
        "zero-page victim was preempted (freed nothing)"
    assert 2 not in plan2.preempted
    assert plan2.decode_slots == [0]   # the younger decoder just waits
    # slot 2's own prefill then preempts the page-OWNING decoder (slot
    # 1) — a legitimate eviction that actually frees a page
    assert plan2.preempted == [1]
    sched.check_invariants()


def test_scheduler_packs_equal_length_prefill_groups():
    """Equal-length power-of-two chunks from different sequences land in
    one batched group; unequal lengths stay separate (rectangular rows
    are required by the SSM full-scan path)."""
    sched = Scheduler(slots=4, total_pages=32, page_size=4,
                      max_pages_per_seq=8, token_budget=32,
                      prefill_chunk=8)
    for i, plen in enumerate((8, 8, 8, 3)):
        sched.add(Request(
            req_id=i, prompt=np.arange(plen, dtype=np.int32),
            max_new_tokens=1))
    plan = sched.schedule()
    groups = plan.prefill_groups
    by_len = {len(g[0][2]): sorted(item[0] for item in g) for g in groups}
    assert by_len[8] == [0, 1, 2]   # three chunks -> ONE batched call
    assert by_len[2] == [3]         # pow2 chunk of the length-3 prompt
    assert plan.n_tokens == 26


def test_windowed_page_occupancy_stays_bounded():
    """A long decode against a small window holds O(window) pages, not
    O(seq_len): the reclamation actually frees the out-of-window prefix."""
    page_size, window = 4, 8
    sched = Scheduler(slots=1, total_pages=64, page_size=page_size,
                      max_pages_per_seq=64, token_budget=4,
                      prefill_chunk=4, window=window)
    sched.add(Request(req_id=0, prompt=np.arange(4, dtype=np.int32),
                      max_new_tokens=120))
    steps = 0
    max_resident = 0
    while sched.has_work() and steps < 400:
        plan = sched.schedule()
        for slot, start, toks in plan.prefills:
            sched.advance_prefill(slot, len(toks))
            seq = sched.active[slot]
            if not seq.prefilling and len(seq.tokens) == seq.n_prefilled:
                sched.append_token(slot, 1)
        for slot in plan.decode_slots:
            sched.note_decoded(slot)
            sched.append_token(slot, 1)
        if sched.active[0] is not None:
            max_resident = max(max_resident, sched._n_pages[0])
        for slot in range(1):
            seq = sched.active[slot]
            if seq is not None and seq.done:
                sched.finish(slot)
        sched.check_invariants()
        steps += 1
    assert not sched.has_work()
    assert sched.stats["reclaimed_pages"] > 20
    # window w spans at most ceil(w/page)+1 pages, +1 for the write head
    assert max_resident <= window // page_size + 2
    assert sched.state.free() == 64


def test_engine_sliding_window_reclamation_token_parity():
    """An all-local (fixed-window) model serves through the engine with
    window reclamation active, and stays token-identical to the
    full-recompute oracle while actually freeing out-of-window pages."""
    cfg = _tiny_cfg(sparse=False, layer_pattern=("local",), attn_window=6)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 4)]
    eng = _check_engine_parity(
        model, params, prompts, 24,
        EngineConfig(max_slots=2, page_size=4, total_pages=16,
                     max_pages_per_seq=16, token_budget=12,
                     prefill_chunk=8, backend="xla"))
    assert eng.sched.window == 6
    assert eng.sched.stats["reclaimed_pages"] > 0


def test_engine_reclaim_window_disabled_for_global_layers():
    """Any global (unwindowed) attention layer shares the page table, so
    reclamation must stay off — its pages are live forever."""
    from repro.serving.engine import ServingEngine as SE
    cfg = _tiny_cfg(local_global_ratio=1, attn_window=8)
    assert SE._reclaim_window(cfg) is None
    cfg2 = _tiny_cfg(layer_pattern=("local",), attn_window=8)
    assert SE._reclaim_window(cfg2) == 8


# ---------------------------------------------------------------------------
# paged decode kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window,softcap", [(None, None), (6, None),
                                            (None, 30.0), (6, 30.0)])
def test_paged_decode_kernel_interpret_matches_xla(window, softcap):
    rng = np.random.default_rng(0)
    b, hkv, g, dh, page, n_pages, total = 3, 2, 3, 16, 4, 5, 12
    q = jnp.asarray(rng.normal(size=(b, hkv, g, dh)), jnp.float32)
    k_pages = jnp.asarray(rng.normal(size=(total, page, hkv, dh)),
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(total, page, hkv, dh)),
                          jnp.float32)
    # rows with different lengths; unmapped tail entries are -1
    table = np.full((b, n_pages), -1, np.int32)
    perm = rng.permutation(total - 1)  # page `total-1` plays trash
    lengths = np.asarray([3, 11, 17], np.int32)
    lengths = np.minimum(lengths, n_pages * page)
    k = 0
    for i in range(b):
        for pg in range(-(-int(lengths[i]) // page)):
            table[i, pg] = perm[k]
            k += 1
    ref = paged_decode_attention(q, k_pages, v_pages,
                                 jnp.asarray(table), jnp.asarray(lengths),
                                 window=window, softcap=softcap,
                                 backend="xla")
    out = paged_decode_attention(q, k_pages, v_pages,
                                 jnp.asarray(table), jnp.asarray(lengths),
                                 window=window, softcap=softcap,
                                 backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged decode == full recompute (logits + tokens)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparse"])
@pytest.mark.parametrize("backend,interp", [("xla", False),
                                            ("pallas", True)])
def test_paged_decode_logits_match_full_forward(sparse, backend, interp):
    """Chunked paged prefill + paged decode reproduce the full-recompute
    forward's last-token logits at every step (model-level, no engine)."""
    cfg = _tiny_cfg(sparse=sparse)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    page_size, n_prompt, n_decode = 4, 10, 5
    toks = rng.integers(0, cfg.vocab_size,
                        n_prompt + n_decode).astype(np.int32)

    total_pages = -(-(n_prompt + n_decode) // page_size)
    st_ = kv_cache.init_page_state(1, total_pages, total_pages)
    st_ = kv_cache.alloc_pages(st_, 0, total_pages)
    cache = model.stack.init_paged_cache(1, total_pages, page_size,
                                         jnp.float32)

    def paged(tokens_chunk, pos):
        return model.paged_step(
            params, jnp.asarray(tokens_chunk[None]),
            jnp.asarray([pos], jnp.int32),
            jnp.asarray([len(tokens_chunk)], jnp.int32),
            cache, st_.page_table, jnp.asarray([0], jnp.int32),
            backend=backend, interpret=interp)

    def full_logits(n):
        h, _, _ = model.forward(params, {"tokens": jnp.asarray(toks[:n][None])})
        return np.asarray(model.logits_fn(params, h[:, -1:]))[0, 0]

    # prefill in two uneven chunks, then single-token decode steps
    logits, cache = paged(toks[:6], 0)
    logits, cache = paged(toks[6:n_prompt], 6)
    np.testing.assert_allclose(np.asarray(logits)[0, 0],
                               full_logits(n_prompt), atol=1e-4, rtol=1e-4)
    for i in range(n_decode):
        pos = n_prompt + i
        logits, cache = paged(toks[pos:pos + 1], pos)
        np.testing.assert_allclose(np.asarray(logits)[0, 0],
                                   full_logits(pos + 1),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# speculative decode certification
# ---------------------------------------------------------------------------


def _periodic_prompt(rng, vocab, period, reps):
    motif = rng.integers(0, vocab, period).astype(np.int32)
    return np.tile(motif, reps)


def _check_spec_vs_baseline(model, params, prompts, steps, spec_k=4,
                            **ecfg_kw):
    """Certify: greedy speculative decode is token-identical to the
    non-speculative engine (the PR-3 baseline path) on the same
    requests. Returns the speculative engine for stats assertions."""
    base = ServingEngine(model, params, EngineConfig(**ecfg_kw))
    ref = base.run(list(prompts), steps)
    eng = ServingEngine(model, params,
                        EngineConfig(spec_k=spec_k, **ecfg_kw))
    out = eng.run(list(prompts), steps)
    eng.sched.check_invariants()
    for i, (a, b) in enumerate(zip(ref, out)):
        assert a.tolist() == b.tolist(), \
            f"req {i}: spec {b.tolist()} != baseline {a.tolist()}"
    return eng, base


@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparse"])
def test_spec_decode_token_parity(sparse):
    """Acceptance: speculative greedy decode == plain greedy decode,
    dense and sparse junctions, with drafts actually being accepted
    (repetitive prompts feed the prompt-lookup drafter)."""
    cfg = _tiny_cfg(sparse=sparse)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(21)
    prompts = [_periodic_prompt(rng, cfg.vocab_size, 5, 3),
               rng.integers(0, cfg.vocab_size, 9).astype(np.int32)]
    eng, base = _check_spec_vs_baseline(
        model, params, prompts, 16,
        max_slots=2, page_size=4, total_pages=24, max_pages_per_seq=10,
        token_budget=24, prefill_chunk=8, backend="xla")
    assert eng.spec_k == 4
    assert eng.sched.stats["spec_drafted"] > 0
    # the multi-token verify must compress steps whenever drafts land
    if eng.sched.stats["spec_accepted"] > 0:
        assert eng.sched.stats["steps"] < base.sched.stats["steps"]


def test_spec_decode_parity_sliding_window_reclamation():
    """Speculation + window reclamation together: rollback must never
    collide with prefix release (reclaim runs only after truncate)."""
    cfg = _tiny_cfg(sparse=False, layer_pattern=("local",), attn_window=6)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(22)
    prompts = [_periodic_prompt(rng, cfg.vocab_size, 4, 3),
               rng.integers(0, cfg.vocab_size, 5).astype(np.int32)]
    eng, _ = _check_spec_vs_baseline(
        model, params, prompts, 24,
        max_slots=2, page_size=4, total_pages=16, max_pages_per_seq=16,
        token_budget=16, prefill_chunk=8, backend="xla")
    assert eng.sched.window == 6
    assert eng.sched.stats["reclaimed_pages"] > 0
    assert eng.sched.stats["spec_drafted"] > 0


def test_spec_decode_parity_under_preemption():
    """A pool too small for all requests forces evict + recompute while
    speculation is active; outputs still match the baseline engine."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(23)
    prompts = [_periodic_prompt(rng, cfg.vocab_size, 4, 2 + i % 2)
               for i in range(4)]
    eng, _ = _check_spec_vs_baseline(
        model, params, prompts, 8,
        max_slots=4, page_size=4, total_pages=7, max_pages_per_seq=6,
        token_budget=12, prefill_chunk=8, backend="xla")
    assert eng.sched.stats["preempted"] > 0, \
        "pool was sized to force preemption"


def test_spec_decode_parity_hybrid_attention_arch():
    """gemma3 smoke (sliding-window locals + globals under scan groups):
    an attention-only hybrid serves speculatively with full parity."""
    from repro.configs import get_config
    cfg = get_config("gemma3_4b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(24)
    prompts = [_periodic_prompt(rng, cfg.vocab_size, 4, 2),
               rng.integers(0, cfg.vocab_size, 6).astype(np.int32)]
    eng, _ = _check_spec_vs_baseline(
        model, params, prompts, 6,
        max_slots=2, page_size=4, total_pages=12, max_pages_per_seq=6,
        token_budget=16, prefill_chunk=8, backend="xla")
    assert eng.spec_k == 4


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2_1p2b"])
def test_spec_clamped_for_recurrent_stacks(arch):
    """Mamba / hybrid-mamba stacks cannot roll a recurrence back, so the
    engine must clamp ``spec_k`` to 0 — and still serve with parity."""
    from repro.configs import get_config
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(25)
    prompts = [_periodic_prompt(rng, cfg.vocab_size, 4, 2),
               rng.integers(0, cfg.vocab_size, 7).astype(np.int32)]
    eng, _ = _check_spec_vs_baseline(
        model, params, prompts, 6,
        max_slots=2, page_size=4, total_pages=12, max_pages_per_seq=6,
        token_budget=16, prefill_chunk=8, backend="xla")
    assert eng.spec_k == 0
    assert eng.sched.stats["spec_drafted"] == 0


# ---------------------------------------------------------------------------
# engine bugfix regressions
# ---------------------------------------------------------------------------


def test_add_request_rejects_duplicate_req_id():
    """Regression: an explicit req_id duplicating a queued or in-flight
    request silently cross-wired outputs/ttft between the two."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, EngineConfig(
        max_slots=2, page_size=4, total_pages=12, max_pages_per_seq=6,
        token_budget=16, prefill_chunk=8, backend="xla"))
    p = np.arange(4, dtype=np.int32)
    eng.add_request(p, 2, req_id=5)
    with pytest.raises(ValueError, match="req_id 5"):
        eng.add_request(p, 2, req_id=5)          # duplicate while queued
    eng.step()                                   # admit into a slot
    with pytest.raises(ValueError, match="req_id 5"):
        eng.add_request(p, 2, req_id=5)          # duplicate in flight
    while eng.sched.has_work():
        eng.step()
    assert len(eng.outputs[5]) == 2
    eng.add_request(p, 1, req_id=5)              # finished id: reusable
    # auto ids keep advancing past explicit ones
    assert eng.add_request(p, 1) > 5


def test_run_tolerates_preempt_only_plan(monkeypatch):
    """Regression: a plan with zero tokens and zero admissions but a
    preemption (allocations failed AFTER preemption freed pages) made
    ``run`` declare the engine stuck, even though the freed pages let
    the very next step progress."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, EngineConfig(
        max_slots=2, page_size=4, total_pages=12, max_pages_per_seq=6,
        token_budget=16, prefill_chunk=8, backend="xla"))
    real = eng.sched.schedule
    first = {"done": False}

    def preempt_only_once():
        if not first["done"]:
            first["done"] = True
            return StepPlan(decode_slots=[], prefills=[], preempted=[0])
        return real()

    monkeypatch.setattr(eng.sched, "schedule", preempt_only_once)
    outs = eng.run([np.arange(4, dtype=np.int32)], 3)   # pre-fix: raises
    assert len(outs[0]) == 3


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------


def test_engine_greedy_token_parity_32_steps():
    """Acceptance: paged-cache decode is token-identical to the
    full-recompute path over >= 32 greedy steps, 4 mixed-length prompts
    through continuous batching (smoke-sized engine, CI tier-1)."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 8, 16)]
    eng = _check_engine_parity(
        model, params, prompts, 32,
        EngineConfig(max_slots=4, page_size=8, total_pages=28,
                     max_pages_per_seq=7, token_budget=20,
                     prefill_chunk=8, backend="xla"))
    assert eng.sched.stats["finished"] == 4


def test_engine_sparse_junctions_and_pallas_decode():
    """Sparse FFN junctions + the Pallas paged-decode kernel (interpret)
    through the engine, vs full recompute."""
    cfg = _tiny_cfg(sparse=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9)]
    _check_engine_parity(
        model, params, prompts, 8,
        EngineConfig(max_slots=2, page_size=4, total_pages=12,
                     max_pages_per_seq=6, token_budget=16,
                     prefill_chunk=8, backend="pallas", interpret=True))


def test_engine_preemption_recompute_parity():
    """A pool too small for all requests forces evict + recompute
    preemption; outputs must still match isolated generation."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 12, 5, 9)]
    eng = _check_engine_parity(
        model, params, prompts, 8,
        EngineConfig(max_slots=4, page_size=4, total_pages=7,
                     max_pages_per_seq=6, token_budget=8,
                     prefill_chunk=8, backend="xla"))
    assert eng.sched.stats["preempted"] > 0, \
        "pool was sized to force preemption"


def test_engine_ssm_state_through_cache_interface():
    """Mamba recurrent state rides the paged-cache interface: per-slot
    state rows advance over exact prompt chunks and survive continuous
    batching."""
    from repro.configs import get_config
    cfg = get_config("mamba2-130m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 12)]
    _check_engine_parity(
        model, params, prompts, 6,
        EngineConfig(max_slots=2, page_size=4, total_pages=12,
                     max_pages_per_seq=6, token_budget=16,
                     prefill_chunk=8, backend="xla"))


def test_engine_slot_reuse_resets_ssm_state():
    """Regression: a freed slot re-admitted for a new request must not
    leak the previous occupant's recurrent state. One slot serves two
    mamba requests back-to-back; the second must match isolated
    generation (stale ssd/conv state would corrupt it)."""
    from repro.configs import get_config
    cfg = get_config("mamba2-130m", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 7)]
    _check_engine_parity(
        model, params, prompts, 6,
        EngineConfig(max_slots=1, page_size=4, total_pages=6,
                     max_pages_per_seq=4, token_budget=16,
                     prefill_chunk=8, backend="xla"))


@pytest.mark.parametrize("arch", ["gemma3_4b", "zamba2_1p2b",
                                  "deepseek_moe_16b"])
def test_engine_parity_structured_archs(arch):
    """Engine vs full recompute on the structurally-interesting stacks:
    gemma3 (5:1 sliding-window local layers + scan groups), zamba2
    (mamba backbone + shared attention block with its own page pools
    under scan), deepseek-moe (routed experts; capacity unconstrained so
    decode and teacher-forcing see the same expert assignment)."""
    from repro.configs import get_config
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=100.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(15)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9)]
    _check_engine_parity(
        model, params, prompts, 6,
        EngineConfig(max_slots=2, page_size=4, total_pages=12,
                     max_pages_per_seq=6, token_budget=16,
                     prefill_chunk=8, backend="xla"))


def test_engine_rejects_capacity_constrained_moe():
    """Finite expert capacity + garbage rows from inactive slots would
    let empty slots evict real tokens from expert buckets; the engine
    must refuse and point at dropless decode (the legacy loop and the
    generate() wrapper handle the fallback)."""
    from repro.nn import MoEConfig
    cfg = _tiny_cfg(sparse=False).with_(
        moe=MoEConfig(n_routed=4, top_k=1, d_expert=64,
                      capacity_factor=1.25))
    model = build_model(cfg)
    with pytest.raises(NotImplementedError, match="capacity"):
        ServingEngine(model, None, EngineConfig(
            max_slots=2, page_size=4, total_pages=8, max_pages_per_seq=4))


def test_generate_wrapper_routes_through_engine():
    """launch.serve.generate == the legacy dense-cache loop (greedy), now
    served by the engine underneath."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(11)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (3, 8)), jnp.int32)
    toks_eng, _ = generate(model, params, prompt, s_max=24, steps=6)
    toks_ref, _ = generate_cached(model, params, prompt, s_max=24, steps=6)
    np.testing.assert_array_equal(np.asarray(toks_eng),
                                  np.asarray(toks_ref))


def test_generate_cached_nongreedy_splits_key_per_step():
    """The sampled path draws the FIRST token too (not argmax) and uses a
    fresh split every step: different keys give different streams, and no
    two steps of one stream reuse the same draw pattern degenerately."""
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(12)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    outs = []
    for seed in (1, 2, 3):
        toks, _ = generate_cached(model, params, prompt, s_max=24, steps=6,
                                  greedy=False, key=jax.random.key(seed))
        outs.append(np.asarray(toks))
    greedy, _ = generate_cached(model, params, prompt, s_max=24, steps=6)
    # all three sampled streams equal to greedy would mean sampling is off
    assert any((o != np.asarray(greedy)).any() for o in outs)
    # first token is sampled: with 3 keys over vocab 256, at least one
    # first-token draw should differ from the greedy argmax
    assert any((o[:, 0] != np.asarray(greedy)[:, 0]).any() for o in outs)
    # determinism: same key -> same stream
    again, _ = generate_cached(model, params, prompt, s_max=24, steps=6,
                               greedy=False, key=jax.random.key(1))
    np.testing.assert_array_equal(outs[0], np.asarray(again))


def test_serving_smoke_mixed_requests():
    """CI smoke: tiny config, 4 mixed-length requests, 8 decode steps —
    the fast end-to-end gate for the serving workflow."""
    cfg = _tiny_cfg(sparse=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 6, 12)]
    eng = ServingEngine(model, params, EngineConfig(
        max_slots=4, page_size=4, total_pages=24, max_pages_per_seq=6,
        token_budget=16, prefill_chunk=8, backend="xla"))
    outs = eng.run(prompts, 8)
    assert all(len(o) == 8 for o in outs)
    eng.sched.check_invariants()
    assert eng.sched.stats["finished"] == 4
    assert eng.sched.state.free() == 24  # all pages returned


# ---------------------------------------------------------------------------
# observability: engine counters + bounded host state (the PR-7 ttft leak)
# ---------------------------------------------------------------------------


def test_engine_obs_counters_consistent():
    """Engine metrics agree with the run's ground truth: emitted tokens ==
    sum of output lengths, request lifecycle balances, spec proposed ==
    accepted + rolled_back, TTFT histogram has one sample per request,
    and page occupancy stays a fraction."""
    from repro.obs.metrics import Registry
    cfg = _tiny_cfg(sparse=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(31)
    reg = Registry()
    eng = ServingEngine(model, params, EngineConfig(
        max_slots=4, page_size=4, total_pages=24, max_pages_per_seq=8,
        token_budget=16, prefill_chunk=8, backend="xla", spec_k=4),
        registry=reg)
    prompts = [np.full(6 + i, (11 * i + 3) % cfg.vocab_size, np.int32)
               for i in range(4)]
    outs = eng.run(prompts, 10)

    emitted = reg.counter("serving_emitted_tokens_total").value()
    assert emitted == sum(len(o) for o in outs) == 40
    req = reg.counter("serving_requests_total")
    assert req.value(event="added") == 4
    assert req.value(event="finished") == 4
    cnt, _ = reg.histogram("serving_ttft_seconds").stats()
    assert cnt == 4                       # exactly one TTFT per request
    icnt, _ = reg.histogram("serving_itl_seconds").stats()
    assert icnt > 0
    spec = reg.counter("serving_spec_tokens_total")
    drafted = spec.value(result="proposed")
    assert drafted > 0
    assert drafted == spec.value(result="accepted") \
        + spec.value(result="rolled_back")
    # the engine's phase counter and the scheduler's plan counter count
    # the same drafts independently
    assert reg.counter("serving_tokens_total").value(
        phase="spec_draft") == drafted
    assert reg.counter("sched_plan_tokens_total").value(
        phase="draft") == drafted
    assert drafted == eng.sched.stats["spec_drafted"]
    assert 0.0 <= reg.gauge("serving_page_occupancy").value() <= 1.0
    assert reg.gauge("serving_pages_highwater").value() > 0
    scnt, ssum = reg.histogram("serving_step_seconds").stats()
    assert scnt == eng.sched.stats["steps"] and ssum > 0


def test_engine_host_state_bounded_over_many_requests():
    """Regression for the PR-7 leak: per-request host dicts must not grow
    with completed requests. Run several waves through one engine and
    assert the timestamp map drains and registry cardinality is flat."""
    from repro.obs.metrics import Registry
    cfg = _tiny_cfg(sparse=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(33)
    reg = Registry()
    eng = ServingEngine(model, params, EngineConfig(
        max_slots=2, page_size=4, total_pages=16, max_pages_per_seq=4,
        token_budget=12, prefill_chunk=8, backend="xla"), registry=reg)
    series_after_wave = []
    for wave in range(3):
        prompts = [rng.integers(0, cfg.vocab_size, 3 + (i + wave) % 4
                                ).astype(np.int32) for i in range(6)]
        eng.run(prompts, 4)
        assert eng._t_added == {}, "admission timestamps must drain"
        assert all(t is None for t in eng._last_tok)
        h = reg.histogram("serving_ttft_seconds")
        series_after_wave.append(
            (len(h.series),
             len(reg.counter("serving_requests_total").series)))
    # 18 requests later: per-metric series counts did not grow past wave 1
    assert series_after_wave[0] == series_after_wave[-1]
    cnt, _ = reg.histogram("serving_ttft_seconds").stats()
    assert cnt == 18
    # outputs were popped by run(); nothing references finished requests
    assert eng.outputs == {}
