"""Data pipeline determinism/resumability + the paper MLP model."""
import numpy as np
import pytest

from repro.configs.paper_mlp import MNIST_2J, rho_from_dout
from repro.data import BigramLM, synthetic_features, synthetic_mnist
from repro.nn.mlp import MLPConfig, SparseMLP, train_mlp


def test_bigram_batches_deterministic():
    d1 = BigramLM(vocab_size=64, seed=3)
    d2 = BigramLM(vocab_size=64, seed=3)
    b1 = d1.batch(17, 8, 16)
    b2 = d2.batch(17, 8, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    b3 = d1.batch(18, 8, 16)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_bigram_host_sharding_partitions_batch():
    d = BigramLM(vocab_size=64, seed=0)
    full = d.batch(5, 8, 16, process_index=0, process_count=1)
    parts = [d.batch(5, 8, 16, process_index=i, process_count=4)
             for i in range(4)]
    assert all(p["tokens"].shape == (2, 16) for p in parts)


def test_bigram_is_learnable_structure():
    """Most next-tokens come from the transition table (low noise)."""
    d = BigramLM(vocab_size=32, branching=2, noise=0.0, seed=0)
    b = d.batch(0, 4, 64)
    tok, lab = b["tokens"], b["labels"]
    ok = 0
    for i in range(4):
        for t in range(63):
            ok += lab[i, t] in d.table[tok[i, t]]
    assert ok == 4 * 63


def test_synthetic_mnist_shapes_and_padding():
    x_tr, y_tr, x_te, y_te = synthetic_mnist(n_train=200, n_test=50)
    assert x_tr.shape == (200, 800)  # padded to 800 (paper footnote 8)
    assert (x_tr[:, 784:] == 0).all()
    assert y_tr.min() >= 0 and y_tr.max() < 10
    x_crop, *_ = synthetic_mnist(n_train=50, n_test=10, n_features=200)
    assert x_crop.shape == (50, 200)


def test_mlp_weight_count_matches_paper():
    cfg = MLPConfig(n_net=MNIST_2J, rho=rho_from_dout(MNIST_2J, (20, 10)),
                    method="clashfree")
    m = SparseMLP(cfg)
    assert m.n_weights() == 17000  # Table I sparse |W|
    assert abs(m.density() - 0.21) < 0.005


def test_mlp_trains_above_chance():
    data = synthetic_mnist(n_train=1500, n_test=400, seed=0)
    cfg = MLPConfig(n_net=(800, 50, 10), rho=(0.2, 1.0),
                    method="clashfree")
    _, acc = train_mlp(SparseMLP(cfg), data, epochs=6, batch=128)
    assert acc > 0.3  # 10 classes, chance = 0.1


def test_mlp_gather_equals_mask_training_dynamics():
    """mode='mask' and mode='gather' give the same loss trajectory — the
    paper's claim that masked-dense training is per-edge training."""
    import jax
    import jax.numpy as jnp
    data = synthetic_mnist(n_train=600, n_test=100, seed=1)
    rho = rho_from_dout(MNIST_2J, (20, 10))
    lm = SparseMLP(MLPConfig(n_net=MNIST_2J, rho=rho, mode="mask",
                             method="clashfree", seed=5))
    lg = SparseMLP(MLPConfig(n_net=MNIST_2J, rho=rho, mode="gather",
                             method="clashfree", seed=5))
    x = jnp.asarray(data[0][:64])
    y = jnp.asarray(data[1][:64])
    pm = lm.init(jax.random.key(0))
    pg = lg.init(jax.random.key(0))
    # align weights: copy gather weights into the masked dense weights
    from repro.core import gather_weights_to_dense, to_mask
    for i, (layer_m, layer_g) in enumerate(zip(lm.layers, lg.layers)):
        if layer_g.pattern is not None:
            pm[f"j{i}"]["w"] = gather_weights_to_dense(
                pg[f"j{i}"]["w"], layer_g.pattern.idx, layer_g.spec.n_in)
        else:
            pm[f"j{i}"]["w"] = pg[f"j{i}"]["w"]
        pm[f"j{i}"]["b"] = pg[f"j{i}"]["b"]
    l_m = lm.loss(pm, x, y)
    l_g = lg.loss(pg, x, y)
    np.testing.assert_allclose(l_m, l_g, rtol=1e-5)
    # gradients agree on the existing edges
    gm = jax.grad(lm.loss)(pm, x, y)["j0"]["w"]
    gg = jax.grad(lg.loss)(pg, x, y)["j0"]["w"]
    from repro.core import dense_weights_to_gather
    gm_on_edges = dense_weights_to_gather(gm, lg.layers[0].pattern.idx)
    np.testing.assert_allclose(gm_on_edges, gg, atol=1e-6)
