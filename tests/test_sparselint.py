"""sparselint certifies the certifier: deliberately broken artifacts must
produce exactly the expected finding codes, and the shipped tree must
produce none."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro.analysis import grid_pass, jaxpr_pass, pattern_pass
from repro.analysis.capture import CapturedLaunch, capture_launch
from repro.analysis.findings import Finding, Report, apply_suppressions
from repro.compat import shard_map
from repro.core import sparsity
from repro.core.block_pattern import (fit_block_pattern, make_block_pattern,
                                      partition_pattern)


def _codes(findings):
    return sorted({f.code for f in findings})


# ---------------------------------------------------------------------------
# Pass 1: grid analysis
# ---------------------------------------------------------------------------


def test_injected_aliasing_kernel_flags_sl101():
    """The race-broken csd_spmm_fwd copy (accumulation dim hoisted
    outermost) must produce SL101 and nothing else."""
    case = grid_pass.injected_alias_case()
    findings, _ = grid_pass.analyze_launch(case.build(), case)
    assert _codes(findings) == ["SL101"], findings
    assert len(findings) > 0


def _manual_launch(in_spec, in_shape, grid=(2,)):
    return CapturedLaunch(
        name="synthetic", grid=grid,
        in_specs=[in_spec],
        out_specs=[pl.BlockSpec((2, 5), lambda i: (0, 0))],
        out_shapes=[((4, 10), np.dtype("float32"))],
        in_shapes=[(in_shape, np.dtype("float32"))],
        scalar_args=[], scratch_shapes=[], num_scalar_prefetch=0)


def test_non_dividing_blockspec_flags_sl102():
    launch = _manual_launch(pl.BlockSpec((3, 5), lambda i: (0, 0)), (4, 10))
    findings, _ = grid_pass.analyze_launch(
        launch, grid_pass.KernelCase("synthetic", lambda: launch))
    assert "SL102" in _codes(findings), findings


def test_out_of_range_index_map_flags_sl105():
    launch = _manual_launch(pl.BlockSpec((2, 5), lambda i: (i + 5, 0)),
                            (4, 10))
    findings, _ = grid_pass.analyze_launch(
        launch, grid_pass.KernelCase("synthetic", lambda: launch))
    assert "SL105" in _codes(findings), findings


def test_vmem_budget_flags_sl104():
    launch = _manual_launch(pl.BlockSpec((2, 5), lambda i: (0, 0)), (4, 10))
    findings, _ = grid_pass.analyze_launch(
        launch, grid_pass.KernelCase("synthetic", lambda: launch),
        vmem_budget=16)
    assert "SL104" in _codes(findings), findings


def test_shipped_kernels_have_no_findings():
    """Every shipped Pallas kernel family passes the grid pass clean."""
    findings, cost, covered = grid_pass.run()
    assert findings == [], [str(f.to_dict()) for f in findings]
    # the ISSUE scope: fwd/dx/dw in 4-D and 5-D forms + paged decode
    for want in ("csd_spmm_fwd_4d_relu", "csd_spmm_fwd_5d_batched",
                 "csd_spmm_dx_4d", "csd_spmm_dx_5d_batched",
                 "csd_spmm_dw_4d_db", "csd_spmm_dw_5d_batched",
                 "paged_decode_attention", "flash_attention_fwd"):
        assert want in covered, covered
        assert cost[want]["steps"] > 1


def test_capture_records_real_launch():
    """capture_launch sees the true grid of the real entry point."""
    bp = make_block_pattern(256, 512, 0.5, block_in=128, block_out=128)
    from repro.kernels import csd_spmm
    x = jnp.zeros((128, bp.n_in), jnp.float32)
    w = jnp.zeros((bp.n_rb, bp.d_in_b, bp.block_in, bp.block_out),
                  jnp.float32)
    launch = capture_launch(csd_spmm.csd_spmm_fwd, x, w, bp.block_idx,
                            block_m=128)
    assert launch.grid == (1, bp.n_rb, bp.d_in_b)
    assert launch.num_scalar_prefetch == 1
    # index maps evaluate with the real pattern array
    blk = launch.eval_index_map(launch.in_specs[0], (0, 1, 0))
    assert blk == (0, int(bp.block_idx[1, 0]))


# ---------------------------------------------------------------------------
# Pass 2: jaxpr lint
# ---------------------------------------------------------------------------


def test_shard_map_missing_psum_flags_sl205():
    mesh = jax.make_mesh((1,), ("model",))

    def broken(x):
        return shard_map(lambda xl: xl.sum(axis=0), mesh=mesh,
                         in_specs=P("model"), out_specs=P(),
                         check_vma=False)(x)

    traced = jax.jit(broken).trace(jax.ShapeDtypeStruct((4, 8),
                                                        jnp.float32))
    findings = jaxpr_pass.lint_closed_jaxpr(traced.jaxpr, "broken")
    assert _codes(findings) == ["SL205"], findings


def test_shard_map_with_psum_is_clean():
    mesh = jax.make_mesh((1,), ("model",))

    def ok(x):
        return shard_map(
            lambda xl: jax.lax.psum(xl.sum(axis=0), "model"), mesh=mesh,
            in_specs=P("model"), out_specs=P(), check_vma=False)(x)

    traced = jax.jit(ok).trace(jax.ShapeDtypeStruct((4, 8), jnp.float32))
    assert jaxpr_pass.lint_closed_jaxpr(traced.jaxpr, "ok") == []


def test_whole_slab_dequant_flags_sl206():
    """The injected quantization-defeating junction (whole-slab upcast
    before csd_matmul) must trip SL206; the shipped fused-dequant path
    on the same shapes must stay clean."""
    from repro.core.block_pattern import make_block_pattern
    from repro.core.quant import dequantize_slab, quantize_slab
    from repro.kernels import ops as kops

    bp = make_block_pattern(64, 64, 0.5, block_in=16, block_out=16, seed=0)
    w_aval = jax.ShapeDtypeStruct((bp.n_rb, bp.d_in_b, 16, 16), jnp.int8)
    s_aval = jax.ShapeDtypeStruct((bp.n_rb, bp.d_in_b), jnp.float32)
    x_aval = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def bad(x, w, s):
        return kops.csd_matmul(x, dequantize_slab(w, s), bp, backend="xla")

    traced = jax.jit(bad).trace(x_aval, w_aval, s_aval)
    findings = jaxpr_pass._lint_quant(traced.jaxpr, "bad", None)
    assert _codes(findings) == ["SL206"], findings

    def good(x, w, s):
        return kops.csd_matmul(x, w, bp, backend="xla", w_scale=s)

    traced = jax.jit(good).trace(x_aval, w_aval, s_aval)
    assert jaxpr_pass._lint_quant(traced.jaxpr, "good", None) == []
    # the batched (expert-major) fallback's vmapped per-slot converts
    # must not pattern-match the 5-D slab shape either
    e = 3
    w5 = jax.ShapeDtypeStruct((e, bp.n_rb, bp.d_in_b, 16, 16), jnp.int8)
    s5 = jax.ShapeDtypeStruct((e, bp.n_rb, bp.d_in_b), jnp.float32)
    x5 = jax.ShapeDtypeStruct((e, 4, 64), jnp.float32)
    traced = jax.jit(good).trace(x5, w5, s5)
    assert jaxpr_pass._lint_quant(traced.jaxpr, "good5", None) == []


def test_selftest_inject_produces_sl206():
    """run(inject=True) adds the broken quant subject and it must fire —
    the CI gate that proves SL206 has teeth."""
    traced, _, subject = jaxpr_pass._trace_quant_inject(None)
    assert subject == "quant_inject[selftest]"
    findings = jaxpr_pass._lint_quant(traced.jaxpr, subject, None)
    assert _codes(findings) == ["SL206"], findings


def test_missing_donation_flags_sl202():
    aval = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)  # 4 MiB

    def f(x):
        return x * 2.0

    text = jax.jit(f).trace(aval).lower().as_text()
    findings = jaxpr_pass.lint_donation(text, (aval,), "nodonate")
    assert _codes(findings) == ["SL202"], findings

    text = jax.jit(f, donate_argnums=(0,)).trace(aval).lower().as_text()
    assert jaxpr_pass.lint_donation(text, (aval,), "donate") == []


def test_host_callback_flags_sl201():
    def f(x):
        jax.debug.callback(lambda v: None, x)
        return x + 1

    traced = jax.jit(f).trace(jax.ShapeDtypeStruct((4,), jnp.float32))
    findings = jaxpr_pass.lint_closed_jaxpr(traced.jaxpr, "cb")
    assert "SL201" in _codes(findings), findings


def test_large_baked_constant_flags_sl204():
    big = jnp.zeros((512, 1024), jnp.float32)  # 2 MiB closure constant

    def f(x):
        return x + big

    traced = jax.jit(f).trace(
        jax.ShapeDtypeStruct((512, 1024), jnp.float32))
    findings = jaxpr_pass.lint_closed_jaxpr(traced.jaxpr, "const")
    assert "SL204" in _codes(findings), findings


# ---------------------------------------------------------------------------
# Pass 3: pattern invariants
# ---------------------------------------------------------------------------


def _demo():
    return make_block_pattern(512, 512, 0.5, block_in=128, block_out=128)


def test_valid_pattern_is_clean():
    assert pattern_pass.check_pattern(_demo(), "demo") == []


def test_duplicate_edge_flags_sl301():
    bp = _demo()
    idx = np.asarray(bp.block_idx).copy()
    idx[0, 1] = idx[0, 0]  # same left block twice in one row
    bad = dataclasses.replace(bp, block_idx=idx)
    codes = _codes(pattern_pass.check_pattern(bad, "dup"))
    assert "SL301" in codes, codes


def test_scatter_gather_mismatch_flags_sl303():
    bp = _demo()
    oi = np.asarray(bp.out_idx).copy()
    osl = np.asarray(bp.out_slot)
    # retarget one scatter entry of left block 0 at a (right block, slot)
    # cell it does not actually feed — still duplicate-free, but no longer
    # the transpose of block_idx
    taken = {(int(r), int(s)) for r, s in zip(oi[0], osl[0])}
    s0 = int(osl[0, 0])
    oi[0, 0] = next(r for r in range(bp.n_rb) if (r, s0) not in taken)
    bad = dataclasses.replace(bp, out_idx=oi)
    codes = _codes(pattern_pass.check_pattern(bad, "mismatch"))
    assert "SL303" in codes, codes


def test_out_of_range_pattern_flags_sl304():
    bp = _demo()
    idx = np.asarray(bp.block_idx).copy()
    idx[0, 0] = bp.n_lb + 3
    bad = dataclasses.replace(bp, block_idx=idx)
    assert "SL304" in _codes(pattern_pass.check_pattern(bad, "oob"))


def test_unbalanced_shard_pattern_flags_sl305():
    part = partition_pattern(_demo(), 2)
    ov = np.asarray(part.out_valid).copy()
    ov[1, 0, :] = 0  # drop one shard's slots: unbalanced work
    bad = dataclasses.replace(part, out_valid=ov)
    codes = _codes(pattern_pass.check_partition(bad, "unbal"))
    assert "SL305" in codes, codes


def test_valid_partition_is_clean():
    part = partition_pattern(_demo(), 4)
    assert pattern_pass.check_partition(part, "demo") == []


# ---------------------------------------------------------------------------
# debug wiring + repair semantics (satellite 3)
# ---------------------------------------------------------------------------


def test_fit_block_pattern_debug_certifies():
    class SP:
        enabled, block_in, block_out = True, 128, 128
        method, seed, cf_type, dither = "clashfree", 0, 1, False

    bp = fit_block_pattern(512, 512, 0.5, SP(), debug=True)
    assert bp is not None


def test_pattern_debug_env_flag(monkeypatch):
    monkeypatch.setenv("REPRO_PATTERN_DEBUG", "1")
    part = partition_pattern(_demo(), 2)  # must not raise
    assert part.n_shards == 2


def test_repair_raises_when_impossible():
    rng = np.random.default_rng(0)
    # left id 0 occurs 6 times but only 2 rows exist
    idx = np.zeros((2, 3), np.int64)
    with pytest.raises(ValueError, match="impossible"):
        sparsity._repair_duplicates(idx, n_left=4, rng=rng)
    # rows wider than the left side can never be duplicate-free
    idx = np.tile(np.arange(5), (2, 1))
    with pytest.raises(ValueError, match="impossible"):
        sparsity._repair_duplicates(idx, n_left=3, rng=rng)


def test_repair_still_fixes_feasible_duplicates():
    rng = np.random.default_rng(0)
    idx = np.array([[0, 0, 1], [2, 3, 1]])  # feasible: swap 0 with 2/3
    out = sparsity._repair_duplicates(idx, n_left=4, rng=rng)
    assert all(len(set(r)) == len(r) for r in out.tolist())
    assert sorted(np.asarray(out).reshape(-1).tolist()) == \
        sorted(idx.reshape(-1).tolist())


# ---------------------------------------------------------------------------
# report + CLI plumbing
# ---------------------------------------------------------------------------


def test_suppressions_mark_but_keep_findings():
    fs = [Finding("SL101", "kern_a", "boom"),
          Finding("SL101", "kern_b", "boom")]
    out = apply_suppressions(fs, [("SL101", "kern_a", "known issue")])
    assert out[0].suppressed and out[0].justification == "known issue"
    assert not out[1].suppressed
    r = Report(findings=out)
    assert len(r.unsuppressed()) == 1
    assert "suppressed" in r.to_text()


def test_cli_exit_codes():
    from repro.analysis import lint
    assert lint.main(["--passes", "grid,pattern", "--format", "json",
                      "--output", "/dev/null"]) == 0
    assert lint.main(["--passes", "grid", "--selftest-inject",
                      "--output", "/dev/null"]) == 1
