"""Cross-mode equivalence of the unified ``csd_matmul`` junction path.

Every execution route of one ``BlockPattern`` must agree, forward and
backward, with the masked-dense oracle — with and without the fused
bias/activation epilogue:

* ``mask``              — x @ (W_dense * mask)  (the paper-dynamics oracle)
* ``block_gather``      — csd_matmul, XLA column-parallel dataflow
* ``block_scatter``     — csd_matmul, XLA row-parallel dataflow
* ``pallas``            — csd_matmul, Pallas kernels in interpret mode
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SparseLinear, SparseLinearSpec, block_weights_to_dense,
    make_block_pattern,
)
from repro.kernels import ops
from repro.kernels.ref import block_gather_ref, block_scatter_ref

_ROUTES = [
    dict(backend="xla", dataflow="gather"),
    dict(backend="xla", dataflow="scatter"),
    dict(backend="pallas", block_m=8, interpret=True),
]


def _setup(seed=0, n_in=64, n_out=48, bl=8, br=8, rho=0.5, m=12):
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=seed)
    keys = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(keys[0], (m, n_in))
    w = jax.random.normal(keys[1], (bp.n_rb, bp.d_in_b, bl, br))
    b = jax.random.normal(keys[2], (n_out,))
    return bp, x, w, b


def _oracle_act(name):
    return {None: lambda z: z, "relu": jax.nn.relu,
            "gelu": lambda z: jax.nn.gelu(z, approximate=True)}[name]


@pytest.mark.parametrize("activation", [None, "relu", "gelu"])
@pytest.mark.parametrize("use_bias", [True, False])
def test_all_routes_match_masked_dense_forward(activation, use_bias):
    bp, x, w, b = _setup()
    bias = b if use_bias else None
    wd = block_weights_to_dense(w, bp)
    mask = jnp.asarray(bp.to_mask())
    z = x @ (wd * mask)  # wd is already zero off-pattern; mask is belt
    if use_bias:
        z = z + b
    y_ref = _oracle_act(activation)(z)
    for kw in _ROUTES:
        y = ops.csd_matmul(x, w, bp, bias=bias, activation=activation, **kw)
        np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5,
                                   err_msg=f"{kw} act={activation}")


@pytest.mark.parametrize("activation", [None, "relu", "gelu"])
def test_all_routes_match_masked_dense_gradients(activation):
    bp, x, w, b = _setup(seed=1)
    act = _oracle_act(activation)

    def loss_dense(w, b, x):
        return jnp.sum(jnp.sin(act(x @ block_weights_to_dense(w, bp) + b)))

    g_ref = jax.grad(loss_dense, argnums=(0, 1, 2))(w, b, x)
    for kw in _ROUTES:
        def loss_sparse(w, b, x, kw=kw):
            y = ops.csd_matmul(x, w, bp, bias=b, activation=activation,
                               **kw)
            return jnp.sum(jnp.sin(y))
        g = jax.grad(loss_sparse, argnums=(0, 1, 2))(w, b, x)
        for got, ref in zip(g, g_ref):
            np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4,
                                       err_msg=f"{kw} act={activation}")


def test_fused_equals_unfused_epilogue():
    """The fused epilogue must be bit-comparable to epilogue-outside."""
    bp, x, w, b = _setup(seed=2)
    for kw in _ROUTES:
        unfused = jax.nn.relu(
            ops.csd_matmul(x, w, bp, **kw) + b)
        fused = ops.csd_matmul(x, w, bp, bias=b, activation="relu", **kw)
        np.testing.assert_allclose(fused, unfused, atol=1e-6, rtol=1e-6)


def test_ref_oracles_match_csd_matmul():
    """The demoted einsum forms stay honest as oracles."""
    bp, x, w, _ = _setup(seed=3)
    y_g = block_gather_ref(x, w, bp.block_idx, bp.block_in, bp.block_out)
    y_s = block_scatter_ref(x, w, bp.out_idx, bp.out_slot, bp.block_in,
                            bp.block_out)
    y = ops.csd_matmul(x, w, bp, backend="xla")
    np.testing.assert_allclose(y_g, y, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(y_s, y, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["block_gather", "block_scatter"])
def test_sparse_linear_block_modes_route_through_csd_matmul(mode):
    """Layer-level: block modes == masked-dense oracle, fwd + grad, with
    the hidden activation fused into the junction."""
    spec = SparseLinearSpec(64, 32, rho=0.5, mode=mode, block_in=8,
                            block_out=8, seed=4)
    layer = SparseLinear(spec)
    p = layer.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (6, 64))
    wd = block_weights_to_dense(p["w"], layer.pattern)

    y = layer(p, x, activation="relu")
    np.testing.assert_allclose(y, jax.nn.relu(x @ wd + p["b"]),
                               atol=1e-5, rtol=1e-5)

    def loss_layer(p):
        return jnp.sum(layer(p, x, activation="relu") ** 2)

    def loss_oracle(p):
        wd = block_weights_to_dense(p["w"], layer.pattern)
        return jnp.sum(jax.nn.relu(x @ wd + p["b"]) ** 2)

    g1 = jax.grad(loss_layer)(p)
    g2 = jax.grad(loss_oracle)(p)
    np.testing.assert_allclose(g1["w"], g2["w"], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(g1["b"], g2["b"], atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("activation", [None, "relu", "gelu"])
def test_padded_m_forward_and_grads_match_unpadded_xla(activation):
    """Regression for the padded-M path: leading dims whose product (here
    M=3*5=15) is NOT a multiple of block_m must produce the same forward
    value AND gradients as the unpadded XLA route — including the bias
    cotangent: the zero-padded rows the Pallas path appends must not leak
    into db (they see bias + activation in-kernel, so a naive sum over the
    padded dy would overcount)."""
    bp, _, w, b = _setup(seed=7)
    x = jax.random.normal(jax.random.key(11), (3, 5, 64))  # M=15, bm=8

    y = ops.csd_matmul(x, w, bp, bias=b, activation=activation,
                       backend="pallas", block_m=8, interpret=True)
    y_ref = ops.csd_matmul(x, w, bp, bias=b, activation=activation,
                           backend="xla")
    np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5)

    def loss(w, b, x, kw):
        return jnp.sum(jnp.sin(ops.csd_matmul(
            x, w, bp, bias=b, activation=activation, **kw)))

    g = jax.grad(loss, argnums=(0, 1, 2))(
        w, b, x, dict(backend="pallas", block_m=8, interpret=True))
    g_ref = jax.grad(loss, argnums=(0, 1, 2))(w, b, x, dict(backend="xla"))
    for got, ref, name in zip(g, g_ref, ("dw", "db", "dx")):
        np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4,
                                   err_msg=f"{name} act={activation}")


def test_batched_routes_match_dense_expert_oracle():
    """Batched (expert-major) layout: every execution route == the
    per-expert masked-dense einsum, forward and gradients (incl. db)."""
    bp, _, _, _ = _setup(seed=8)
    E = 3
    ks = jax.random.split(jax.random.key(8), 3)
    x = jax.random.normal(ks[0], (E, 7, 64))  # M=7: pallas pads per expert
    w = jax.random.normal(ks[1], (E, bp.n_rb, bp.d_in_b, 8, 8))
    b = jax.random.normal(ks[2], (E, 48))
    wd = jnp.stack([block_weights_to_dense(w[e], bp) for e in range(E)])

    def loss_dense(w, b, x):
        wd = jnp.stack([block_weights_to_dense(w[e], bp)
                        for e in range(E)])
        z = jnp.einsum("ecd,edf->ecf", x, wd) + b[:, None]
        return jnp.sum(jnp.sin(jax.nn.relu(z)))

    y_ref = jax.nn.relu(jnp.einsum("ecd,edf->ecf", x, wd) + b[:, None])
    g_ref = jax.grad(loss_dense, argnums=(0, 1, 2))(w, b, x)
    for kw in _ROUTES:
        y = ops.csd_matmul(x, w, bp, bias=b, activation="relu", **kw)
        np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5,
                                   err_msg=str(kw))

        def loss_sparse(w, b, x, kw=kw):
            return jnp.sum(jnp.sin(ops.csd_matmul(
                x, w, bp, bias=b, activation="relu", **kw)))

        g = jax.grad(loss_sparse, argnums=(0, 1, 2))(w, b, x)
        for got, ref, name in zip(g, g_ref, ("dw", "db", "dx")):
            np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4,
                                       err_msg=f"{name} {kw}")


def test_pallas_padding_with_epilogue():
    """Odd M exercises the block_m padding path; padded rows see bias +
    activation in-kernel and must not leak into outputs or gradients."""
    bp, _, w, b = _setup(seed=5)
    x = jax.random.normal(jax.random.key(9), (3, 7, 64))  # M=21, block_m=8

    y = ops.csd_matmul(x, w, bp, bias=b, activation="gelu",
                       backend="pallas", block_m=8, interpret=True)
    y_ref = ops.csd_matmul(x, w, bp, bias=b, activation="gelu",
                           backend="xla")
    np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5)

    g = jax.grad(lambda w: jnp.sum(ops.csd_matmul(
        x, w, bp, bias=b, activation="gelu", backend="pallas", block_m=8,
        interpret=True) ** 2))(w)
    g_ref = jax.grad(lambda w: jnp.sum(ops.csd_matmul(
        x, w, bp, bias=b, activation="gelu", backend="xla") ** 2))(w)
    np.testing.assert_allclose(g, g_ref, atol=1e-4, rtol=1e-4)
