"""Sharding policy + distributed-lowering tests.

The multi-device cases run in a subprocess (XLA device count is locked at
first jax init, and the main test process must keep the real 1-CPU view).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import policy

REPO = os.path.join(os.path.dirname(__file__), "..")


def run_sub(code: str, devices: int = 8) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class FakeMesh:
    def __init__(self, names, sizes):
        self.axis_names = tuple(names)
        self.shape = dict(zip(names, sizes))
        import numpy as _np
        self.devices = _np.empty(sizes)


def test_rules_prune_missing_axes():
    mesh = FakeMesh(("data", "model"), (16, 16))
    r = policy.rules_for("train", 256, mesh)
    assert r["batch"] == ("data",)   # 'pod' pruned
    assert r["seq"] == "model"


def test_decode_rules_switch_to_long_for_small_batch():
    mesh = FakeMesh(("data", "model"), (16, 16))
    r = policy.rules_for("decode", 128, mesh)
    assert r["kv_seq"] == "model" and r["batch"] == ("data",)
    r1 = policy.rules_for("decode", 1, mesh)
    assert r1["batch"] is None
    assert r1["kv_seq"] == ("data", "model")


def test_mamba_rules_fold_model_into_batch():
    from repro.configs import get_config
    mesh = FakeMesh(("data", "model"), (16, 16))
    cfg = get_config("mamba2_130m")
    r = policy.rules_for("train", 256, mesh, cfg)
    assert r["batch"] == ("data", "model")
    assert r["seq"] is None
    # multi-pod: 256 % 512 != 0 -> model not folded
    mesh2 = FakeMesh(("pod", "data", "model"), (2, 16, 16))
    r2 = policy.rules_for("train", 256, mesh2, cfg)
    assert r2["batch"] == ("pod", "data")


def test_sanitize_drops_indivisible_dims():
    mesh = jax.make_mesh((1,), ("model",))

    class S:
        shape = (37, 64)
    fixed = policy.sanitize(P("model", None), S(), mesh)
    assert fixed == P("model", None)  # 37 % 1 == 0

    mesh_names = FakeMesh(("model",), (16,))
    # emulate: use the real function against a fake 16-wide mesh
    sizes = {"model": 16}

    def fix_one(spec, shape):
        out = []
        for dim, ax in zip(shape, list(spec) + [None] * (len(shape) - len(spec))):
            if ax is None:
                out.append(None)
                continue
            n = int(np.prod([sizes[a] for a in
                             (ax if isinstance(ax, tuple) else (ax,))]))
            out.append(ax if dim % n == 0 else None)
        return P(*out)

    assert fix_one(P("model", None), (37, 64)) == P(None, None)
    assert fix_one(P("model", None), (64, 37)) == P("model", None)


def test_param_pspecs_resolve_logical_axes():
    from repro.configs import get_config
    from repro.nn import build_model
    cfg = get_config("qwen2_7b", smoke=True)
    model = build_model(cfg)
    rules = {"embed": "data", "mlp": "model", "qheads": "model",
             "kvheads": "model", "vocab": "model", "layers": None,
             "mlp_act": None, "batch": ("data",), "seq": "model",
             "kv_seq": None, "expert": "model"}
    specs = policy.param_pspecs(model.spec(), rules)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(l, P) for l in leaves)


@pytest.mark.slow
def test_distributed_train_step_runs_and_matches_single_device():
    """4-device (2x2) sharded train step == unsharded step (same math)."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import ModelConfig, build_model
        from repro.nn.common import mesh_context
        from repro.optim import AdamWConfig
        from repro.launch import specs
        from repro.sharding import policy

        cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=128, attn_chunk=8,
                          loss_chunk=8, dtype="float32", remat=False)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        from repro.optim import adam
        opt = adam.init(params)
        tokens = jax.random.randint(jax.random.key(1), (8, 32), 0, 128)
        batch = {"tokens": tokens, "labels": tokens}
        step = specs.make_train_step(model, AdamWConfig(lr=1e-3,
                                                        warmup_steps=0))
        p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = policy.rules_for("train", 8, mesh, cfg)
        pspec = policy.param_pspecs(model.spec(), rules)
        p_sh = policy.named(mesh, pspec, params)
        o_sh = policy.named(mesh, policy.opt_pspecs(pspec), opt)
        b_sh = policy.named(mesh, policy.batch_pspecs(batch, rules), batch)
        with mesh, mesh_context(mesh, rules):
            p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                                 out_shardings=(p_sh, o_sh, None))(
                params, opt, batch)
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)))
        print("MAXERR", err)
        print("LOSSDIFF", abs(float(m_ref["loss"]) - float(m2["loss"])))
    """, devices=4)
    maxerr = float(out.split("MAXERR")[1].split()[0])
    lossdiff = float(out.split("LOSSDIFF")[1].split()[0])
    assert maxerr < 2e-3, out
    assert lossdiff < 1e-4, out


@pytest.mark.slow
def test_moe_shardmap_matches_local():
    """Expert-parallel shard_map MoE == local MoE on the same inputs."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.nn import ModelConfig, MoEConfig
        from repro.nn.common import mesh_context
        from repro.nn.ffn import MoE
        from repro.sharding import policy

        cfg = ModelConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, dtype="float32",
                          moe=MoEConfig(n_routed=8, top_k=2, n_shared=0,
                                        d_expert=16,
                                        capacity_factor=100.0))
        moe = MoE(cfg)
        params = moe.init(jax.random.key(0))
        x = jax.random.normal(jax.random.key(1), (4, 8, 32))
        y_local, _ = moe(params, x)   # no mesh -> local path

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = policy.rules_for("train", 4, mesh, cfg)
        with mesh, mesh_context(mesh, rules):
            y_sm, aux = jax.jit(lambda p, x: moe(p, x))(params, x)
        print("ERR", float(jnp.abs(y_local - y_sm).max()))
    """, devices=4)
    err = float(out.split("ERR")[1].split()[0])
    assert err < 1e-3, out


@pytest.mark.slow
def test_sparse_moe_shardmap_matches_local_and_dense_oracle():
    """Cross-mode certification of the batched block-sparse expert path:
    with ``moe_sparsity`` on, the shard_map (expert-parallel) mode, the
    gshard-style local mode, and the dense ``kernels.ref`` expert oracle
    all agree — forward and expert-weight gradients."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.core import block_weights_to_dense
        from repro.kernels import ref
        from repro.nn import ModelConfig, MoEConfig
        from repro.nn.common import SparsityConfig, mesh_context
        from repro.nn.ffn import MoE
        from repro.sharding import policy

        cfg = ModelConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                          d_ff=64, vocab_size=64, dtype="float32",
                          moe=MoEConfig(n_routed=8, top_k=2, n_shared=0,
                                        d_expert=16,
                                        capacity_factor=100.0),
                          sparsity=SparsityConfig(
                              enabled=True, rho_ffn=(0.5, 0.75),
                              block_in=8, block_out=8, moe_sparsity=True,
                              backend="xla"))
        moe = MoE(cfg)
        assert moe.up_pat is not None
        params = moe.init(jax.random.key(0))
        assert params["up"].ndim == 5  # batched junction slabs
        x = jax.random.normal(jax.random.key(1), (4, 8, 32))
        y_local, _ = moe(params, x)   # no mesh -> gshard-style local path

        # dense-oracle MoE on the expanded weights (routing identical)
        E = 8
        expand = lambda n, pat: jnp.stack(
            [block_weights_to_dense(params[n][e], pat) for e in range(E)])
        params_d = dict(params, up=expand("up", moe.up_pat),
                        gate=expand("gate", moe.gate_pat),
                        down=expand("down", moe.down_pat))
        moe_d = MoE(cfg.with_(sparsity=SparsityConfig()))
        y_dense, _ = moe_d(params_d, x)
        print("ERRDENSE", float(jnp.abs(y_local - y_dense).max()))

        def loss(p, m=moe):
            y, aux = m(p, x)
            return jnp.sum(y ** 2)
        g_s = jax.grad(loss)(params)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = policy.rules_for("train", 4, mesh, cfg)
        with mesh, mesh_context(mesh, rules):
            y_sm, aux = jax.jit(lambda p, x: moe(p, x))(params, x)
            g_sm = jax.jit(jax.grad(loss))(params)
        print("ERRSM", float(jnp.abs(y_local - y_sm).max()))
        gerr = max(float(jnp.abs(g_s[n] - g_sm[n]).max())
                   for n in ("up", "gate", "down", "router"))
        print("ERRGRAD", gerr)
    """, devices=4)
    assert float(out.split("ERRDENSE")[1].split()[0]) < 1e-4, out
    assert float(out.split("ERRSM")[1].split()[0]) < 1e-3, out
    assert float(out.split("ERRGRAD")[1].split()[0]) < 1e-3, out


@pytest.mark.slow
def test_seq_parallel_attention_matches_unsharded():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.nn import ModelConfig, build_model
        from repro.nn.common import mesh_context
        from repro.sharding import policy

        cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=128, attn_chunk=8,
                          loss_chunk=8, dtype="float32", remat=False,
                          local_global_ratio=1, attn_window=16)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (4, 64), 0, 128)
        batch = {"tokens": tokens, "labels": tokens}
        h_ref, _, _ = model.forward(params, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = policy.rules_for("train", 4, mesh, cfg)
        with mesh, mesh_context(mesh, rules):
            h_sh, _, _ = jax.jit(
                lambda p, b: model.forward(p, b))(params, batch)
        print("ERR", float(jnp.abs(h_ref - h_sh).max()))
    """, devices=4)
    err = float(out.split("ERR")[1].split()[0])
    assert err < 2e-3, out
