"""Unit tests: chunked attention vs reference; SSD chunked vs sequential."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degraded-mode property testing (see the fallback doc)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.ref import mha_ref
from repro.nn.attention import chunked_attention, decode_attention
from repro.nn.ssm import ssd_chunked, ssd_decode_step


def _grouped(q, hkv):
    b, s, hq, dh = q.shape
    return q.reshape(b, s, hkv, hq // hkv, dh)


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_attention_matches_ref(window, chunk):
    b, s, hq, hkv, dh = 2, 32, 4, 2, 8
    q = jax.random.normal(jax.random.key(0), (b, s, hq, dh))
    k = jax.random.normal(jax.random.key(1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.key(2), (b, s, hkv, dh))
    o_ref = mha_ref(q, k, v, causal=True, window=window)
    o = chunked_attention(_grouped(q, hkv), k, v, causal=True,
                          window=window, softcap=None, chunk=chunk,
                          scale=dh ** -0.5)
    np.testing.assert_allclose(o.reshape(o_ref.shape), o_ref, atol=2e-5,
                               rtol=2e-5)


def test_chunked_attention_odd_seq():
    b, s, hq, hkv, dh = 1, 19, 2, 1, 4
    q = jax.random.normal(jax.random.key(0), (b, s, hq, dh))
    k = jax.random.normal(jax.random.key(1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.key(2), (b, s, hkv, dh))
    o_ref = mha_ref(q, k, v, causal=True)
    o = chunked_attention(_grouped(q, hkv), k, v, causal=True, window=None,
                          softcap=None, chunk=8, scale=dh ** -0.5)
    np.testing.assert_allclose(o.reshape(o_ref.shape), o_ref, atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_matches_ref():
    b, s, hq, hkv, dh = 2, 16, 4, 2, 8
    q = jax.random.normal(jax.random.key(0), (b, 1, hq, dh))
    k = jax.random.normal(jax.random.key(1), (b, s, hkv, dh))
    v = jax.random.normal(jax.random.key(2), (b, s, hkv, dh))
    for pos, window in [(7, None), (15, None), (12, 4)]:
        o_ref = mha_ref(q, k[:, :pos + 1], v[:, :pos + 1], causal=True,
                        window=window, q_offset=pos)
        o = decode_attention(_grouped(q, hkv), k, v, pos=jnp.asarray(pos),
                             window=window, softcap=None, scale=dh ** -0.5)
        np.testing.assert_allclose(o.reshape(o_ref.shape), o_ref,
                                   atol=2e-5, rtol=2e-5)


# -- SSD ---------------------------------------------------------------------


@given(st.integers(1, 3), st.sampled_from([8, 12, 16]),
       st.sampled_from([2, 4]), st.sampled_from([1, 2]),
       st.integers(0, 3))
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_equals_sequential(b, s, h, g, seed):
    p, n = 4, 6
    ks = jax.random.split(jax.random.key(seed), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b_in = jax.random.normal(ks[3], (b, s, g, n))
    c_in = jax.random.normal(ks[4], (b, s, g, n))
    d_skip = jax.random.normal(ks[5], (h,))
    y, hf = ssd_chunked(x, dt, a, b_in, c_in, d_skip, chunk=4)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        yt, state = ssd_decode_step(x[:, t:t + 1], dt[:, t:t + 1], a,
                                    b_in[:, t:t + 1], c_in[:, t:t + 1],
                                    d_skip, state)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y, y_seq, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(hf, state, atol=1e-4, rtol=1e-4)


def test_ssd_streaming_state_carry():
    b, s, h, p, g, n = 2, 16, 4, 8, 1, 6
    ks = jax.random.split(jax.random.key(1), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b_in = jax.random.normal(ks[3], (b, s, g, n))
    c_in = jax.random.normal(ks[4], (b, s, g, n))
    d = jax.random.normal(ks[5], (h,))
    y_full, h_full = ssd_chunked(x, dt, a, b_in, c_in, d, chunk=4)
    y1, h1 = ssd_chunked(x[:, :8], dt[:, :8], a, b_in[:, :8], c_in[:, :8],
                         d, chunk=4)
    y2, h2 = ssd_chunked(x[:, 8:], dt[:, 8:], a, b_in[:, 8:], c_in[:, 8:],
                         d, chunk=4, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-5)
    np.testing.assert_allclose(h2, h_full, atol=1e-5)


def test_ssd_pad_is_identity_on_state():
    """Non-multiple seq: padded steps must not perturb the final state."""
    b, s, h, p, g, n = 1, 13, 2, 4, 1, 4
    ks = jax.random.split(jax.random.key(3), 6)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b_in = jax.random.normal(ks[3], (b, s, g, n))
    c_in = jax.random.normal(ks[4], (b, s, g, n))
    d = jax.random.normal(ks[5], (h,))
    y8, h8 = ssd_chunked(x, dt, a, b_in, c_in, d, chunk=8)   # pads to 16
    y13, h13 = ssd_chunked(x, dt, a, b_in, c_in, d, chunk=13)  # exact
    np.testing.assert_allclose(y8, y13, atol=1e-5)
    np.testing.assert_allclose(h8, h13, atol=1e-5)
