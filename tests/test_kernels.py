"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_weights_to_dense, make_block_pattern
from repro.kernels import csd_spmm, ops, ref
from repro.kernels.flash_attention import flash_attention


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# -- CSD-SpMM: shape x dtype x density sweep ---------------------------------

SPMM_CASES = [
    # (n_in, n_out, bl, br, rho, m, block_m)
    (64, 64, 8, 8, 0.5, 16, 8),
    (128, 64, 16, 16, 0.25, 32, 16),
    (64, 128, 8, 16, 0.75, 24, 8),
    (96, 48, 8, 8, 1.0 / 3.0, 8, 8),
    (256, 256, 32, 32, 0.125, 64, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SPMM_CASES)
def test_csd_spmm_fwd(case, dtype):
    n_in, n_out, bl, br, rho, m, bm = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=1)
    x = jax.random.normal(jax.random.key(0), (m, n_in), dtype)
    w = jax.random.normal(jax.random.key(1),
                          (bp.n_rb, bp.d_in_b, bl, br), dtype)
    y_ref = ref.csd_spmm_fwd_ref(x, w, bp.block_idx)
    y = csd_spmm.csd_spmm_fwd(x, w, bp.block_idx, block_m=bm,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("case", SPMM_CASES[:3])
def test_csd_spmm_dx_dw(case):
    n_in, n_out, bl, br, rho, m, bm = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=2)
    dy = jax.random.normal(jax.random.key(2), (m, n_out))
    x = jax.random.normal(jax.random.key(3), (m, n_in))
    w = jax.random.normal(jax.random.key(4),
                          (bp.n_rb, bp.d_in_b, bl, br))
    dx = csd_spmm.csd_spmm_dx(dy, w, bp.out_idx, bp.out_slot, block_m=bm,
                              interpret=True)
    dx_ref = ref.csd_spmm_dx_ref(dy, w, bp.out_idx, bp.out_slot)
    np.testing.assert_allclose(dx, dx_ref, atol=2e-5, rtol=2e-5)
    dw = csd_spmm.csd_spmm_dw(x, dy, bp.block_idx, block_in=bl,
                              block_out=br, block_m=bm, interpret=True)
    dw_ref = ref.csd_spmm_dw_ref(x, dy, bp.block_idx, bl, br)
    np.testing.assert_allclose(dw, dw_ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", SPMM_CASES[:3])
def test_csd_spmm_backward_kernels_match_xla_paths(case):
    """Interpret-mode Pallas dx/dw == the `_xla_dx`/`_xla_dw` fallback
    lowerings — the backward kernels are certified against the exact
    slot-sweep forms the XLA backend executes, not only the ref oracles."""
    n_in, n_out, bl, br, rho, m, bm = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=7)
    pat = ops._Pat(bp)
    dy = jax.random.normal(jax.random.key(10), (m, n_out))
    x = jax.random.normal(jax.random.key(11), (m, n_in))
    w = jax.random.normal(jax.random.key(12),
                          (bp.n_rb, bp.d_in_b, bl, br))
    dx = csd_spmm.csd_spmm_dx(dy, w, bp.out_idx, bp.out_slot, block_m=bm,
                              interpret=True)
    np.testing.assert_allclose(dx, ops._xla_dx(dy, w, pat.out_idx, pat.out_slot), atol=2e-5,
                               rtol=2e-5)
    dw = csd_spmm.csd_spmm_dw(x, dy, bp.block_idx, block_in=bl,
                              block_out=br, block_m=bm, interpret=True)
    np.testing.assert_allclose(dw, ops._xla_dw(x, dy, pat.block_idx, pat.block_in,
                                            pat.block_out), atol=2e-5,
                               rtol=2e-5)


# -- batched (expert-major) kernels vs vmapped oracles -----------------------

BATCHED_CASES = [
    # (E, n_in, n_out, bl, br, rho, m, block_m)
    (2, 64, 64, 8, 8, 0.5, 16, 8),
    (3, 64, 48, 8, 8, 0.5, 16, 8),
    (4, 128, 64, 16, 16, 0.25, 32, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", BATCHED_CASES)
def test_csd_spmm_fwd_batched(case, dtype):
    e, n_in, n_out, bl, br, rho, m, bm = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=1)
    x = jax.random.normal(jax.random.key(0), (e, m, n_in), dtype)
    w = jax.random.normal(jax.random.key(1),
                          (e, bp.n_rb, bp.d_in_b, bl, br), dtype)
    y_ref = ref.csd_spmm_fwd_batched_ref(x, w, bp.block_idx)
    y = csd_spmm.csd_spmm_fwd(x, w, bp.block_idx, block_m=bm,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("case", BATCHED_CASES[:2])
def test_csd_spmm_dx_dw_batched(case):
    """Batched backward kernels vs vmapped ref oracles AND the vmapped XLA
    fallback paths (both lowerings of the same expert-major layout)."""
    e, n_in, n_out, bl, br, rho, m, bm = case
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=2)
    pat = ops._Pat(bp)
    dy = jax.random.normal(jax.random.key(2), (e, m, n_out))
    x = jax.random.normal(jax.random.key(3), (e, m, n_in))
    w = jax.random.normal(jax.random.key(4),
                          (e, bp.n_rb, bp.d_in_b, bl, br))
    dx = csd_spmm.csd_spmm_dx(dy, w, bp.out_idx, bp.out_slot, block_m=bm,
                              interpret=True)
    np.testing.assert_allclose(
        dx, ref.csd_spmm_dx_batched_ref(dy, w, bp.out_idx, bp.out_slot),
        atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(dx, ops._xla_dx_batched(dy, w, pat),
                               atol=2e-5, rtol=2e-5)
    dw = csd_spmm.csd_spmm_dw(x, dy, bp.block_idx, block_in=bl,
                              block_out=br, block_m=bm, interpret=True)
    np.testing.assert_allclose(
        dw, ref.csd_spmm_dw_batched_ref(x, dy, bp.block_idx, bl, br),
        atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(dw, ops._xla_dw_batched(x, dy, pat),
                               atol=2e-5, rtol=2e-5)


def test_csd_spmm_fwd_batched_epilogue():
    """Fused bias+activation in the batched kernel == epilogue outside."""
    e, n_in, n_out, bl, br, m, bm = 3, 64, 48, 8, 8, 16, 8
    bp = make_block_pattern(n_in, n_out, 0.5, block_in=bl, block_out=br,
                            seed=3)
    x = jax.random.normal(jax.random.key(5), (e, m, n_in))
    w = jax.random.normal(jax.random.key(6),
                          (e, bp.n_rb, bp.d_in_b, bl, br))
    b = jax.random.normal(jax.random.key(7), (e, n_out))
    y = csd_spmm.csd_spmm_fwd(x, w, bp.block_idx, bias=b,
                              activation="relu", block_m=bm,
                              interpret=True)
    z = ref.csd_spmm_fwd_batched_ref(x, w, bp.block_idx) + b[:, None]
    np.testing.assert_allclose(y, jax.nn.relu(z), atol=1e-5, rtol=1e-5)
    # save_preact returns the batched pre-activation alongside gelu output
    y2, z2 = csd_spmm.csd_spmm_fwd(x, w, bp.block_idx, bias=b,
                                   activation="gelu", save_preact=True,
                                   block_m=bm, interpret=True)
    np.testing.assert_allclose(z2, z, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(y2, jax.nn.gelu(z, approximate=True),
                               atol=1e-5, rtol=1e-5)


def test_csd_matmul_grad_matches_dense_oracle():
    bp = make_block_pattern(64, 48, 0.5, block_in=8, block_out=8, seed=0)
    x = jax.random.normal(jax.random.key(0), (16, 64))
    w = jax.random.normal(jax.random.key(1), (bp.n_rb, bp.d_in_b, 8, 8))

    def loss_sparse(w):
        y = ops.csd_matmul(x, w, bp, backend="pallas", block_m=8,
                           interpret=True)
        return jnp.sum(jnp.sin(y))

    def loss_dense(w):
        return jnp.sum(jnp.sin(x @ block_weights_to_dense(w, bp)))

    np.testing.assert_allclose(loss_sparse(w), loss_dense(w), rtol=1e-5)
    g1 = jax.grad(loss_sparse)(w)
    g2 = jax.grad(loss_dense)(w)
    np.testing.assert_allclose(g1, g2, atol=1e-4, rtol=1e-4)


def test_csd_matmul_xla_equals_pallas():
    bp = make_block_pattern(64, 64, 0.25, block_in=16, block_out=16, seed=3)
    x = jax.random.normal(jax.random.key(5), (4, 7, 64))  # odd M: padding
    w = jax.random.normal(jax.random.key(6), (bp.n_rb, bp.d_in_b, 16, 16))
    y1 = ops.csd_matmul(x, w, bp, backend="xla")
    y2 = ops.csd_matmul(x, w, bp, backend="pallas", block_m=8,
                        interpret=True)
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-5)


# -- flash attention sweep ------------------------------------------------------

ATTN_CASES = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=8),
    dict(causal=True, logit_softcap=30.0),
    dict(causal=True, window=16, logit_softcap=50.0),
]


@pytest.mark.parametrize("kwargs", ATTN_CASES)
@pytest.mark.parametrize("dims", [(2, 32, 32, 4, 2, 8), (1, 16, 16, 4, 4, 16),
                                  (2, 16, 16, 8, 1, 8)])
def test_flash_attention_vs_ref(kwargs, dims):
    b, sq, skv, hq, hkv, dh = dims
    q = jax.random.normal(jax.random.key(1), (b, sq, hq, dh))
    k = jax.random.normal(jax.random.key(2), (b, skv, hkv, dh))
    v = jax.random.normal(jax.random.key(3), (b, skv, hkv, dh))
    o_ref = ref.mha_ref(q, k, v, **kwargs)
    o = flash_attention(q, k, v, block_q=8, block_k=8, interpret=True,
                        **kwargs)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    b, s, hq, hkv, dh = 2, 32, 4, 2, 8
    q = jax.random.normal(jax.random.key(1), (b, s, hq, dh), dtype)
    k = jax.random.normal(jax.random.key(2), (b, s, hkv, dh), dtype)
    v = jax.random.normal(jax.random.key(3), (b, s, hkv, dh), dtype)
    o_ref = ref.mha_ref(q, k, v, causal=True)
    o = flash_attention(q, k, v, block_q=8, block_k=8, interpret=True,
                        causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_attention_decode_offset():
    b, skv, hq, hkv, dh = 2, 32, 4, 2, 8
    q = jax.random.normal(jax.random.key(1), (b, 1, hq, dh))
    k = jax.random.normal(jax.random.key(2), (b, skv, hkv, dh))
    v = jax.random.normal(jax.random.key(3), (b, skv, hkv, dh))
    for off in (0, 13, 31):
        o_ref = ref.mha_ref(q, k, v, causal=True, q_offset=off)
        o = flash_attention(q, k, v, causal=True, q_offset=off, block_q=1,
                            block_k=8, interpret=True)
        np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
