"""Cross-mode equivalence of the sparse junction + hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degraded-mode property testing (see the fallback doc)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (
    SparseLinear, SparseLinearSpec, block_weights_to_dense,
    dense_weights_to_gather, gather_weights_to_dense, make_block_pattern,
    storage_cost,
)
from repro.core.sparse_linear import gather_apply
from repro.kernels.ref import block_gather_ref, block_scatter_ref


def test_gather_matches_masked_dense():
    spec = SparseLinearSpec(24, 16, rho=0.5, mode="gather", seed=1)
    layer = SparseLinear(spec)
    p = layer.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (5, 24))
    y = layer(p, x)
    wd = gather_weights_to_dense(p["w"], layer.pattern.idx, 24)
    np.testing.assert_allclose(y, x @ wd + p["b"], atol=1e-5, rtol=1e-5)


def test_dense_roundtrip():
    spec = SparseLinearSpec(24, 16, rho=0.5, mode="gather", seed=2)
    layer = SparseLinear(spec)
    p = layer.init(jax.random.key(0))
    wd = gather_weights_to_dense(p["w"], layer.pattern.idx, 24)
    w2 = dense_weights_to_gather(wd, layer.pattern.idx)
    np.testing.assert_allclose(w2, p["w"], atol=1e-6)


@given(st.sampled_from([(32, 16, 8, 8), (64, 32, 16, 8), (48, 48, 8, 8)]),
       st.sampled_from([0.25, 0.5, 0.75]), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_block_modes_agree(dims, rho, seed):
    n_in, n_out, bl, br = dims
    bp = make_block_pattern(n_in, n_out, rho, block_in=bl, block_out=br,
                            seed=seed)
    x = jax.random.normal(jax.random.key(seed), (3, n_in))
    w = jax.random.normal(jax.random.key(seed + 1),
                          (bp.n_rb, bp.d_in_b, bl, br))
    y_g = block_gather_ref(x, w, bp.block_idx, bl, br)
    y_s = block_scatter_ref(x, w, bp.out_idx, bp.out_slot, bl, br)
    y_d = x @ block_weights_to_dense(w, bp)
    np.testing.assert_allclose(y_g, y_d, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y_s, y_d, atol=1e-4, rtol=1e-4)


def test_block_pattern_density_and_mask():
    bp = make_block_pattern(128, 64, 0.5, block_in=16, block_out=16, seed=0)
    mask = bp.to_mask()
    assert mask.shape == (128, 64)
    assert np.isclose(mask.mean(), bp.density)
    # every right block has exactly d_in_b feeding blocks
    bm = bp.to_block_mask()
    assert (bm.sum(0) == bp.d_in_b).all()


def test_storage_cost_matches_paper_table1():
    fc = storage_cost((800, 100, 10))
    sp = storage_cost((800, 100, 10), d_in=[160, 100])
    assert fc.total == 85930     # paper Table I, FC column
    assert sp.total == 21930     # paper Table I, sparse column
    assert fc.w == 81000 and sp.w == 17000
    # memory reduction 3.9x (paper §III-A)
    assert 3.8 < fc.total / sp.total < 4.0


def test_sparse_weight_count_scales_with_density():
    for rho in (0.25, 0.5, 1.0):
        spec = SparseLinearSpec(128, 128, rho=rho, mode="block_gather",
                                block_in=16, block_out=16)
        layer = SparseLinear(spec)
        assert layer.n_weights == pytest.approx(rho * 128 * 128, rel=0.01)
