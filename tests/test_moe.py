"""MoE unit tests: routing properties, local-vs-brute-force equivalence,
and pre-defined sparse expert junctions (the batched csd_matmul path) vs
the dense ``kernels.ref`` expert oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_weights_to_dense
from repro.kernels import ref
from repro.nn import ModelConfig, MoEConfig
from repro.nn.common import SparsityConfig
from repro.nn.ffn import MoE


def _moe(capacity_factor=100.0, n_routed=8, top_k=2, n_shared=0,
         sparsity=None):
    cfg = ModelConfig(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=64, dtype="float32",
        moe=MoEConfig(n_routed=n_routed, top_k=top_k, n_shared=n_shared,
                      d_expert=16, capacity_factor=capacity_factor),
        sparsity=sparsity or SparsityConfig())
    return MoE(cfg), cfg


_SPARSE = SparsityConfig(enabled=True, rho_ffn=(0.5, 0.75), block_in=8,
                         block_out=8, moe_sparsity=True, backend="xla")


def _dense_expert_weights(moe, params):
    """Expand the block-sparse expert slabs to (E, n, n) dense-with-zeros."""
    E = moe.mc.n_routed
    return tuple(
        jnp.stack([block_weights_to_dense(params[n][e], pat)
                   for e in range(E)])
        for n, pat in (("up", moe.up_pat), ("gate", moe.gate_pat),
                       ("down", moe.down_pat)))


def test_moe_local_matches_brute_force():
    """With unlimited capacity, sort-based dispatch == dense top-k mixing."""
    moe, cfg = _moe()
    params = moe.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 6, 32))
    y, aux = moe(params, x)

    # brute force: every expert on every token, combine top-k
    x2 = x.reshape(-1, 32)
    logits = x2 @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    for e in range(8):
        h = x2 @ params["up"][e]
        g = jax.nn.silu(x2 @ params["gate"][e]) * h
        outs.append(g @ params["down"][e])
    outs = jnp.stack(outs, 1)  # (T, E, d)
    ref = jnp.einsum("tk,tkd->td", gates,
                     jnp.take_along_axis(outs, ids[..., None], 1))
    np.testing.assert_allclose(y.reshape(-1, 32), ref, atol=1e-4,
                               rtol=1e-4)
    assert "moe_lb" in aux and jnp.isfinite(aux["moe_lb"])


def test_moe_capacity_drops_tokens():
    """With capacity 1 per expert, most assignments overflow -> y shrinks."""
    moe_lo, _ = _moe(capacity_factor=0.01)
    moe_hi, _ = _moe(capacity_factor=100.0)
    params = moe_lo.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 32, 32))
    y_lo, _ = moe_lo(params, x)
    y_hi, _ = moe_hi(params, x)
    assert float(jnp.abs(y_lo).sum()) < float(jnp.abs(y_hi).sum())


def test_moe_gradients_flow_to_router_and_experts():
    moe, cfg = _moe()
    params = moe.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 4, 32))

    def loss(p):
        y, aux = moe(p, x)
        return jnp.sum(y ** 2) + aux["moe_lb"] + aux["moe_z"]

    g = jax.grad(loss)(params)
    for name in ("router", "up", "gate", "down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


def test_moe_shared_experts_add():
    moe_s, _ = _moe(n_shared=2)
    params = moe_s.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 4, 32))
    y, _ = moe_s(params, x)
    # zeroing shared-expert weights must change the output
    p2 = dict(params, shared=jax.tree.map(jnp.zeros_like, params["shared"]))
    y2, _ = moe_s(p2, x)
    assert float(jnp.abs(y - y2).sum()) > 0


# -- pre-defined sparse expert junctions (batched csd_matmul path) -----------


def test_sparse_moe_expert_ffn_matches_dense_ref_oracle():
    """_expert_ffn with block-sparse slabs (the batched csd_matmul path)
    == kernels.ref.moe_expert_ffn_ref on the dense-expanded weights."""
    moe, cfg = _moe(sparsity=_SPARSE)
    assert moe.up_pat is not None and moe.down_pat is not None
    params = moe.init(jax.random.key(0))
    # the stacked slabs really carry the batched junction layout
    assert params["up"].ndim == 5 and params["down"].ndim == 5
    upd, gd, dd = _dense_expert_weights(moe, params)
    xe = jax.random.normal(jax.random.key(1), (8, 5, 32))
    ye = moe._expert_ffn(params["up"], params["gate"], params["down"], xe)
    y_ref = ref.moe_expert_ffn_ref(xe, upd, gd, dd, moe.act)
    np.testing.assert_allclose(ye, y_ref, atol=1e-5, rtol=1e-5)


def test_sparse_moe_gradients_match_dense_ref_oracle():
    """jax.grad through the sparse expert junctions == grads through the
    dense ref oracle, projected back onto the pattern positions."""
    moe, cfg = _moe(sparsity=_SPARSE)
    params = moe.init(jax.random.key(0))
    xe = jax.random.normal(jax.random.key(2), (8, 4, 32))

    def loss_sparse(p):
        return jnp.sum(jnp.sin(moe._expert_ffn(p["up"], p["gate"],
                                               p["down"], xe)))

    def loss_dense(p):
        upd, gd, dd = _dense_expert_weights(moe, p)
        return jnp.sum(jnp.sin(
            ref.moe_expert_ffn_ref(xe, upd, gd, dd, moe.act)))

    g_s = jax.grad(loss_sparse)(params)
    g_d = jax.grad(loss_dense)(params)
    for n in ("up", "gate", "down"):
        np.testing.assert_allclose(g_s[n], g_d[n], atol=1e-4, rtol=1e-4,
                                   err_msg=n)


def test_sparse_moe_full_forward_matches_dense_oracle_moe():
    """End-to-end: a sparse-expert MoE == a dense-expert MoE whose weights
    are the dense expansions of the same slabs (routing identical)."""
    moe_s, cfg = _moe(sparsity=_SPARSE)
    moe_d, _ = _moe()
    params = moe_s.init(jax.random.key(0))
    upd, gd, dd = _dense_expert_weights(moe_s, params)
    params_d = dict(params, up=upd, gate=gd, down=dd)
    x = jax.random.normal(jax.random.key(3), (2, 6, 32))
    y_s, aux_s = moe_s(params, x)
    y_d, aux_d = moe_d(params_d, x)
    np.testing.assert_allclose(y_s, y_d, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(aux_s["moe_lb"], aux_d["moe_lb"], rtol=1e-5)


def test_sparse_moe_gradients_flow_and_param_count_shrinks():
    moe, cfg = _moe(sparsity=_SPARSE)
    params = moe.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(4), (2, 4, 32))

    def loss(p):
        y, aux = moe(p, x)
        return jnp.sum(y ** 2) + aux["moe_lb"] + aux["moe_z"]

    g = jax.grad(loss)(params)
    for name in ("router", "up", "gate", "down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name
    # storage really shrinks: up slab holds rho_up * dense elements
    dense_elems = 8 * 32 * 16
    assert params["up"].size == pytest.approx(0.5 * dense_elems)


def test_load_balance_loss_prefers_uniform():
    moe, cfg = _moe()
    T, E = 512, 8
    x2 = jax.random.normal(jax.random.key(2), (T, 32))
    # uniform router -> lb ~ 1; collapsed router -> lb ~ E
    p_uniform = moe.init(jax.random.key(0))
    p_collapsed = dict(p_uniform)
    p_collapsed["router"] = jnp.zeros_like(p_uniform["router"]
                                           ).at[:, 0].set(10.0)
    *_, aux_u = moe._route(p_uniform, x2)
    *_, aux_c = moe._route(p_collapsed, x2)
    assert aux_c["moe_lb"] > aux_u["moe_lb"] * 2
