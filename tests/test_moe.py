"""MoE unit tests: routing properties, local-vs-brute-force equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import ModelConfig, MoEConfig
from repro.nn.ffn import MoE


def _moe(capacity_factor=100.0, n_routed=8, top_k=2, n_shared=0):
    cfg = ModelConfig(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=64, dtype="float32",
        moe=MoEConfig(n_routed=n_routed, top_k=top_k, n_shared=n_shared,
                      d_expert=16, capacity_factor=capacity_factor))
    return MoE(cfg), cfg


def test_moe_local_matches_brute_force():
    """With unlimited capacity, sort-based dispatch == dense top-k mixing."""
    moe, cfg = _moe()
    params = moe.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 6, 32))
    y, aux = moe(params, x)

    # brute force: every expert on every token, combine top-k
    x2 = x.reshape(-1, 32)
    logits = x2 @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    outs = []
    for e in range(8):
        h = x2 @ params["up"][e]
        g = jax.nn.silu(x2 @ params["gate"][e]) * h
        outs.append(g @ params["down"][e])
    outs = jnp.stack(outs, 1)  # (T, E, d)
    ref = jnp.einsum("tk,tkd->td", gates,
                     jnp.take_along_axis(outs, ids[..., None], 1))
    np.testing.assert_allclose(y.reshape(-1, 32), ref, atol=1e-4,
                               rtol=1e-4)
    assert "moe_lb" in aux and jnp.isfinite(aux["moe_lb"])


def test_moe_capacity_drops_tokens():
    """With capacity 1 per expert, most assignments overflow -> y shrinks."""
    moe_lo, _ = _moe(capacity_factor=0.01)
    moe_hi, _ = _moe(capacity_factor=100.0)
    params = moe_lo.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 32, 32))
    y_lo, _ = moe_lo(params, x)
    y_hi, _ = moe_hi(params, x)
    assert float(jnp.abs(y_lo).sum()) < float(jnp.abs(y_hi).sum())


def test_moe_gradients_flow_to_router_and_experts():
    moe, cfg = _moe()
    params = moe.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 4, 32))

    def loss(p):
        y, aux = moe(p, x)
        return jnp.sum(y ** 2) + aux["moe_lb"] + aux["moe_z"]

    g = jax.grad(loss)(params)
    for name in ("router", "up", "gate", "down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


def test_moe_shared_experts_add():
    moe_s, _ = _moe(n_shared=2)
    params = moe_s.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 4, 32))
    y, _ = moe_s(params, x)
    # zeroing shared-expert weights must change the output
    p2 = dict(params, shared=jax.tree.map(jnp.zeros_like, params["shared"]))
    y2, _ = moe_s(p2, x)
    assert float(jnp.abs(y - y2).sum()) > 0


def test_load_balance_loss_prefers_uniform():
    moe, cfg = _moe()
    T, E = 512, 8
    x2 = jax.random.normal(jax.random.key(2), (T, 32))
    # uniform router -> lb ~ 1; collapsed router -> lb ~ E
    p_uniform = moe.init(jax.random.key(0))
    p_collapsed = dict(p_uniform)
    p_collapsed["router"] = jnp.zeros_like(p_uniform["router"]
                                           ).at[:, 0].set(10.0)
    *_, aux_u = moe._route(p_uniform, x2)
    *_, aux_c = moe._route(p_collapsed, x2)
    assert aux_c["moe_lb"] > aux_u["moe_lb"] * 2
