"""Trainer integration: loss decreases, checkpoint-restart, fault tolerance,
optimizer and compression units."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import BigramLM
from repro.nn import ModelConfig, build_model
from repro.optim import (AdamWConfig, adam, compress_with_feedback,
                         dequantize_int8, psum_compressed_tree,
                         quantize_int8)
from repro.train import (CheckpointManager, HeartbeatMonitor, RestartLoop,
                         RestartPolicy, Trainer, TrainerConfig, remesh_plan)


def _tiny_model():
    cfg = ModelConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=128, attn_chunk=16,
                      loss_chunk=16, dtype="float32", remat=False)
    return build_model(cfg), cfg


def test_loss_decreases():
    model, cfg = _tiny_model()
    data = BigramLM(vocab_size=cfg.vocab_size, branching=4, noise=0.0,
                    seed=0)
    tc = TrainerConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=80, weight_decay=0.0))
    tr = Trainer(model, tc)
    _, _, hist = tr.fit(data.iterate(16, 32), steps=80)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_checkpoint_roundtrip_and_resume():
    model, cfg = _tiny_model()
    data = BigramLM(vocab_size=cfg.vocab_size, seed=1)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(opt=AdamWConfig(lr=1e-3, total_steps=30),
                           checkpoint_dir=d, checkpoint_every=10)
        tr = Trainer(model, tc)
        p1, o1, _ = tr.fit(data.iterate(8, 16), steps=20)
        # new trainer resumes from step 20 and finishes
        tr2 = Trainer(model, tc)
        p2, o2, hist = tr2.fit(data.iterate(8, 16, start_step=20),
                               steps=30, resume=True)
        assert hist[0]["step"] > 20
        mgr = CheckpointManager(d)
        assert mgr.latest_step() == 30


def test_checkpoint_integrity_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
        mgr.save(5, tree)
        # corrupt the shard file
        import numpy as np
        path = os.path.join(d, "step_00000005", "shard-00000.npz")
        data = dict(np.load(path))
        key = [k for k in data if k.endswith("'a']")][0] \
            if any(k.endswith("'a']") for k in data) else list(data)[0]
        data[key] = data[key] + 1.0
        np.savez(path, **data)
        with pytest.raises(IOError):
            mgr.restore(5, tree)


def test_checkpoint_gc_keeps_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"x": jnp.ones(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.steps() == [3, 4]


def test_restart_loop_recovers_from_failures():
    state = {"restored": 0, "saved": [], "fail_at": {7, 23}}
    progress = {"step": 0}

    def step_fn(step):
        if step in state["fail_at"]:
            state["fail_at"].remove(step)
            raise RuntimeError("injected device loss")
        progress["step"] = step + 1

    def save(step):
        state["saved"].append(step)

    def restore():
        return max([s for s in state["saved"]] or [0])

    loop = RestartLoop(RestartPolicy(checkpoint_every=5), save, restore)
    loop.run(step_fn, total_steps=30)
    assert progress["step"] == 30
    assert loop.restarts == 2


def test_heartbeat_and_stragglers():
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10,
                           straggler_steps=3)
    now = 100.0
    mon.beat("h0", 10, now)
    mon.beat("h1", 10, now)
    mon.beat("h2", 6, now)
    assert mon.stragglers(now) == ["h2"]
    assert mon.dead(now + 5) == []
    mon.beat("h0", 11, now + 20)
    mon.beat("h2", 7, now + 20)
    assert mon.dead(now + 20) == ["h1"]
    assert set(mon.healthy(now + 20)) == {"h0", "h2"}


def test_stragglers_exclude_dead_hosts():
    """Regression: the lead step was computed over ALL hosts and dead
    hosts were reported as stragglers too. A host that dies ahead of the
    pack must not inflate the lead (flagging every live host), and a
    host that dies behind the pack belongs to dead(), not stragglers()."""
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10,
                           straggler_steps=3)
    now = 100.0
    mon.beat("h0", 50, now)           # dies ahead of the pack
    mon.beat("h1", 46, now + 20)
    mon.beat("h2", 46, now + 20)
    late = now + 20
    assert mon.dead(late) == ["h0"]
    # pre-fix: lead=50 over all hosts -> h1/h2 (lag 4) flagged, and a
    # dead laggard would be listed as a straggler as well
    assert mon.stragglers(late) == []
    mon.beat("h1", 55, late)
    assert mon.dead(late) == ["h0"]
    assert mon.stragglers(late) == ["h2"]   # live laggard, dead excluded


def test_restart_loop_failures_reset_on_checkpoint_progress():
    """Regression: ``failures`` accumulated over the job's lifetime, so
    ``max_failures`` transient faults spread over a long run killed it
    even though every restart made progress. A landed checkpoint resets
    the budget; only no-progress crash loops exhaust it."""
    saved = [0]

    # 4 transient faults with max_failures=3 — but checkpoints land in
    # between, so the loop must survive all of them
    state = {"fail_at": {6, 16, 26, 36}}

    def step_fn(step):
        if step in state["fail_at"]:
            state["fail_at"].remove(step)
            raise RuntimeError("injected transient fault")

    loop = RestartLoop(RestartPolicy(max_failures=3, checkpoint_every=5),
                       lambda s: saved.append(s), lambda: max(saved))
    loop.run(step_fn, total_steps=40)
    assert loop.restarts == 4

    # a crash loop that never reaches a checkpoint still dies
    loop2 = RestartLoop(RestartPolicy(max_failures=3, checkpoint_every=5),
                        lambda s: None, lambda: 0)

    def always_fail(step):
        raise RuntimeError("hard fault")

    with pytest.raises(RuntimeError):
        loop2.run(always_fail, total_steps=40)
    assert loop2.failures == 4        # max_failures + the raising one


def test_remesh_plan_shrinks_to_power_of_two():
    # 256-host pod, 8 devices/host, model=16: full data degree = 128
    full = remesh_plan(256, 8, 16)
    assert full["data"] == 128
    # lose 3 hosts -> 253*8 = 2024 devices -> data=64 (largest pow2 fit)
    plan = remesh_plan(253, 8, 16)
    assert plan["data"] == 64
    assert plan["devices_used"] == 64 * 16
    # not even one model replica
    assert remesh_plan(1, 8, 16) is None


# -- optimizer / compression units ------------------------------------------


def test_adamw_step_moves_params():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros(4)}
    g = {"w": jnp.full((4, 4), 0.1), "b": jnp.full(4, 0.1)}
    st = adam.init(params)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
    p2, st2, m = adam.update(cfg, g, st, params)
    assert not np.allclose(p2["w"], params["w"])
    assert st2["step"] == 1
    assert m["grad_norm"] > 0


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 1e6)}
    st = adam.init(params)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, grad_clip=1.0,
                      weight_decay=0.0)
    p2, _, m = adam.update(cfg, g, st, params)
    assert jnp.isfinite(p2["w"]).all()
    assert m["grad_norm"] > 1.0  # pre-clip norm reported


def test_int8_roundtrip_and_error_feedback():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 3)
    q, s = quantize_int8(x)
    err0 = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert err0 <= s / 2 + 1e-6
    # error feedback makes repeated transmission unbiased: accumulate the
    # same gradient many times, total transmitted ~= n * x
    err = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for _ in range(50):
        q, s, err = compress_with_feedback(x, err)
        total = total + dequantize_int8(q, s)
    np.testing.assert_allclose(total / 50, x, atol=float(s) * 0.2 + 1e-4)


def test_psum_compressed_local_path():
    tree = {"a": jnp.arange(8.0)}
    errs = {"a": jnp.zeros(8)}
    mean, new_err = psum_compressed_tree(tree, errs, None)
    np.testing.assert_allclose(mean["a"], tree["a"], atol=0.05)


def test_grad_accum_matches_single_batch():
    model, cfg = _tiny_model()
    data = BigramLM(vocab_size=cfg.vocab_size, seed=3)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0, 8, 16).items()}
    tc1 = TrainerConfig(opt=AdamWConfig(lr=0.0, warmup_steps=0,
                                        weight_decay=0.0, grad_clip=None))
    t1 = Trainer(model, tc1)
    params, opt = t1.init_state(jax.random.key(0))

    # direct gradient vs 2-way accumulated gradient (lr=0 so params fixed)
    g_full = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    halves = jax.tree.map(lambda x: x.reshape((2, 4) + x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, params)
    for i in range(2):
        mb = jax.tree.map(lambda x: x[i], halves)
        g = jax.grad(lambda p: model.loss(p, mb)[0])(params)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda x: x / 2, g_acc)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(a, b, atol=5e-3, rtol=5e-2)
