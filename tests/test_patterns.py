"""Property-based tests of the paper's pattern invariants (core.sparsity)."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degraded-mode property testing (see the fallback doc)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (
    JunctionSpec, clashfree_pattern, clashfree_schedule,
    count_access_patterns, degrees_for_density, disconnected_left,
    in_degrees, make_pattern, out_degrees, pattern_from_schedule,
    possible_densities, quantize_density, schedule_is_clash_free,
    structured_pattern, to_mask, transpose_pattern,
)


# -- admissible-density structure (paper Appendix A) --------------------------


@given(st.integers(2, 64), st.integers(2, 64))
@settings(max_examples=50, deadline=None)
def test_density_set_size_is_gcd(n_left, n_right):
    ds = possible_densities(n_left, n_right)
    assert len(ds) == math.gcd(n_left, n_right)
    assert np.isclose(ds[-1], 1.0)


@given(st.integers(2, 64), st.integers(2, 64),
       st.floats(0.01, 1.0, allow_nan=False))
@settings(max_examples=50, deadline=None)
def test_degrees_satisfy_structured_constraint(n_left, n_right, rho):
    d_out, d_in = degrees_for_density(n_left, n_right, rho)
    # paper eq. (6): N_{i-1} d_out = N_i d_in, both natural numbers
    assert n_left * d_out == n_right * d_in
    assert 1 <= d_in <= n_left
    assert 1 <= d_out <= n_right


# -- structured patterns: exact degrees, no duplicate edges --------------------


@st.composite
def junctions(draw):
    g = draw(st.integers(2, 8))
    a = draw(st.integers(1, 8))
    b = draw(st.integers(1, 8))
    n_left, n_right = g * a, g * b
    k = draw(st.integers(1, g))
    d_in = k * (n_left // g)
    return JunctionSpec(n_left, n_right, d_in)


@given(junctions(), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_structured_pattern_degrees(spec, seed):
    pat = structured_pattern(spec, np.random.default_rng(seed))
    assert (in_degrees(pat) == spec.d_in).all()
    assert (out_degrees(pat) == spec.d_out).all()
    # no duplicate edges
    assert to_mask(pat).sum() == spec.n_edges


@given(junctions(), st.integers(0, 5), st.integers(1, 3),
       st.booleans())
@settings(max_examples=40, deadline=None)
def test_clashfree_pattern_is_structured_and_clash_free(spec, seed, cf_type,
                                                        dither):
    # pick a z dividing both n_left and n_edges
    z = math.gcd(spec.n_left, spec.n_edges)
    rng = np.random.default_rng(seed)
    sched = clashfree_schedule(spec, z, rng, cf_type, dither)
    assert schedule_is_clash_free(sched, spec.n_left // z)
    pat = clashfree_pattern(spec, z, np.random.default_rng(seed),
                            cf_type, dither)
    assert (in_degrees(pat) == spec.d_in).all()
    assert (out_degrees(pat) == spec.d_out).all()
    assert to_mask(pat).sum() == spec.n_edges


def test_type1_never_duplicates():
    # type-1: same left neuron => same bank => slot gap >= n_left (see
    # sparsity.clashfree_pattern docstring); check exhaustively for a grid
    for n_left, n_right, d_in, z in [(12, 8, 3, 4), (16, 16, 4, 8),
                                     (24, 6, 8, 12), (8, 32, 2, 8)]:
        spec = JunctionSpec(n_left, n_right, d_in)
        for seed in range(10):
            pat = clashfree_pattern(spec, z, np.random.default_rng(seed), 1)
            srt = np.sort(pat.idx, axis=1)
            assert not (srt[:, 1:] == srt[:, :-1]).any()


# -- transpose pattern (BP adjacency) ------------------------------------------


@given(junctions(), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_transpose_pattern_roundtrip(spec, seed):
    pat = structured_pattern(spec, np.random.default_rng(seed))
    ridx = transpose_pattern(pat)
    # every (l -> (j, f)) entry must satisfy idx[j, f] == l
    for l in range(spec.n_left):
        for g in range(spec.d_out):
            j, f = ridx[l, g]
            assert pat.idx[j, f] == l


# -- random patterns can disconnect neurons (paper §IV-B) -----------------------


def test_random_sparsity_disconnects_at_low_density():
    rng_hits = 0
    for seed in range(20):
        pat = make_pattern(100, 50, 0.02, method="random", seed=seed)
        rng_hits += disconnected_left(pat) > 0
    # at rho=2%, ~1 edge per left neuron on average: disconnections are
    # near-certain in most draws
    assert rng_hits >= 15


def test_structured_never_disconnects():
    for seed in range(10):
        pat = make_pattern(100, 50, 0.02, method="structured", seed=seed)
        assert disconnected_left(pat) == 0


# -- pattern-count formulas (paper Appendix C, Table III) -----------------------


def test_table3_pattern_counts():
    spec = JunctionSpec(12, 12, 2)  # Table III junction
    z = 4
    # type 1, no dither: D^z = 3^4 = 81
    assert np.isclose(10 ** count_access_patterns(spec, z, 1, False), 81)
    # type 2, no dither: D^(z d_out) = 3^8 = 6561
    assert np.isclose(10 ** count_access_patterns(spec, z, 2, False), 6561)
    # type 3, no dither: (D!)^(z d_out) = 6^8 = 1679616 ~ 1.68M
    assert np.isclose(10 ** count_access_patterns(spec, z, 3, False),
                      1679616)


def test_quantize_density_monotone():
    assert quantize_density(800, 100, 0.2) >= 0.2 - 1e-9
    assert quantize_density(800, 100, 1.0) == 1.0
