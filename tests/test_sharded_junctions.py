"""Sharded (model-parallel) sparse junction certification.

The tentpole contract: partitioning a junction's BlockPattern + weight
slab across a mesh axis — the jax_pallas analogue of the paper's
size-flexible ``z`` (more parallel block-rows per cycle) — must be
numerically invisible. Coverage:

* host-side partition properties (disjoint cover, slot balance, padded
  local scatter forms, slab split/merge round-trip);
* 8-forced-host-device parity of the sharded ``csd_matmul`` (fwd + VJP,
  4-D and 5-D slabs, both backends) vs the single-device path;
* sharded train step == single-device train step (loss + params), with
  slab weights and Adam state actually chunked over the slab axis;
* sharded ``ServingEngine`` greedy decode token-identical to the
  single-device engine on a mixed-length sparse batch;
* checkpoint save/restore round-trip of sharded params + opt state.

Multi-device cases run in subprocesses (XLA device count is locked at
first jax init; the main test process keeps the real 1-CPU view).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (make_block_pattern, merge_slab, partition_pattern,
                        split_slab)

REPO = os.path.join(os.path.dirname(__file__), "..")


def run_sub(code: str, devices: int = 8) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# host-side partition properties (fast, tier-1)
# ---------------------------------------------------------------------------


def _pat(n_lb=8, n_rb=16, bl=4, br=4, rho=0.5, seed=0):
    return make_block_pattern(n_lb * bl, n_rb * br, rho, block_in=bl,
                              block_out=br, seed=seed)


def test_partition_covers_rows_disjointly_and_balances_slots():
    bp = _pat()
    for k in (2, 4, 8):
        part = partition_pattern(bp, k)
        rows = np.sort(np.concatenate(
            [s.meta["rows"] for s in part.shards]))
        assert rows.tolist() == list(range(bp.n_rb))
        slot_counts = [s.block_idx.size for s in part.shards]
        assert len(set(slot_counts)) == 1  # balanced by slot count
        assert part.contiguous
        # inverse permutation really inverts
        assert (part.perm[part.inv_perm] == np.arange(bp.n_rb)).all()


def test_partition_local_patterns_preserve_adjacency():
    bp = _pat()
    part = partition_pattern(bp, 4)
    for s, shard in enumerate(part.shards):
        rows = part.parent.block_idx[np.asarray(part.shards[s].meta["rows"])]
        assert (shard.block_idx == rows).all()
        # padded scatter form: valid entries reproduce every edge exactly
        edges = set()
        for lb in range(bp.n_lb):
            for g in range(part.out_idx.shape[2]):
                if part.out_valid[s, lb, g]:
                    r = part.out_idx[s, lb, g]
                    f = part.out_slot[s, lb, g]
                    assert shard.block_idx[r, f] == lb
                    edges.add((int(r), int(f)))
        assert len(edges) == shard.block_idx.size  # all edges, no dupes


def test_partition_rejects_indivisible_row_counts():
    bp = _pat(n_rb=6)
    with pytest.raises(ValueError):
        partition_pattern(bp, 4)


def test_slab_split_merge_roundtrip_4d_and_5d():
    bp = _pat()
    part = partition_pattern(bp, 4)
    rng = np.random.default_rng(0)
    w4 = rng.normal(size=(bp.n_rb, bp.d_in_b, 4, 4)).astype(np.float32)
    ws = split_slab(w4, part)
    assert ws.shape == (4, bp.n_rb // 4, bp.d_in_b, 4, 4)
    np.testing.assert_array_equal(merge_slab(ws, part), w4)
    w5 = rng.normal(size=(3, bp.n_rb, bp.d_in_b, 4, 4)).astype(np.float32)
    ws5 = split_slab(w5, part)
    assert ws5.shape == (4, 3, bp.n_rb // 4, bp.d_in_b, 4, 4)
    np.testing.assert_array_equal(merge_slab(ws5, part), w5)


def test_shard_pattern_is_a_full_csd_matmul_citizen():
    """A shard-local BlockPattern (padded, validity-masked scatter form)
    must behave correctly through the PUBLIC csd_matmul API — scatter
    dataflow forward and gradients — matching the corresponding slice of
    the full junction."""
    import jax.numpy as jnp
    from repro.kernels import ops
    bp = _pat()
    k = 4
    part = partition_pattern(bp, k)
    rng = np.random.default_rng(5)
    m, q = 6, part.n_rb_local
    x = jnp.asarray(rng.normal(size=(m, bp.n_in)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(bp.n_rb, bp.d_in_b, 4, 4)),
                    jnp.float32)
    ws = split_slab(np.asarray(w), part)
    y_full = ops.csd_matmul(x, w, bp, backend="xla")
    for s in (0, k - 1):
        shard = part.shards[s]
        assert shard.out_valid is not None
        for dataflow in ("gather", "scatter"):
            y_s = ops.csd_matmul(x, jnp.asarray(ws[s]), shard,
                                 backend="xla", dataflow=dataflow)
            ref = y_full[:, s * q * 4:(s + 1) * q * 4]
            np.testing.assert_allclose(y_s, ref, atol=1e-4, rtol=1e-4,
                                       err_msg=f"s={s} {dataflow}")
        # grads through the shard pattern's (masked) BP/UP
        g_s = jax.grad(lambda xx: jnp.sum(jnp.sin(
            ops.csd_matmul(xx, jnp.asarray(ws[s]), shard,
                           backend="xla"))))(x)
        g_ref = jax.grad(lambda xx: jnp.sum(jnp.sin(
            ops.csd_matmul(xx, w, bp, backend="xla")
            [:, s * q * 4:(s + 1) * q * 4])))(x)
        np.testing.assert_allclose(g_s, g_ref, atol=1e-4, rtol=1e-4)


def test_permutation_plumbing_inverts_on_synthetic_noncontiguous():
    """perm/inv_perm + the slab helpers + reassemble_outputs honor a
    general (non-identity) assignment: fixed-degree patterns never
    produce one, so pin the machinery with a synthetic shuffle."""
    import dataclasses as dc
    bp = _pat()
    part = partition_pattern(bp, 4)
    rng = np.random.default_rng(9)
    perm = rng.permutation(bp.n_rb).astype(np.int32)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(bp.n_rb, dtype=np.int32)
    shuffled = dc.replace(part, perm=perm, inv_perm=inv)
    assert not shuffled.contiguous
    w = rng.normal(size=(bp.n_rb, bp.d_in_b, 4, 4)).astype(np.float32)
    np.testing.assert_array_equal(
        merge_slab(split_slab(w, shuffled), shuffled), w)
    # shard-major feature order -> logical order at block granularity
    y_logical = rng.normal(size=(3, bp.n_out)).astype(np.float32)
    yb = y_logical.reshape(3, bp.n_rb, bp.block_out)
    y_shard_major = yb[:, perm].reshape(3, bp.n_out)
    from repro.core import reassemble_outputs
    np.testing.assert_array_equal(
        reassemble_outputs(y_shard_major, shuffled), y_logical)


def test_partitioned_dx_partials_sum_to_full():
    """Each shard's validity-masked BP over its padded local scatter form
    contributes exactly its share: the partials sum to the full-pattern
    dx (this is what the sharded VJP psums)."""
    from repro.kernels import ops
    from repro.kernels.csd_spmm import csd_spmm_dx
    bp = _pat()
    k = 4
    part = partition_pattern(bp, k)
    rng = np.random.default_rng(1)
    m = 6
    w = rng.normal(size=(bp.n_rb, bp.d_in_b, 4, 4)).astype(np.float32)
    dy = rng.normal(size=(m, bp.n_out)).astype(np.float32)
    dx_full = np.asarray(ops._xla_dx(
        jax.numpy.asarray(dy), jax.numpy.asarray(w),
        bp.out_idx, bp.out_slot))
    ws = split_slab(w, part)
    dyb = dy.reshape(m, bp.n_rb, 4)
    acc = np.zeros((m, bp.n_in), np.float32)
    q = part.n_rb_local
    for s in range(k):
        dy_s = dyb[:, s * q:(s + 1) * q].reshape(m, -1)
        dx_s = csd_spmm_dx(
            jax.numpy.asarray(dy_s), jax.numpy.asarray(ws[s]),
            part.out_idx[s], part.out_slot[s],
            out_valid=part.out_valid[s], block_m=2, interpret=True)
        acc += np.asarray(dx_s)
    np.testing.assert_allclose(acc, dx_full, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# 8-device parity: sharded csd_matmul fwd + VJP, 4-D and 5-D slabs
# ---------------------------------------------------------------------------

_PARITY_PRELUDE = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import make_block_pattern
    from repro.kernels import ops

    bp = make_block_pattern(8 * 4, 16 * 4, 0.5, block_in=4, block_out=4,
                            seed=0)
    mesh = jax.make_mesh((8,), ("model",))
    ks = jax.random.split(jax.random.key(0), 3)

    def check(mk_args, backends, acts):
        worst = 0.0
        for act in acts:
            for kw in backends:
                w, x, b = mk_args()
                f0 = lambda w, x, b: ops.csd_matmul(
                    x, w, bp, bias=b, activation=act, **kw)
                f1 = lambda w, x, b: ops.csd_matmul(
                    x, w, bp, bias=b, activation=act, mesh=mesh,
                    axis="model", **kw)
                y0, y1 = f0(w, x, b), f1(w, x, b)
                worst = max(worst, float(jnp.abs(y0 - y1).max()))
                loss = lambda f: (lambda w, x, b:
                                  jnp.sum(jnp.sin(f(w, x, b))))
                g0 = jax.grad(loss(f0), argnums=(0, 1, 2))(w, x, b)
                g1 = jax.grad(loss(f1), argnums=(0, 1, 2))(w, x, b)
                for a, c in zip(g0, g1):
                    worst = max(worst, float(jnp.abs(a - c).max()))
        print("WORST", worst)
"""


@pytest.mark.slow
def test_sharded_csd_matmul_parity_4d_8dev():
    out = run_sub(_PARITY_PRELUDE + """
    def mk():
        x = jax.random.normal(ks[0], (6, bp.n_in))
        w = jax.random.normal(ks[1], (bp.n_rb, bp.d_in_b, 4, 4))
        b = jax.random.normal(ks[2], (bp.n_out,))
        return w, x, b
    check(mk,
          [dict(backend="xla"),
           dict(backend="pallas", block_m=2, interpret=True)],
          [None, "relu", "gelu"])
    """)
    assert float(out.split("WORST")[1].split()[0]) < 1e-4, out


@pytest.mark.slow
def test_sharded_csd_matmul_parity_5d_8dev():
    out = run_sub(_PARITY_PRELUDE + """
    def mk():
        E = 3
        x = jax.random.normal(ks[0], (E, 6, bp.n_in))
        w = jax.random.normal(ks[1], (E, bp.n_rb, bp.d_in_b, 4, 4))
        b = jax.random.normal(ks[2], (E, bp.n_out))
        return w, x, b
    check(mk,
          [dict(backend="xla"),
           dict(backend="pallas", block_m=2, interpret=True)],
          [None, "gelu"])
    """)
    assert float(out.split("WORST")[1].split()[0]) < 1e-4, out


@pytest.mark.slow
def test_sharded_quant_matmul_parity_4d_5d_8dev():
    """Int8 junction under the 8-way shard_map (slab + per-block scales
    both chunked on the block-row dim) == the single-device int8 path,
    4-D and 5-D, both backends. Forward-only: the quant path is
    inference-only by contract."""
    out = run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import make_block_pattern
    from repro.core.quant import quantize_slab
    from repro.kernels import ops

    bp = make_block_pattern(8 * 4, 16 * 4, 0.5, block_in=4, block_out=4,
                            seed=0)
    mesh = jax.make_mesh((8,), ("model",))
    ks = jax.random.split(jax.random.key(0), 3)
    worst = 0.0
    for batched in (False, True):
        if batched:
            x = jax.random.normal(ks[0], (3, 6, bp.n_in))
            w = jax.random.normal(ks[1], (3, bp.n_rb, bp.d_in_b, 4, 4))
            b = jax.random.normal(ks[2], (3, bp.n_out))
        else:
            x = jax.random.normal(ks[0], (6, bp.n_in))
            w = jax.random.normal(ks[1], (bp.n_rb, bp.d_in_b, 4, 4))
            b = jax.random.normal(ks[2], (bp.n_out,))
        q, s = quantize_slab(w)
        for kw in (dict(backend="xla"),
                   dict(backend="pallas", block_m=2, interpret=True)):
            y0 = ops.csd_matmul(x, q, bp, bias=b, activation="relu",
                                w_scale=s, **kw)
            y1 = ops.csd_matmul(x, q, bp, bias=b, activation="relu",
                                w_scale=s, mesh=mesh, axis="model", **kw)
            worst = max(worst, float(jnp.abs(y0 - y1).max()))
    print("WORST", worst)
    """)
    assert float(out.split("WORST")[1].split()[0]) < 1e-4, out


@pytest.mark.slow
def test_sharded_engine_int8_decode_parity_8dev():
    """ISSUE acceptance (sharded leg): the int8 engine under an 8-way
    SERVE mesh — quantized slabs + scale siblings placed by the extended
    spec, int8 KV pools + per-token scale pools partitioned on the same
    axis — decodes token-identically to the single-device int8 engine."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.quant import QuantConfig
        from repro.nn import ModelConfig, SparsityConfig, build_model
        from repro.serving import EngineConfig, ServingEngine

        sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                            block_in=8, block_out=8, backend="xla")
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          d_ff=64, vocab_size=128, attn_chunk=8,
                          loss_chunk=8, dtype="float32", remat=False,
                          sparsity=sp)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, 128, n).astype(np.int32)
                   for n in (5, 11, 8, 3)]
        ecfg = EngineConfig(max_slots=4, page_size=4, total_pages=31,
                            max_pages_per_seq=8, token_budget=16,
                            prefill_chunk=8, backend="xla",
                            quant=QuantConfig())
        ref = ServingEngine(model, params, ecfg).run(prompts, 12)

        mesh = jax.make_mesh((8,), ("model",))
        eng = ServingEngine(model, params, ecfg, mesh=mesh)
        slabs = [l for l in jax.tree.leaves(eng.params)
                 if l.dtype == jnp.int8]
        assert slabs, "engine did not quantize at load"
        up = eng.params["stack"]["scan"][0]["ffn"]["up"]
        wq, ws = up["w"], up["w_scale"]
        chunked = all(
            s.data.shape[1] == wq.shape[1] // 8
            for s in wq.addressable_shards) and all(
            s.data.shape[1] == ws.shape[1] // 8
            for s in ws.addressable_shards)
        print("SLABCHUNKED", chunked)
        blk = eng.cache["scan"][0]["self"]
        kp, ks = blk["k_pages"], blk["k_scale"]
        kvq = kp.dtype == jnp.int8 and all(
            s.data.shape[1] == kp.shape[1] // 8
            for s in kp.addressable_shards) and all(
            s.data.shape[1] == ks.shape[1] // 8
            for s in ks.addressable_shards)
        print("KVCHUNKED", kvq)
        got = eng.run(prompts, 12)
        same = all(a.tolist() == b.tolist() for a, b in zip(ref, got))
        print("TOKENPARITY", same)
    """, devices=8)
    assert "SLABCHUNKED True" in out, out
    assert "KVCHUNKED True" in out, out
    assert "TOKENPARITY True" in out, out


# ---------------------------------------------------------------------------
# sharded train step parity + checkpoint round-trip
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_train_step_loss_parity_and_slab_chunking():
    """(2 data x 4 model) sharded train step of a sparse LM == unsharded
    step; the slab rule must actually chunk sparse weights + Adam state
    on the block-row dim."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import ModelConfig, SparsityConfig, build_model
        from repro.nn.common import mesh_context
        from repro.optim import AdamWConfig, adam
        from repro.launch import specs
        from repro.sharding import policy

        sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                            block_in=8, block_out=8, backend="xla")
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          d_ff=64, vocab_size=128, attn_chunk=8,
                          loss_chunk=8, dtype="float32", remat=False,
                          sparsity=sp)
        model = build_model(cfg)
        assert model.stack.unit_blocks[0].ffn.up.is_sparse
        params = model.init(jax.random.key(0))
        opt = adam.init(params)
        tokens = jax.random.randint(jax.random.key(1), (8, 32), 0, 128)
        batch = {"tokens": tokens, "labels": tokens}
        step = specs.make_train_step(model, AdamWConfig(lr=1e-3,
                                                        warmup_steps=0))
        p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = policy.rules_for("train", 8, mesh, cfg)
        assert rules["slab"] == "model"
        pspec = policy.param_pspecs(model.spec(), rules)
        p_sh = policy.named(mesh, pspec, params)
        o_sh = policy.named(mesh, policy.opt_pspecs(pspec), opt)
        b_sh = policy.named(mesh, policy.batch_pspecs(batch, rules), batch)
        with mesh, mesh_context(mesh, rules):
            p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                                 out_shardings=(p_sh, o_sh, None))(
                params, opt, batch)
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)))
        print("MAXERR", err)
        print("LOSSDIFF", abs(float(m_ref["loss"]) - float(m2["loss"])))
        # the up-projection slab (n_rb=8 block-rows) and its Adam state
        # must be chunked 4-ways on the block-row dim
        up = p2["stack"]["scan"][0]["ffn"]["up"]["w"]
        assert up.ndim == 5  # (layers, n_rb, d_in_b, bL, bR)
        shard_shapes = {s.data.shape for s in up.addressable_shards}
        print("CHUNKED", all(sh[1] == up.shape[1] // 4
                             for sh in shard_shapes))
    """, devices=8)
    # one Adam step at lr=1e-3 moves params by ~lr; reduction-order noise
    # flips low bits of the update, so the budget is a few ulps of lr.
    # Keep this tight: a missing dw/db psum over the data axis (sparselint
    # SL205) produces ~lr-scale divergence that 5e-3 would let through
    assert float(out.split("MAXERR")[1].split()[0]) < 5e-4, out
    assert float(out.split("LOSSDIFF")[1].split()[0]) < 1e-4, out
    assert "CHUNKED True" in out, out


@pytest.mark.slow
def test_sharded_checkpoint_roundtrip_8dev():
    """Sharded params + Adam state survive a save/restore cycle with
    their shardings reapplied (restore device_puts per-leaf)."""
    out = run_sub("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import ModelConfig, SparsityConfig, build_model
        from repro.optim import adam
        from repro.sharding import policy
        from repro.train.checkpoint import CheckpointManager

        sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                            block_in=8, block_out=8, backend="xla")
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          d_ff=64, vocab_size=128, dtype="float32",
                          remat=False, sparsity=sp)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        opt = adam.init(params)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = policy.rules_for("train", 8, mesh, cfg)
        pspec = policy.param_pspecs(model.spec(), rules)
        p_sh = policy.named(mesh, pspec, params)
        o_sh = policy.named(mesh, policy.opt_pspecs(pspec), opt)
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, o_sh)

        with tempfile.TemporaryDirectory() as d:
            ckpt = CheckpointManager(d, keep=1)
            ckpt.save(7, (params, opt))
            (p2, o2), _ = ckpt.restore(7, (params, opt), (p_sh, o_sh))
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
        err = max(err, max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(opt), jax.tree.leaves(o2))))
        print("MAXERR", err)
        same = all(a.sharding == b.sharding for a, b in
                   zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
        print("SHARDINGS", same)
    """, devices=8)
    assert float(out.split("MAXERR")[1].split()[0]) == 0.0, out
    assert "SHARDINGS True" in out, out


# ---------------------------------------------------------------------------
# sharded engine decode parity
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_engine_decode_token_parity_8dev():
    """Acceptance: a ServingEngine built under an 8-way SERVE mesh (slab-
    sharded junctions + pages partitioned on the same axis) produces
    token-identical greedy decodes to the single-device engine on a
    mixed-length sparse batch."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.nn import ModelConfig, SparsityConfig, build_model
        from repro.serving import EngineConfig, ServingEngine

        sp = SparsityConfig(enabled=True, rho_ffn=(0.5, 1.0),
                            block_in=8, block_out=8, backend="xla")
        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          d_ff=64, vocab_size=128, attn_chunk=8,
                          loss_chunk=8, dtype="float32", remat=False,
                          sparsity=sp)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, 128, n).astype(np.int32)
                   for n in (5, 11, 8, 3)]
        # total_pages = 31 -> the (P+1)-page pools divide 8 and the KV
        # pages really partition (context-parallel KV)
        ecfg = EngineConfig(max_slots=4, page_size=4, total_pages=31,
                            max_pages_per_seq=8, token_budget=16,
                            prefill_chunk=8, backend="xla")
        ref = ServingEngine(model, params, ecfg).run(prompts, 12)

        mesh = jax.make_mesh((8,), ("model",))
        eng = ServingEngine(model, params, ecfg, mesh=mesh)
        assert eng.rules["slab"] == "model"
        kp = eng.cache["scan"][0]["self"]["k_pages"]
        # pages dim (P+1 = 32) must really be chunked 8 ways
        chunked = all(s.data.shape[1] == kp.shape[1] // 8
                      for s in kp.addressable_shards)
        print("KVCHUNKED", chunked)
        got = eng.run(prompts, 12)
        same = all(a.tolist() == b.tolist() for a, b in zip(ref, got))
        print("TOKENPARITY", same)
    """, devices=8)
    assert "TOKENPARITY True" in out, out
    assert "KVCHUNKED True" in out, out
