"""Per-architecture smoke tests: reduced config, forward + train step on
CPU, output shapes + no NaNs; decode consistency against teacher forcing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, shapes_for
from repro.nn import build_model
from repro.optim import AdamWConfig
from repro.optim import adam


def _batch(cfg, b=2, s=24, seed=0):
    tokens = jax.random.randint(jax.random.key(seed), (b, s), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.input_mode == "embeddings" or cfg.enc_dec is not None:
        batch["embeds"] = jax.random.normal(
            jax.random.key(seed + 1), (b, s, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), f"{arch} loss not finite"

    # one full train step (loss + grad + AdamW) — shapes preserved, no NaNs
    opt = adam.init(params)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    new_params, new_opt, om = adam.update(AdamWConfig(lr=1e-3), g, opt,
                                          params)
    for a, b2 in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b2.shape
        assert jnp.isfinite(b2).all()
    assert jnp.isfinite(om["grad_norm"])


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    s = batch["tokens"].shape[1]
    logits, cache = model.prefill(params, batch, s + 8)
    assert logits.shape[:2] == (2, 1)
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ["gemma3_4b", "qwen2_7b", "mamba2_130m",
                                  "zamba2_1p2b", "deepseek_moe_16b"])
def test_decode_matches_teacher_forcing(arch):
    """Incremental decode == full forward at the new position."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=100.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    logits, cache = model.prefill(params, batch, 24)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = model.decode_step(params, tok, cache)
    full = jnp.concatenate([tokens, tok], axis=1)
    h, _, _ = model.forward(params, {"tokens": full})
    ref = model.logits_fn(params, h[:, -1:])
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_assigned_cell_count():
    cells = [(a, s.name) for a in ARCHS for s in shapes_for(a)]
    # 10 archs x 3 universal shapes + 3 long_500k (ssm/hybrid/5:1-window)
    assert len(cells) == 33
    longs = [c for c in cells if c[1] == "long_500k"]
    assert {a for a, _ in longs} == {"mamba2_130m", "zamba2_1p2b",
                                     "gemma3_4b"}


def test_exact_published_dimensions():
    """The full configs carry the exact assigned numbers."""
    want = {
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "granite_34b": (88, 6144, 48, 1, 24576, 49152),
        "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152064),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
    }
    for arch, (L, d, h, kv, ff, v) in want.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    ds = get_config("deepseek_moe_16b")
    assert (ds.moe.n_routed, ds.moe.top_k, ds.moe.n_shared) == (64, 6, 2)
    gm = get_config("granite_moe_1b_a400m")
    assert (gm.moe.n_routed, gm.moe.top_k) == (32, 8)
    mb = get_config("mamba2_130m")
    assert (mb.n_layers, mb.d_model, mb.ssm.d_state) == (24, 768, 128)
    zb = get_config("zamba2_1p2b")
    assert (zb.n_layers, zb.d_model, zb.ssm.d_state) == (38, 2048, 64)
    sm = get_config("seamless_m4t_medium")
    assert (sm.enc_dec.n_encoder_layers, sm.d_model, sm.vocab_size) == \
        (12, 1024, 256206)
