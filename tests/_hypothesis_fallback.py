"""Degraded-mode stand-in for ``hypothesis`` when it is not installed.

The property tests guard their import with::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

so environments without hypothesis (the pinned container image) still
*execute* the invariants instead of skipping whole modules at collection.
The fallback draws deterministic pseudo-random examples from the small
strategy subset the suite uses (``integers``, ``floats``, ``booleans``,
``sampled_from``, ``composite``). No shrinking, no database, no edge-case
bias — install real hypothesis (``pip install -e '.[test]'``) for the full
property-based run.
"""
from __future__ import annotations



import numpy as np

_MAX_EXAMPLES_CAP = 50


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)


class strategies:  # noqa: N801 — mimics the hypothesis.strategies module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                       max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def sampled_from(elements):
        xs = list(elements)
        return _Strategy(lambda rng: xs[int(rng.integers(len(xs)))])

    @staticmethod
    def composite(fn):
        def builder(*args, **kwargs):
            return _Strategy(
                lambda rng: fn(lambda s: s.sample(rng), *args, **kwargs))
        return builder


st = strategies


def settings(max_examples: int = 20, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        n = min(getattr(fn, "_fallback_max_examples", 20),
                _MAX_EXAMPLES_CAP)

        # zero-arg wrapper: every test argument comes from a strategy, and
        # pytest must not mistake the wrapped signature for fixtures
        def wrapper():
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*(s.sample(rng) for s in strats))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
